# AOT pipeline tests: manifest consistency and HLO-text validity of every
# artifact the registry produces (the rust runtime trusts these).
import json
import os

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_registry(manifest):
    for name in aot.registry(full=False):
        assert name in manifest["artifacts"], f"{name} missing from manifest"


def test_artifact_files_exist_and_are_hlo(manifest):
    for name, spec in manifest["artifacts"].items():
        path = os.path.join(ART_DIR, spec["file"])
        assert os.path.exists(path), path
        head = open(path).read(200)
        assert "HloModule" in head, f"{name}: not HLO text"


def test_input_kinds_and_order(manifest):
    # Params come first (sorted), then args — the rust Executor relies on
    # this ordering when assembling execute_b argument lists.
    for name, spec in manifest["artifacts"].items():
        kinds = [i["kind"] for i in spec["inputs"]]
        if "param" in kinds:
            first_arg = kinds.index("arg") if "arg" in kinds else len(kinds)
            assert all(k == "param" for k in kinds[:first_arg]), name
            assert all(k == "arg" for k in kinds[first_arg:]), name
            # Model params (non-optimizer-state) are sorted by name; the
            # rust Executor feeds params strictly in manifest order.
            pnames = [
                i["name"]
                for i in spec["inputs"]
                if i["kind"] == "param" and not i["name"].startswith("adam_")
            ]
            assert pnames == sorted(pnames), f"{name}: params not sorted"


def test_decode_static_config(manifest):
    spec = manifest["artifacts"]["decode_dec_tiny_b1"]
    st = spec["static"]
    cfg = model.DEC_TINY
    assert st["vocab"] == cfg.vocab
    assert st["dim"] == cfg.dim
    assert st["n_layers"] == cfg.n_layers
    assert st["knn_k"] == cfg.knn_k
    outs = [o["name"] for o in spec["outputs"]]
    assert outs == ["probs", "query_vec", "new_kv"]


def test_scan_artifacts_cover_table3_widths(manifest):
    for m in (16, 32, 64):
        name = f"chamvs_scan_m{m}"
        st = manifest["artifacts"][name]["static"]
        assert st["m"] == m
        assert st["k"] == 100
        # VMEM discipline: cost dict records a tile that fits ~16 MiB.
        assert st["cost"]["vmem_bytes_per_tile"] < 16 * 2**20


def test_cost_fields_present(manifest):
    st = manifest["artifacts"]["decode_dec_tiny_b1"]["static"]
    assert st["cost"]["flops"] > 0
    assert st["cost"]["param_bytes"] > 0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
