# L2 search-graph correctness: the full chamvs_scan pipeline (LUT -> ADC
# -> approximate top-K) vs a flat oracle, including the padding contract
# the rust memory node relies on.
import numpy as np
import pytest
import jax.numpy as jnp

from compile import pq
from compile.kernels import ref


def setup(seed, n=2048, m=16, dsub=8):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((m, dsub)), jnp.float32)
    cb = jnp.asarray(rng.standard_normal((m, 256, dsub)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.int32)
    return q, cb, codes


def oracle(q, cb, codes, k):
    lut = ref.lut_ref(q, cb)
    dists = ref.adc_scan_ref(codes, lut)
    return ref.topk_ref(dists, k)


def test_chamvs_scan_matches_oracle():
    q, cb, codes = setup(0)
    n_valid = jnp.asarray([codes.shape[0]], jnp.int32)
    vals, idxs = pq.chamvs_scan(q, cb, codes, n_valid, k=100)
    ovals, oidxs = oracle(q, cb, codes, 100)
    overlap = np.isin(np.asarray(idxs), np.asarray(oidxs)).mean()
    assert overlap >= 0.98, overlap
    np.testing.assert_allclose(
        np.sort(np.asarray(vals)), np.asarray(vals), rtol=1e-6
    )  # ascending


def test_padding_never_wins():
    # Mark only the first 100 codes valid; padded rows must never appear.
    q, cb, codes = setup(1, n=1024)
    n_valid = jnp.asarray([100], jnp.int32)
    vals, idxs = pq.chamvs_scan(q, cb, codes, n_valid, k=50)
    assert int(jnp.max(idxs)) < 100
    # And results equal the oracle restricted to the valid prefix.
    ovals, oidxs = oracle(q, cb, codes[:100], 50)
    overlap = np.isin(np.asarray(idxs), np.asarray(oidxs)).mean()
    assert overlap >= 0.95, overlap


def test_batch_variant_consistent():
    q, cb, codes = setup(2, n=512)
    qs = jnp.stack([q, q * 0.5])
    codes_b = jnp.stack([codes, codes])
    nv = jnp.asarray([[512], [512]], jnp.int32)
    vals, idxs = pq.chamvs_scan_batch(qs, cb, codes_b, nv, k=10)
    v0, i0 = pq.chamvs_scan(q, cb, codes, jnp.asarray([512], jnp.int32), k=10)
    np.testing.assert_allclose(np.asarray(vals[0]), np.asarray(v0), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idxs[0]), np.asarray(i0))


def test_distances_nonnegative():
    q, cb, codes = setup(3, n=512)
    vals, _ = pq.chamvs_scan(q, cb, codes, jnp.asarray([512], jnp.int32), k=20)
    assert bool(jnp.all(vals >= 0.0))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
