# L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.
# hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is THE
# correctness signal the rust runtime inherits through the AOT artifacts.
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ivf_scan, pq_lut, pq_scan, ref, topk

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------- pq_lut
@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    dsub=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_matches_ref(m, dsub, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, m, dsub)
    cb = rand(rng, m, 256, dsub)
    got = pq_lut.lut(q, cb)
    np.testing.assert_allclose(got, ref.lut_ref(q, cb), rtol=1e-5, atol=1e-5)


def test_batched_lut():
    rng = np.random.default_rng(0)
    qs = rand(rng, 4, 16, 8)
    cb = rand(rng, 16, 256, 8)
    got = pq_lut.batched_lut(qs, cb)
    np.testing.assert_allclose(
        got, ref.batched_lut_ref(qs, cb), rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------- pq_scan
@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adc_onehot_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.int32)
    lut_tbl = jnp.abs(rand(rng, m, 256))
    got = pq_scan.adc_scan(codes, lut_tbl)
    want = ref.adc_scan_ref(codes, lut_tbl)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(m=st.sampled_from([8, 32]), seed=st.integers(0, 2**31 - 1))
def test_adc_gather_matches_onehot(m, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 256, (256, m)), jnp.int32)
    lut_tbl = jnp.abs(rand(rng, m, 256))
    a = pq_scan.adc_scan(codes, lut_tbl)
    b = pq_scan.adc_scan_gather(codes, lut_tbl)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_adc_extreme_codes():
    # Codes 0 and 255 exercise the one-hot boundary lanes.
    m = 16
    codes = jnp.concatenate(
        [jnp.zeros((8, m), jnp.int32), jnp.full((8, m), 255, jnp.int32)]
    )
    lut_tbl = jnp.arange(m * 256, dtype=jnp.float32).reshape(m, 256)
    got = pq_scan.adc_scan(codes, lut_tbl)
    want = ref.adc_scan_ref(codes, lut_tbl)
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ------------------------------------------------------------------ topk
@settings(**SETTINGS)
@given(
    k=st.sampled_from([1, 10, 100]),
    lanes=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_approx_topk_values_match_exact(k, lanes, seed):
    rng = np.random.default_rng(seed)
    n = 4096
    dists = rand(rng, n)
    vals, idxs = topk.approx_hier_topk(dists, k, num_lanes=lanes)
    # With the 99% depth bound, a single random query matches exact nearly
    # always; we assert the guaranteed invariants and near-agreement:
    assert vals.shape == (k,)
    # ascending
    assert bool(jnp.all(vals[1:] >= vals[:-1]))
    # idxs point at their values
    np.testing.assert_allclose(dists[idxs], vals, rtol=1e-6)
    # overlap with exact top-k is near-total
    ref_vals, ref_idxs = ref.topk_ref(dists, k)
    overlap = np.isin(np.asarray(idxs), np.asarray(ref_idxs)).mean()
    assert overlap >= 0.95, overlap


def test_approx_topk_matches_lane_reference():
    rng = np.random.default_rng(1)
    dists = rand(rng, 2048)
    vals, idxs = topk.approx_hier_topk(dists, 50, num_lanes=8, lane_depth=12)
    rvals, ridxs = ref.approx_hier_topk_ref(dists, 50, 8, 12)
    np.testing.assert_allclose(vals, rvals, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idxs), np.asarray(ridxs))


def test_default_lane_depth_bound():
    # Matches rust kselect::binomial::required_depth semantics.
    d = topk.default_lane_depth(100, 16)
    assert 10 <= d <= 20, d
    assert topk.default_lane_depth(100, 64) < d


# -------------------------------------------------------------- ivf_scan
@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 4, 16]),
    d=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ivf_scan_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    nlist, nprobe = 2048, 32
    qs = rand(rng, b, d)
    cents = rand(rng, nlist, d)
    dv, di = ivf_scan.ivf_scan(qs, cents, nprobe)
    rv, ri = ref.ivf_scan_ref(qs, cents, nprobe)
    np.testing.assert_allclose(dv, rv, rtol=1e-3, atol=1e-3)
    # Ties can permute ids; compare as sets per query.
    for i in range(b):
        assert set(np.asarray(di[i]).tolist()) == set(np.asarray(ri[i]).tolist())


def test_ivf_dists_exact_values():
    q = jnp.asarray([[1.0, 0.0], [0.0, 2.0]], jnp.float32)
    c = jnp.asarray([[1.0, 0.0], [0.0, 0.0], [1.0, 2.0]], jnp.float32)
    d = ivf_scan.ivf_dists(q, c, interpret=True)
    want = np.array([[0.0, 1.0, 4.0], [5.0, 4.0, 1.0]], np.float32)
    np.testing.assert_allclose(d, want, atol=1e-5)


# ------------------------------------------------------------- attention
@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 4, 8]),
    t_valid=st.integers(1, 256),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, t_valid, seed):
    rng = np.random.default_rng(seed)
    T, dh = 256, 32
    q = rand(rng, h, dh)
    k = rand(rng, h, T, dh)
    v = rand(rng, h, T, dh)
    got = attention.decode_attention(q, k, v, t_valid)
    want = ref.attention_ref(q, k, v, t_valid)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_single_valid_token():
    # t=1: output must equal v[:, 0] exactly (softmax over one element).
    rng = np.random.default_rng(3)
    q = rand(rng, 2, 16)
    k = rand(rng, 2, 128, 16)
    v = rand(rng, 2, 128, 16)
    got = attention.decode_attention(q, k, v, 1)
    np.testing.assert_allclose(got, v[:, 0], rtol=1e-5, atol=1e-5)


def test_attention_vmap_batches():
    # The batched decode artifact vmaps the kernel; verify that path.
    rng = np.random.default_rng(4)
    B, h, T, dh = 3, 2, 128, 16
    q = rand(rng, B, h, dh)
    k = rand(rng, B, h, T, dh)
    v = rand(rng, B, h, T, dh)
    ts = jnp.asarray([1, 64, 128], jnp.int32)
    got = jax.vmap(lambda a, b, c, t: attention.decode_attention(a, b, c, t))(
        q, k, v, ts
    )
    for i in range(B):
        want = ref.attention_ref(q[i], k[i], v[i], ts[i])
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
