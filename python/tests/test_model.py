# L2 model correctness: decode-with-KV-cache vs full forward, kNN-LM
# interpolation, encoder-decoder path, and the train step.
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model

CFG = model.DEC_TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


def zero_kv(cfg):
    return jnp.zeros(
        (cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32
    )


def no_knn(cfg):
    rt = jnp.zeros((cfg.knn_k,), jnp.int32)
    rd = jnp.full((cfg.knn_k,), 1e4, jnp.float32)
    return rt, rd


def test_decode_matches_forward(params):
    # Stepping the decode path must reproduce the full causal forward.
    cfg0 = model.ModelConfig(
        "lam0", CFG.vocab, CFG.dim, CFG.n_layers, CFG.n_heads,
        max_seq=CFG.max_seq, knn_k=CFG.knn_k, knn_lambda=0.0,
    )
    toks = jnp.asarray([[5, 9, 3, 7, 100, 42]], jnp.int32)
    logits = model.lm_forward(cfg0, params, toks)
    kv = zero_kv(cfg0)
    rt, rd = no_knn(cfg0)
    for i in range(6):
        probs, _, kv = model.decode_step_jit(
            cfg0, params, toks[0, i : i + 1], jnp.asarray([i], jnp.int32), kv, rt, rd
        )
        want = jax.nn.softmax(logits[0, i])
        np.testing.assert_allclose(
            np.asarray(probs), np.asarray(want), rtol=5e-3, atol=5e-5
        )


def test_knn_interpolation_shifts_mass(params):
    # Close neighbors all voting for one token must raise its probability
    # by ~lambda relative to the pure LM distribution.
    kv = zero_kv(CFG)
    rt, rd = no_knn(CFG)
    tok = jnp.asarray([3], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    p_lm, _, _ = model.decode_step_jit(CFG, params, tok, pos, kv, rt, rd)
    target = 777
    rt2 = jnp.full((CFG.knn_k,), target, jnp.int32)
    rd2 = jnp.zeros((CFG.knn_k,), jnp.float32)  # all at distance 0
    p_knn, _, _ = model.decode_step_jit(CFG, params, tok, pos, kv, rt2, rd2)
    gain = float(p_knn[target] - p_lm[target])
    assert abs(gain - CFG.knn_lambda * (1.0 - float(p_lm[target]) / 1.0)) < 0.05
    assert float(jnp.abs(p_knn.sum() - 1.0)) < 1e-3


def test_knn_distance_weighting(params):
    # A strictly closer neighbor gets more interpolation weight.
    kv = zero_kv(CFG)
    tok = jnp.asarray([3], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    rt = jnp.asarray([11] + [22] * (CFG.knn_k - 1), jnp.int32)
    rd = jnp.asarray([0.0] + [50.0] * (CFG.knn_k - 1), jnp.float32)
    probs, _, _ = model.decode_step_jit(CFG, params, tok, pos, kv, rt, rd)
    assert float(probs[11]) > float(probs[22])


def test_encdec_decode_consumes_encoder():
    cfg = model.ENCDEC_TINY
    p = model.init_params(cfg, seed=1)
    chunks = jnp.arange(cfg.knn_k * cfg.chunk_len, dtype=jnp.int32) % cfg.vocab
    enc = model.encoder_forward(cfg, p, chunks)
    assert enc.shape == (cfg.knn_k * cfg.chunk_len, cfg.dim)
    kv = zero_kv(cfg)
    rt, rd = no_knn(cfg)
    tok = jnp.asarray([1], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    probs1, _, _ = model.decode_step(cfg, p, tok, pos, kv, rt, rd, enc_out=enc)
    # Different encoder content must change the distribution.
    enc2 = model.encoder_forward(cfg, p, (chunks + 7) % cfg.vocab)
    probs2, _, _ = model.decode_step(cfg, p, tok, pos, kv, rt, rd, enc_out=enc2)
    assert not np.allclose(np.asarray(probs1), np.asarray(probs2), atol=1e-5)
    assert abs(float(probs1.sum()) - 1.0) < 1e-3


def test_train_step_reduces_loss():
    cfg = model.DEC_TINY
    p = model.init_params(cfg, seed=2)
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    rng = np.random.default_rng(0)
    # Markov-structured tokens (learnable).
    seqs = np.zeros((8, 32), np.int32)
    for b in range(8):
        t = rng.integers(0, cfg.vocab)
        for s in range(32):
            seqs[b, s] = t
            t = (t + rng.choice([1, 2, 3])) % cfg.vocab
    toks = jnp.asarray(seqs)
    step_fn = jax.jit(
        lambda p, m, v, s: model.train_step(cfg, p, m, v, s, toks, lr=1e-3)
    )
    losses = []
    for s in range(8):
        loss, p, m, v = step_fn(p, m, v, jnp.asarray(s, jnp.int32))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_param_count_matches_init():
    for cfg in [model.DEC_TINY, model.DEC_S]:
        p = model.init_params(cfg, seed=0)
        actual = sum(int(np.prod(v.shape)) for v in p.values())
        assert actual == cfg.param_count() - (
            cfg.vocab * cfg.dim if cfg.is_encdec else 0
        )


def test_query_vec_is_final_hidden(params):
    kv = zero_kv(CFG)
    rt, rd = no_knn(CFG)
    _, qv, _ = model.decode_step_jit(
        CFG, params, jnp.asarray([9], jnp.int32), jnp.asarray([0], jnp.int32), kv, rt, rd
    )
    assert qv.shape == (CFG.dim,)
    assert bool(jnp.all(jnp.isfinite(qv)))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
