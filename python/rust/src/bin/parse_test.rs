fn main() {
    for f in std::env::args().skip(1) {
        eprint!("parsing {f} ... ");
        match xla::HloModuleProto::from_text_file(&f) {
            Ok(_) => eprintln!("OK"),
            Err(e) => eprintln!("ERR {}", format!("{e}").lines().next().unwrap_or("")),
        }
    }
}
