# L2: ChamVS.mem search graph — the compute a disaggregated memory node
# performs per (query, IVF-list-shard) scan request (paper Sec 3 workflow
# steps 5-6): build the distance LUT, ADC-scan the shard's PQ codes, and
# K-select through the approximate hierarchical queue.
#
# One artifact per (n_codes, m, k) shape; the rust memory node pads its
# probed lists up to `n_codes` and passes `n_valid` so padding never wins.
import functools

import jax
import jax.numpy as jnp

from .kernels import pq_lut, pq_scan, topk


@functools.partial(
    jax.jit, static_argnames=("k", "num_lanes", "lane_depth", "interpret")
)
def chamvs_scan(
    query,  # (m, dsub) f32 sub-query vectors
    codebook,  # (m, 256, dsub) f32 PQ centroids
    codes,  # (n, m) int32 PQ codes of the probed lists (padded)
    n_valid,  # (1,) int32 number of real codes (<= n)
    k=100,
    num_lanes=16,
    lane_depth=None,
    interpret=True,
):
    """Full near-memory scan: LUT -> ADC -> approximate hierarchical top-K.

    Returns (vals (k,), idxs (k,)) — idxs are positions into `codes`; the
    rust node maps them back to global vector IDs via its shard layout.
    """
    lut_tbl = pq_lut.lut(query, codebook, interpret=interpret)
    dists = pq_scan.adc_scan(codes, lut_tbl, interpret=interpret)
    n = codes.shape[0]
    pad_mask = jnp.arange(n, dtype=jnp.int32) >= n_valid[0]
    dists = jnp.where(pad_mask, jnp.float32(jnp.finfo(jnp.float32).max), dists)
    return topk.approx_hier_topk(
        dists, k, num_lanes=num_lanes, lane_depth=lane_depth, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("k", "num_lanes", "lane_depth", "interpret")
)
def chamvs_scan_batch(queries, codebook, codes, n_valid, k=100, num_lanes=16,
                      lane_depth=None, interpret=True):
    """Batched variant: queries (b, m, dsub), codes (b, n, m), n_valid (b, 1)."""
    return jax.vmap(
        lambda q, c, nv: chamvs_scan(
            q, codebook, c, nv, k=k, num_lanes=num_lanes,
            lane_depth=lane_depth, interpret=interpret,
        )
    )(queries, codes, n_valid)
