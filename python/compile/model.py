# L2: ChamLM model graphs (paper Sec 3/5 — Fairseq-based in the original,
# re-implemented in JAX here).
#
# Two RALM families from Table 2:
#   * decoder-only (Dec-S/L): kNN-LM style; every step's last hidden state
#     is the retrieval query, and the next-token distribution is
#     interpolated with a distribution over retrieved next-tokens
#     (p = lambda * p_knn + (1 - lambda) * p_lm).
#   * encoder-decoder (EncDec-S/L): RETRO style; retrieved token chunks are
#     processed by a shallow encoder and consumed by the decoder through
#     cross-attention, with retrieval every `interval` tokens.
#
# The decode hot path calls the L1 Pallas attention kernel; everything is
# AOT-lowered by aot.py and executed from rust via PJRT. Python never runs
# at request time.
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    n_layers: int  # decoder layers
    n_heads: int
    enc_layers: int = 0  # 0 => decoder-only
    max_seq: int = 512
    knn_k: int = 100  # neighbors per retrieval
    chunk_len: int = 8  # tokens per retrieved chunk (EncDec)
    knn_lambda: float = 0.25
    knn_temp: float = 10.0

    @property
    def head_dim(self):
        return self.dim // self.n_heads

    @property
    def ffn_dim(self):
        return 4 * self.dim

    @property
    def is_encdec(self):
        return self.enc_layers > 0

    def param_count(self):
        """Analytic parameter count at paper scale.

        Encoder-decoder models are counted with a separate encoder
        embedding table (matches Table 2: EncDec-L = 1738M); the tiny
        execution variants share one table, which only matters for the
        scaled models' actual memory, not the paper-scale cost model.
        """
        d, v = self.dim, self.vocab
        per_dec = 4 * d * d + 2 * d * self.ffn_dim + (4 * d * d if self.is_encdec else 0)
        per_enc = 4 * d * d + 2 * d * self.ffn_dim
        enc_embed = v * d if self.is_encdec else 0
        return (
            v * d  # tied embedding / output projection
            + enc_embed
            + self.max_seq * d  # learned positions
            + self.n_layers * per_dec
            + self.enc_layers * per_enc
        )


# ---- Table 2 model zoo (paper-scale) plus scaled execution variants. ----
DEC_S = ModelConfig("dec_s", 50_000, 512, 24, 8)
DEC_L = ModelConfig("dec_l", 50_000, 1024, 96, 16)
ENCDEC_S = ModelConfig("encdec_s", 50_000, 512, 24, 8, enc_layers=2, knn_k=10)
ENCDEC_L = ModelConfig("encdec_l", 50_000, 1024, 96, 16, enc_layers=2, knn_k=10)
# Scaled variants: same architecture, small enough for the PJRT CPU client
# to decode at interactive rates in the rust serving path.
DEC_TINY = ModelConfig("dec_tiny", 2048, 128, 4, 4, max_seq=512, knn_k=10)
ENCDEC_TINY = ModelConfig(
    "encdec_tiny", 2048, 128, 4, 4, enc_layers=2, max_seq=512, knn_k=4
)

CONFIGS = {c.name: c for c in [DEC_S, DEC_L, ENCDEC_S, ENCDEC_L, DEC_TINY, ENCDEC_TINY]}


# --------------------------------------------------------------------------
# Parameters. Stored as a flat dict name -> array; aot.py serializes them in
# sorted-name order, which is also the flattened argument order of the AOT
# entry points (see manifest.json).
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, seed: int = 0):
    rng = jax.random.PRNGKey(seed)
    p = {}

    def dense(key, shape, scale=None):
        nonlocal rng
        rng, k = jax.random.split(rng)
        scale = scale if scale is not None else (1.0 / jnp.sqrt(shape[0]))
        p[key] = (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    dense("embed", (cfg.vocab, cfg.dim), scale=0.02)
    dense("pos", (cfg.max_seq, cfg.dim), scale=0.02)
    for i in range(cfg.n_layers):
        pre = f"dec{i:03d}"
        for nm in ["wq", "wk", "wv", "wo"]:
            dense(f"{pre}.{nm}", (cfg.dim, cfg.dim))
        dense(f"{pre}.w1", (cfg.dim, cfg.ffn_dim))
        dense(f"{pre}.w2", (cfg.ffn_dim, cfg.dim))
        if cfg.is_encdec:
            for nm in ["cq", "ck", "cv", "co"]:
                dense(f"{pre}.{nm}", (cfg.dim, cfg.dim))
    for i in range(cfg.enc_layers):
        pre = f"enc{i:03d}"
        for nm in ["wq", "wk", "wv", "wo"]:
            dense(f"{pre}.{nm}", (cfg.dim, cfg.dim))
        dense(f"{pre}.w1", (cfg.dim, cfg.ffn_dim))
        dense(f"{pre}.w2", (cfg.ffn_dim, cfg.dim))
    return p


def _rms_norm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _split_heads(x, n_heads):
    # (..., dim) -> (..., h, dh)
    return x.reshape(x.shape[:-1] + (n_heads, x.shape[-1] // n_heads))


# --------------------------------------------------------------------------
# Decode step (single sequence). The rust ChamLM worker drives this once per
# generated token via the AOT artifact; batching is vmap in aot.py.
# --------------------------------------------------------------------------
def decode_step(
    cfg: ModelConfig,
    params,
    token,  # (1,) int32 current input token
    pos,  # (1,) int32 position (== number of tokens generated so far)
    kv_cache,  # (n_layers, 2, h, T, dh) f32
    retrieved_tokens,  # (knn_k,) int32 next-tokens of neighbors (Dec only)
    retrieved_dists,  # (knn_k,) f32 neighbor distances (Dec only)
    enc_out: Optional[jnp.ndarray] = None,  # (S, dim) encoder output (EncDec)
    interpret: bool = True,
):
    """One token-generation step.

    Returns (probs (vocab,), query_vec (dim,), new_kv_cache).
    `query_vec` is the normalized last hidden state — the retrieval query
    the paper sends to ChamVS (workflow step 1 in Sec 3).
    """
    h_dim, dh = cfg.n_heads, cfg.head_dim
    t = pos[0]
    x = params["embed"][token[0]] + params["pos"][t]

    new_kv = []
    for i in range(cfg.n_layers):
        pre = f"dec{i:03d}"
        xn = _rms_norm(x)
        q = _split_heads(xn @ params[f"{pre}.wq"], h_dim)  # (h, dh)
        k = _split_heads(xn @ params[f"{pre}.wk"], h_dim)
        v = _split_heads(xn @ params[f"{pre}.wv"], h_dim)
        k_cache = jax.lax.dynamic_update_index_in_dim(
            kv_cache[i, 0].transpose(1, 0, 2), k, t, 0
        ).transpose(1, 0, 2)  # (h, T, dh)
        v_cache = jax.lax.dynamic_update_index_in_dim(
            kv_cache[i, 1].transpose(1, 0, 2), v, t, 0
        ).transpose(1, 0, 2)
        new_kv.append(jnp.stack([k_cache, v_cache]))
        o = attn_kernel.decode_attention(q, k_cache, v_cache, t + 1, interpret=interpret)
        x = x + o.reshape(-1).astype(jnp.float32) @ params[f"{pre}.wo"]
        if cfg.is_encdec and enc_out is not None:
            xn = _rms_norm(x)
            cq = _split_heads(xn @ params[f"{pre}.cq"], h_dim)
            ck = _split_heads(enc_out @ params[f"{pre}.ck"], h_dim)  # (S, h, dh)
            cv = _split_heads(enc_out @ params[f"{pre}.cv"], h_dim)
            scores = jnp.einsum("hd,shd->hs", cq, ck) / jnp.sqrt(
                jnp.asarray(dh, jnp.float32)
            )
            probs_c = jax.nn.softmax(scores, axis=-1)
            co = jnp.einsum("hs,shd->hd", probs_c, cv)
            x = x + co.reshape(-1) @ params[f"{pre}.co"]
        xn = _rms_norm(x)
        x = x + jax.nn.gelu(xn @ params[f"{pre}.w1"]) @ params[f"{pre}.w2"]

    x = _rms_norm(x)
    logits = x @ params["embed"].T  # tied output projection
    p_lm = jax.nn.softmax(logits)

    if not cfg.is_encdec:
        # kNN-LM interpolation (paper Sec 2.1, second category). Distances
        # are clipped: the rust worker pads missing neighbors with huge
        # sentinels, and exp() of their negated values must stay finite in
        # f32 under XLA's softmax rewrite.
        clipped = jnp.clip(retrieved_dists, 0.0, 1e4)
        w = jax.nn.softmax(-clipped / cfg.knn_temp)  # (knn_k,)
        p_knn = jnp.zeros((cfg.vocab,), jnp.float32).at[retrieved_tokens].add(w)
        probs = cfg.knn_lambda * p_knn + (1.0 - cfg.knn_lambda) * p_lm
    else:
        probs = p_lm

    query_vec = x  # retrieval query for the *next* step
    return probs, query_vec, jnp.stack(new_kv)


def encoder_forward(cfg: ModelConfig, params, chunk_tokens, interpret=True):
    """EncDec encoder over retrieved chunks (paper's shallow 2-layer encoder).

    chunk_tokens: (knn_k * chunk_len,) int32 concatenated retrieved chunks.
    Returns (S, dim) f32 latent knowledge representations.
    """
    del interpret  # encoder is plain jnp; it runs once per retrieval only
    s = chunk_tokens.shape[0]
    x = params["embed"][chunk_tokens] + params["pos"][:s]
    h_dim, dh = cfg.n_heads, cfg.head_dim
    for i in range(cfg.enc_layers):
        pre = f"enc{i:03d}"
        xn = _rms_norm(x)
        q = _split_heads(xn @ params[f"{pre}.wq"], h_dim)  # (s, h, dh)
        k = _split_heads(xn @ params[f"{pre}.wk"], h_dim)
        v = _split_heads(xn @ params[f"{pre}.wv"], h_dim)
        scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(
            jnp.asarray(dh, jnp.float32)
        )
        probs = jax.nn.softmax(scores, axis=-1)  # bidirectional: no mask
        o = jnp.einsum("hqk,khd->qhd", probs, v).reshape(s, -1)
        x = x + o @ params[f"{pre}.wo"]
        xn = _rms_norm(x)
        x = x + jax.nn.gelu(xn @ params[f"{pre}.w1"]) @ params[f"{pre}.w2"]
    return _rms_norm(x)


# --------------------------------------------------------------------------
# Training (end-to-end validation driver). Full causal forward + Adam.
# --------------------------------------------------------------------------
def lm_forward(cfg: ModelConfig, params, tokens):
    """Causal LM forward over (B, S) tokens -> (B, S, vocab) logits."""
    b, s = tokens.shape
    h_dim, dh = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens] + params["pos"][:s][None]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    for i in range(cfg.n_layers):
        pre = f"dec{i:03d}"
        xn = _rms_norm(x)
        q = _split_heads(xn @ params[f"{pre}.wq"], h_dim)  # (b, s, h, dh)
        k = _split_heads(xn @ params[f"{pre}.wk"], h_dim)
        v = _split_heads(xn @ params[f"{pre}.wv"], h_dim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.asarray(dh, jnp.float32)
        )
        scores = jnp.where(mask[None, None] > 0, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = x + o @ params[f"{pre}.wo"]
        xn = _rms_norm(x)
        x = x + jax.nn.gelu(xn @ params[f"{pre}.w1"]) @ params[f"{pre}.w2"]
    return _rms_norm(x) @ params["embed"].T


def lm_loss(cfg: ModelConfig, params, tokens):
    """Next-token cross-entropy over (B, S) tokens."""
    logits = lm_forward(cfg, params, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}}


def train_step(cfg: ModelConfig, params, opt_m, opt_v, step, tokens, lr=3e-4):
    """One Adam step. Flat dict params in/out so aot.py can lower it.

    Returns (loss, new_params, new_m, new_v).
    """
    loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, tokens))(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step.astype(jnp.float32) + 1.0
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        m = b1 * opt_m[k] + (1 - b1) * grads[k]
        v = b2 * opt_v[k] + (1 - b2) * grads[k] * grads[k]
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m, v
    return loss, new_p, new_m, new_v


# Convenience jitted batched decode for tests.
@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def decode_step_jit(cfg, params, token, pos, kv_cache, rt, rd, interpret=True):
    return decode_step(cfg, params, token, pos, kv_cache, rt, rd, interpret=interpret)
