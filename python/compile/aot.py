# L2 -> artifacts: lower every entry point to HLO *text* + manifest.json.
#
# HLO text (NOT lowered.compiler_ir().serialize()) is the interchange
# format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids
# which the rust side's xla_extension 0.5.1 rejects; the text parser
# reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
#
# Every artifact is lowered with return_tuple=False so the rust runtime
# gets one PJRT buffer per output and can keep state (e.g. the KV cache)
# on device between calls without host round-trips.
#
# Model parameters are NOT shipped as data: manifest.json records each
# parameter input's (name, shape, init_scale) and the rust side
# materializes them with its own deterministic RNG. Numerical correctness
# of the HLO is established by pytest against the pure-jnp oracles, with
# explicit inputs, independent of any particular parameter values.
import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import cost, model, pq
from .kernels import ivf_scan as ivf_kernel

F32, I32 = jnp.float32, jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _meta(name, shape, dtype, kind="arg", init_scale=None):
    d = {
        "name": name,
        "shape": list(shape),
        "dtype": "f32" if dtype == F32 else "i32",
        "kind": kind,
    }
    if init_scale is not None:
        d["init_scale"] = float(init_scale)
    return d


def _param_specs(cfg):
    """Flattened (sorted-name) parameter spec list + ShapeDtypeStructs."""
    params = model.init_params(cfg, seed=0)
    names = sorted(params)
    metas, specs = [], []
    for n in names:
        shape = params[n].shape
        scale = 0.02 if n in ("embed", "pos") else 1.0 / (shape[0] ** 0.5)
        metas.append(_meta(n, shape, F32, kind="param", init_scale=scale))
        specs.append(spec(shape))
    return names, metas, specs


# --------------------------------------------------------------------------
# Entry-point builders. Each returns (lowered, input_metas, output_metas,
# static) for one artifact.
# --------------------------------------------------------------------------
def build_decode(cfg, batch):
    # The manifest must list exactly the inputs surviving jax's dead-arg
    # elimination: encoder-decoder decode never touches the encoder-layer
    # params (the encoder runs in its own artifact) nor the kNN payload
    # (rt/rd), so those are excluded from the signature outright.
    names, pmetas, pspecs = _param_specs(cfg)
    if cfg.is_encdec:
        keep = [i for i, n in enumerate(names) if not n.startswith("enc")]
        names = [names[i] for i in keep]
        pmetas = [pmetas[i] for i in keep]
        pspecs = [pspecs[i] for i in keep]
    L, h, T, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim
    k = cfg.knn_k

    def fn_single(plist, token, pos, kv, *rest):
        params = dict(zip(names, plist))
        if cfg.is_encdec:
            (enc_out,) = rest
            rt = jnp.zeros((k,), I32)
            rd = jnp.full((k,), 1e4, F32)
        else:
            rt, rd = rest
            enc_out = None
        return model.decode_step(
            cfg, params, token, pos, kv, rt, rd, enc_out=enc_out, interpret=True
        )

    enc_s = cfg.knn_k * cfg.chunk_len if cfg.is_encdec else None
    if batch == 1:
        args = [
            spec((1,), I32),
            spec((1,), I32),
            spec((L, 2, h, T, dh)),
        ]
        dyn = [
            _meta("token", (1,), I32),
            _meta("pos", (1,), I32),
            _meta("kv_cache", (L, 2, h, T, dh), F32),
        ]
        fn = fn_single
    else:
        fn = jax.vmap(fn_single, in_axes=(None, 0, 0, 0, 0) + ((0,) if not cfg.is_encdec else ()))
        args = [
            spec((batch, 1), I32),
            spec((batch, 1), I32),
            spec((batch, L, 2, h, T, dh)),
        ]
        dyn = [
            _meta("token", (batch, 1), I32),
            _meta("pos", (batch, 1), I32),
            _meta("kv_cache", (batch, L, 2, h, T, dh), F32),
        ]
    if cfg.is_encdec:
        eshape = (enc_s, cfg.dim) if batch == 1 else (batch, enc_s, cfg.dim)
        args.append(spec(eshape))
        dyn.append(_meta("enc_out", eshape, F32))
    else:
        kshape = (k,) if batch == 1 else (batch, k)
        args += [spec(kshape, I32), spec(kshape)]
        dyn += [
            _meta("retrieved_tokens", kshape, I32),
            _meta("retrieved_dists", kshape, F32),
        ]

    lowered = jax.jit(fn).lower(pspecs, *args)
    b = batch if batch > 1 else None
    out_kv = (L, 2, h, T, dh) if batch == 1 else (batch, L, 2, h, T, dh)
    outs = [
        _meta("probs", (cfg.vocab,) if batch == 1 else (batch, cfg.vocab), F32),
        _meta("query_vec", (cfg.dim,) if batch == 1 else (batch, cfg.dim), F32),
        _meta("new_kv", out_kv, F32),
    ]
    static = {
        "model": cfg.name,
        "batch": batch,
        "vocab": cfg.vocab,
        "dim": cfg.dim,
        "n_layers": L,
        "n_heads": h,
        "max_seq": T,
        "knn_k": k,
        "knn_lambda": cfg.knn_lambda,
        "knn_temp": cfg.knn_temp,
        "is_encdec": cfg.is_encdec,
        "chunk_len": cfg.chunk_len,
        "cost": cost.decode_step_cost(cfg),
    }
    return lowered, pmetas + dyn, outs, static


def build_encode(cfg):
    # Only the encoder-side parameters: jax DCEs unused arguments out of
    # the lowered HLO signature, so the manifest must list exactly the
    # parameters the encoder touches (embed, pos, enc*), or the rust
    # executor's buffer count will not match the compiled program.
    names, pmetas, pspecs = _param_specs(cfg)
    keep = [
        i
        for i, n in enumerate(names)
        if n in ("embed", "pos") or n.startswith("enc")
    ]
    names = [names[i] for i in keep]
    pmetas = [pmetas[i] for i in keep]
    pspecs = [pspecs[i] for i in keep]
    s = cfg.knn_k * cfg.chunk_len

    def fn(plist, chunk_tokens):
        params = dict(zip(names, plist))
        return (model.encoder_forward(cfg, params, chunk_tokens),)

    lowered = jax.jit(fn).lower(pspecs, spec((s,), I32))
    dyn = [_meta("chunk_tokens", (s,), I32)]
    outs = [_meta("enc_out", (s, cfg.dim), F32)]
    return lowered, pmetas + dyn, outs, {"model": cfg.name, "enc_seq": s}


def build_train(cfg, batch, seq):
    names, pmetas, pspecs = _param_specs(cfg)

    def fn(plist, mlist, vlist, step, tokens):
        params = dict(zip(names, plist))
        m = dict(zip(names, mlist))
        v = dict(zip(names, vlist))
        loss, np_, nm, nv = model.train_step(cfg, params, m, v, step, tokens)
        return (loss, *[np_[n] for n in names], *[nm[n] for n in names],
                *[nv[n] for n in names])

    lowered = jax.jit(fn).lower(
        pspecs, pspecs, pspecs, spec((), I32), spec((batch, seq), I32)
    )
    mmetas = [dict(m, name="adam_m." + m["name"], init_scale=0.0) for m in pmetas]
    vmetas = [dict(m, name="adam_v." + m["name"], init_scale=0.0) for m in pmetas]
    dyn = [_meta("step", (), I32), _meta("tokens", (batch, seq), I32)]
    outs = [_meta("loss", (), F32)]
    outs += [_meta("new." + m["name"], m["shape"], F32) for m in pmetas]
    outs += [_meta("new_m." + m["name"], m["shape"], F32) for m in pmetas]
    outs += [_meta("new_v." + m["name"], m["shape"], F32) for m in pmetas]
    static = {
        "model": cfg.name,
        "batch": batch,
        "seq": seq,
        "n_params": cfg.param_count(),
    }
    return lowered, pmetas + mmetas + vmetas + dyn, outs, static


def build_chamvs_scan(name, m, dsub, n_codes, k, num_lanes):
    fn = lambda q, cb, codes, nv: pq.chamvs_scan(
        q, cb, codes, nv, k=k, num_lanes=num_lanes, interpret=True
    )
    lowered = jax.jit(fn).lower(
        spec((m, dsub)), spec((m, 256, dsub)), spec((n_codes, m), I32),
        spec((1,), I32),
    )
    ins = [
        _meta("query", (m, dsub), F32),
        _meta("codebook", (m, 256, dsub), F32),
        _meta("codes", (n_codes, m), I32),
        _meta("n_valid", (1,), I32),
    ]
    outs = [_meta("topk_dists", (k,), F32), _meta("topk_idxs", (k,), I32)]
    static = {
        "m": m, "dsub": dsub, "n_codes": n_codes, "k": k,
        "num_lanes": num_lanes,
        "cost": cost.adc_scan_cost(n_codes, m),
        "lut_cost": cost.lut_cost(m, dsub),
    }
    return lowered, ins, outs, static


def build_ivf_scan(d, nlist, batch, nprobe):
    fn = lambda q, c: ivf_kernel.ivf_scan(q, c, nprobe, interpret=True)
    lowered = jax.jit(fn).lower(spec((batch, d)), spec((nlist, d)))
    ins = [_meta("queries", (batch, d), F32), _meta("centroids", (nlist, d), F32)]
    outs = [
        _meta("dists", (batch, nprobe), F32),
        _meta("list_ids", (batch, nprobe), I32),
    ]
    static = {
        "d": d, "nlist": nlist, "batch": batch, "nprobe": nprobe,
        "cost": cost.ivf_scan_cost(batch, nlist, d),
    }
    return lowered, ins, outs, static


# --------------------------------------------------------------------------
# Artifact registry: everything `make artifacts` produces.
# --------------------------------------------------------------------------
def registry(full=False):
    arts = {}
    # ChamLM decode steps (tiny models run in every example/bench; dec_s is
    # the ~100M-param end-to-end validation model).
    arts["decode_dec_tiny_b1"] = lambda: build_decode(model.DEC_TINY, 1)
    arts["decode_dec_tiny_b8"] = lambda: build_decode(model.DEC_TINY, 8)
    arts["decode_encdec_tiny_b1"] = lambda: build_decode(model.ENCDEC_TINY, 1)
    arts["encode_encdec_tiny"] = lambda: build_encode(model.ENCDEC_TINY)
    arts["train_dec_tiny"] = lambda: build_train(model.DEC_TINY, 8, 64)
    # ChamVS near-memory scan, one per PQ width of Table 3.
    arts["chamvs_scan_m16"] = lambda: build_chamvs_scan("m16", 16, 8, 32768, 100, 16)
    arts["chamvs_scan_m32"] = lambda: build_chamvs_scan("m32", 32, 16, 32768, 100, 16)
    arts["chamvs_scan_m64"] = lambda: build_chamvs_scan("m64", 64, 16, 16384, 100, 16)
    # ChamVS.idx index scans (scaled nlist=1024; D of Table 3 datasets).
    for d in (128, 512, 1024):
        for b in (1, 16):
            arts[f"ivf_scan_d{d}_b{b}"] = (
                lambda d=d, b=b: build_ivf_scan(d, 1024, b, 32)
            )
    if full:
        # Paper-scale models: heavy to lower/compile; built on demand.
        arts["decode_dec_s_b1"] = lambda: build_decode(model.DEC_S, 1)
        arts["train_dec_s"] = lambda: build_train(model.DEC_S, 2, 64)
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    ap.add_argument("--full", action="store_true",
                    help="also build paper-scale dec_s artifacts")
    # Back-compat with the original scaffold Makefile:
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"artifacts": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    arts = registry(full=args.full)
    only = set(args.only.split(",")) if args.only else None
    for name, build in arts.items():
        if only and name not in only:
            continue
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        if (
            not only
            and os.path.exists(path)
            and name in manifest["artifacts"]
        ):
            print(f"[aot] {name}: up to date")
            continue
        print(f"[aot] lowering {name} ...", flush=True)
        lowered, ins, outs, static = build()
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": ins,
            "outputs": outs,
            "static": static,
        }
        print(f"[aot] {name}: {len(text)} chars, {len(ins)} inputs, "
              f"{len(outs)} outputs")
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"[aot] manifest -> {manifest_path}")


if __name__ == "__main__":
    main()
