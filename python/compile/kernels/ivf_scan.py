# L1 kernel: IVF centroid distance scan (ChamVS.idx, paper Sec 3).
#
# The paper runs this on the GPU colocated with the LLM: every query is
# compared against all nlist centroids and the nprobe closest lists are
# probed. On TPU the distance part is one MXU matmul via the
# ||x||^2 - 2 x.c + ||c||^2 expansion; BlockSpec tiles the nlist axis so a
# (B, C_TILE) score tile plus the (C_TILE, d) centroid tile stay in VMEM.
# Selection (top-nprobe) happens outside the kernel in the L2 graph.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C_TILE = 1024  # centroids per grid step


def _ivf_dist_kernel(q_ref, c_ref, out_ref):
    # q_ref: (b, d), c_ref: (C_TILE, d), out_ref: (b, C_TILE)
    q = q_ref[...]
    c = c_ref[...]
    q2 = jnp.sum(q * q, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    qc = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] = q2 - 2.0 * qc + c2


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_dists(queries, centroids, interpret=True):
    """Squared L2 distances (b, nlist) via a tiled Pallas matmul kernel."""
    b, d = queries.shape
    nlist = centroids.shape[0]
    assert centroids.shape == (nlist, d)
    tile = min(C_TILE, nlist)
    assert nlist % tile == 0, (nlist, tile)
    return pl.pallas_call(
        _ivf_dist_kernel,
        grid=(nlist // tile,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, nlist), jnp.float32),
        interpret=interpret,
    )(queries, centroids)


@functools.partial(jax.jit, static_argnames=("nprobe", "interpret"))
def ivf_scan(queries, centroids, nprobe, interpret=True):
    """Top-nprobe closest IVF lists per query: (b, nprobe) dists + ids.

    Selection is argsort-based rather than jax.lax.top_k — the latter's
    HLO (`topk` instruction) cannot be parsed by the rust runtime's
    xla_extension 0.5.1 (see kernels.topk.topk_smallest).
    """
    d = ivf_dists(queries, centroids, interpret=interpret)
    idxs = jnp.argsort(d, axis=1)[:, :nprobe].astype(jnp.int32)
    return jnp.take_along_axis(d, idxs, axis=1), idxs
