# L1 kernel: approximate hierarchical top-K (paper Sec 4.2.2).
#
# The FPGA pairs every PQ decoding unit with two truncated systolic L1
# priority queues and merges them through one exact L2 queue. The
# approximation contract -- each lane keeps only `lane_depth` << K
# candidates, sized so <1% of queries lose a true neighbor -- carries over
# unchanged. On TPU the "lanes" become the sublane axis of a (num_lanes,
# n/num_lanes) tile, the truncated L1 queue is a lane-local top-`lane_depth`
# (iterative masked min-extraction, vectorized across lanes), and the L2
# merge is an exact top-K over the num_lanes*lane_depth survivors. The
# resource-vs-exactness trade of Fig 8 shows up here as work: selection cost
# scales with lane_depth, not K*num_lanes.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def topk_smallest(x, k):
    """Sort-based smallest-k selection: (vals ascending, int32 idxs).

    Deliberately avoids jax.lax.top_k: its HLO lowering emits the newer
    `topk(..., largest=true)` instruction which the rust side's
    xla_extension 0.5.1 text parser rejects; `sort` round-trips fine.
    """
    idx = jnp.argsort(x)[:k].astype(jnp.int32)
    return x[idx], idx


def _lane_topk_kernel(dists_ref, vals_ref, idxs_ref, *, num_lanes, lane_depth):
    # dists_ref: (n,). Outputs: (num_lanes, lane_depth) vals + original idxs.
    n = dists_ref.shape[0]
    per = n // num_lanes
    x = dists_ref[...]
    # Round-robin deal, matching one distance per decoding unit per cycle.
    lanes = x.reshape(per, num_lanes).T  # (num_lanes, per)
    lane_idx = (
        jnp.arange(per, dtype=jnp.int32)[None, :] * num_lanes
        + jnp.arange(num_lanes, dtype=jnp.int32)[:, None]
    )

    def body(i, carry):
        cur, vals, idxs = carry
        j = jnp.argmin(cur, axis=1)  # (num_lanes,) lane-local minima
        v = jnp.take_along_axis(cur, j[:, None], axis=1)[:, 0]
        gi = jnp.take_along_axis(lane_idx, j[:, None], axis=1)[:, 0]
        vals = vals.at[:, i].set(v)
        idxs = idxs.at[:, i].set(gi)
        cur = cur.at[jnp.arange(num_lanes), j].set(jnp.inf)
        return cur, vals, idxs

    vals0 = jnp.full((num_lanes, lane_depth), jnp.inf, jnp.float32)
    idxs0 = jnp.zeros((num_lanes, lane_depth), jnp.int32)
    _, vals, idxs = jax.lax.fori_loop(0, lane_depth, body, (lanes, vals0, idxs0))
    vals_ref[...] = vals
    idxs_ref[...] = idxs


@functools.partial(
    jax.jit, static_argnames=("k", "num_lanes", "lane_depth", "interpret")
)
def approx_hier_topk(dists, k, num_lanes=16, lane_depth=None, interpret=True):
    """Approximate hierarchical top-K.

    dists: (n,) f32 with n % num_lanes == 0.
    Returns (vals, idxs) of the ~K smallest, ascending. Identical to exact
    top-K unless one lane holds more than lane_depth of the true top-K.
    """
    if lane_depth is None:
        lane_depth = default_lane_depth(k, num_lanes)
    n = dists.shape[0]
    assert n % num_lanes == 0, (n, num_lanes)
    kern = functools.partial(
        _lane_topk_kernel, num_lanes=num_lanes, lane_depth=lane_depth
    )
    vals, idxs = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((num_lanes, lane_depth), jnp.float32),
            jax.ShapeDtypeStruct((num_lanes, lane_depth), jnp.int32),
        ),
        interpret=interpret,
    )(dists)
    # L2 queue: exact merge of the lane survivors.
    merged_vals, sel = topk_smallest(vals.reshape(-1), k)
    return merged_vals, idxs.reshape(-1)[sel]


def default_lane_depth(k, num_lanes):
    """Binomial truncation bound of paper Sec 4.2.2.

    Smallest depth d such that P[Binom(k, 1/num_lanes) > d] <= 1e-2 / num_lanes
    (union bound over lanes => >= 99% of queries exactly match the exact
    module). Mirrors rust `kselect::binomial::required_depth`.
    """
    import math

    p = 1.0 / num_lanes
    target = 1e-2 / num_lanes
    cum = 0.0
    for d in range(k + 1):
        cum += math.comb(k, d) * p**d * (1 - p) ** (k - d)
        if 1.0 - cum <= target:
            return max(d, 1)
    return k
