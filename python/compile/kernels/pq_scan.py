# L1 kernel: ADC scan over PQ codes (paper Sec 4.1, "PQ decoding units").
#
# Hardware adaptation (DESIGN.md Sec 3): the FPGA streams m-byte PQ codes
# from DRAM and performs m parallel BRAM lookups + an adder tree, one
# database vector per clock. A TPU has no per-byte scatter BRAM, so the
# same algebra is re-cast for the MXU: expand each code byte to a one-hot
# row and contract against the LUT,
#
#     dist[n] = sum_i onehot(code[n, i]) . lut[i, :]
#
# which is a (N_TILE*m, 256) x (256,) style contraction the systolic array
# executes at full utilization in bf16/f32. BlockSpec tiles N so the
# one-hot expansion never materializes in HBM: each grid step stages one
# (N_TILE, m) code tile into VMEM, expands, contracts, and writes N_TILE
# distances -- the double-buffered HBM->VMEM stream standing in for the
# paper's AXI bursts.
#
# A gather variant (`adc_scan_gather`) keeps the FPGA's lookup structure
# verbatim; it is the ablation baseline (DESIGN.md Sec 7) and loses on TPU
# because per-element gathers serialize on the VPU.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Database vectors per grid step. The one-hot expansion is the VMEM
# pressure point (tile*m*256*4B), so the tile shrinks as m grows:
# 8192/m keeps the expansion at ~8 MiB — half of VMEM, leaving room for
# the double-buffered input stream.
def n_tile(m):
    return max(128, 8192 // m)


def _adc_onehot_kernel(codes_ref, lut_ref, out_ref):
    # codes_ref: (N_TILE, m) int32, lut_ref: (m, 256), out_ref: (N_TILE,)
    codes = codes_ref[...]
    lut_tbl = lut_ref[...]
    # One-hot on the 256-wide lane axis; contraction feeds the MXU.
    onehot = (codes[:, :, None] == jnp.arange(256, dtype=jnp.int32)).astype(
        lut_tbl.dtype
    )  # (N_TILE, m, 256)
    dists = jax.lax.dot_general(
        onehot.reshape(codes.shape[0], -1),
        lut_tbl.reshape(-1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = dists


def _adc_gather_kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]
    lut_tbl = lut_ref[...]
    gathered = jnp.take_along_axis(lut_tbl[None, :, :], codes[:, :, None], axis=2)
    out_ref[...] = jnp.sum(gathered[:, :, 0], axis=1).astype(jnp.float32)


def _scan(kernel, codes, lut_tbl, interpret):
    n, m = codes.shape
    assert lut_tbl.shape == (m, 256), lut_tbl.shape
    tile = min(n_tile(m), n)
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((m, 256), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut_tbl)


@functools.partial(jax.jit, static_argnames=("interpret",))
def adc_scan(codes, lut_tbl, interpret=True):
    """One-hot-MXU ADC scan. codes (n, m) int32, lut (m, 256) -> (n,) f32."""
    return _scan(_adc_onehot_kernel, codes, lut_tbl, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def adc_scan_gather(codes, lut_tbl, interpret=True):
    """Gather-based ADC scan (ablation baseline; FPGA-verbatim structure)."""
    return _scan(_adc_gather_kernel, codes, lut_tbl, interpret)
