# L1 kernel: distance lookup table construction (paper Sec 4, "distance
# lookup table construction unit").
#
# The FPGA builds an (m x 256) table of squared L2 distances between each
# sub-query vector and the 256 PQ centroids of that sub-space, then streams
# it into the PQ decoding units' BRAM. On TPU the analogous move is one
# fused broadcast-subtract-square-reduce over a (m, 256, dsub) tile held in
# VMEM -- pure VPU work, no MXU needed; the table then stays resident for
# the whole IVF-list scan exactly like the BRAM copy does.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sub-space tile: how many of the m sub-spaces one program instance handles.
# m is 16/32/64 in the paper's datasets; 8 divides all of them and keeps the
# per-tile VMEM footprint at 8*256*dsub*4B (<= 128 KiB for dsub <= 16).
M_TILE = 8


def _lut_kernel(q_ref, cb_ref, out_ref):
    # q_ref:  (M_TILE, dsub), cb_ref: (M_TILE, 256, dsub)
    # out_ref: (M_TILE, 256)
    q = q_ref[...]
    cb = cb_ref[...]
    diff = q[:, None, :] - cb
    out_ref[...] = jnp.sum(diff * diff, axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lut(query, codebook, interpret=True):
    """Build the PQ distance lookup table with a Pallas kernel.

    query:    (m, dsub) f32
    codebook: (m, 256, dsub) f32
    returns:  (m, 256) f32
    """
    m, dsub = query.shape
    assert codebook.shape == (m, 256, dsub), codebook.shape
    tile = min(M_TILE, m)
    assert m % tile == 0, (m, tile)
    grid = (m // tile,)
    return pl.pallas_call(
        _lut_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, dsub), lambda i: (i, 0)),
            pl.BlockSpec((tile, 256, dsub), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 256), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 256), jnp.float32),
        interpret=interpret,
    )(query, codebook)


def batched_lut(queries, codebook, interpret=True):
    """(b, m, dsub) -> (b, m, 256); vmapped over the batch of queries."""
    return jax.vmap(lambda q: lut(q, codebook, interpret=interpret))(queries)
