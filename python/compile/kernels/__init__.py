"""Layer-1 Pallas kernels for the Chameleon reproduction.

Every kernel is authored with ``interpret=True`` so it lowers to plain HLO
ops executable on the PJRT CPU client (the rust runtime). Real-TPU
performance is estimated analytically from the BlockSpec tiling; see
DESIGN.md Sec 8 and ``python/compile/cost.py``.

Kernels (paper mapping):
  pq_lut    - distance lookup-table construction   (Sec 4, LUT unit)
  pq_scan   - ADC scan over PQ codes, one-hot-MXU  (Sec 4.1, decoding units)
  topk      - approximate hierarchical top-K       (Sec 4.2.2)
  ivf_scan  - IVF centroid distance scan           (Sec 3, ChamVS.idx)
  attention - decode-step attention over KV cache  (Sec 3, ChamLM)
"""

from . import attention, ivf_scan, pq_lut, pq_scan, ref, topk  # noqa: F401
