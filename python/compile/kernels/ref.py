# Pure-jnp correctness oracles for every L1 Pallas kernel.
#
# These are the CORE correctness signal: pytest (python/tests/) sweeps
# shapes/dtypes with hypothesis and asserts the Pallas kernels match these
# references to tight tolerances. The rust side then trusts the AOT HLO.
import jax
import jax.numpy as jnp


def lut_ref(query, codebook):
    """Distance lookup table: d(x_i, c_i_j) for every sub-space i, centroid j.

    query:    (m, dsub)       sub-query vectors
    codebook: (m, 256, dsub)  PQ centroids per sub-space
    returns:  (m, 256) f32    squared L2 per (sub-space, centroid)
    """
    diff = query[:, None, :] - codebook  # (m, 256, dsub)
    return jnp.sum(diff * diff, axis=-1).astype(jnp.float32)


def batched_lut_ref(queries, codebook):
    """(b, m, dsub), (m, 256, dsub) -> (b, m, 256)."""
    return jax.vmap(lambda q: lut_ref(q, codebook))(queries)


def adc_scan_ref(codes, lut):
    """Asymmetric distance computation over PQ codes.

    codes: (n, m) int32 in [0, 256)   quantized database vectors
    lut:   (m, 256) f32               distance lookup table
    returns: (n,) f32                 approximate squared L2 distances
    """
    gathered = jnp.take_along_axis(
        lut[None, :, :], codes[:, :, None], axis=2
    )  # (n, m, 1)
    return jnp.sum(gathered[:, :, 0], axis=1).astype(jnp.float32)


def topk_ref(dists, k):
    """Exact top-K smallest distances. returns (vals, idxs), ascending."""
    neg_vals, idxs = jax.lax.top_k(-dists, k)
    return -neg_vals, idxs


def approx_hier_topk_ref(dists, k, num_lanes, lane_depth):
    """Reference for the *approximate hierarchical* top-K of paper Sec 4.2.2.

    Distances are dealt round-robin to `num_lanes` lanes (mirroring one
    systolic L1 queue per PQ decoding unit), each lane keeps only its
    `lane_depth` smallest (the truncated L1 queue), and a final exact top-K
    (the L2 queue) merges the survivors. Output is only approximate when a
    single lane holds more than `lane_depth` of the true top-K -- the paper
    sizes lane_depth so that happens for <1% of queries.

    dists: (n,) with n % num_lanes == 0. Returns (vals, idxs) ascending.
    """
    n = dists.shape[0]
    per = n // num_lanes
    # Round-robin deal: lane l gets elements l, l+num_lanes, l+2*num_lanes...
    lanes = dists.reshape(per, num_lanes).T  # (num_lanes, per)
    lane_idx = (
        jnp.arange(per)[None, :] * num_lanes + jnp.arange(num_lanes)[:, None]
    )  # original index of lanes[l, j]
    neg_vals, pos = jax.lax.top_k(-lanes, lane_depth)  # (num_lanes, lane_depth)
    cand_vals = -neg_vals
    cand_idx = jnp.take_along_axis(lane_idx, pos, axis=1)
    flat_vals = cand_vals.reshape(-1)
    flat_idx = cand_idx.reshape(-1)
    neg_out, sel = jax.lax.top_k(-flat_vals, k)
    return -neg_out, flat_idx[sel]


def ivf_dists_ref(queries, centroids):
    """Squared L2 between each query and every IVF centroid.

    queries: (b, d), centroids: (nlist, d) -> (b, nlist) f32
    """
    q2 = jnp.sum(queries * queries, axis=1, keepdims=True)  # (b, 1)
    c2 = jnp.sum(centroids * centroids, axis=1)[None, :]  # (1, nlist)
    qc = queries @ centroids.T  # (b, nlist)
    return (q2 - 2.0 * qc + c2).astype(jnp.float32)


def ivf_scan_ref(queries, centroids, nprobe):
    """Top-nprobe closest centroids per query: (b, nprobe) dists + ids."""
    d = ivf_dists_ref(queries, centroids)
    neg_vals, idxs = jax.lax.top_k(-d, nprobe)
    return -neg_vals, idxs


def attention_ref(q, k_cache, v_cache, t):
    """Single-step decode attention with a causal length mask.

    q:       (h, dh)      current step's query per head
    k_cache: (h, T, dh)   key cache (first t entries valid)
    v_cache: (h, T, dh)
    t:       scalar int   number of valid cache entries (>= 1)
    returns: (h, dh) f32
    """
    dh = q.shape[-1]
    scores = jnp.einsum("hd,htd->ht", q, k_cache) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    mask = jnp.arange(k_cache.shape[1])[None, :] < t
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("ht,htd->hd", probs, v_cache.astype(jnp.float32))
