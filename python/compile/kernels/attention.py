# L1 kernel: single-step decode attention over a KV cache (ChamLM).
#
# Flash-style online-softmax accumulation: the KV cache is tiled along the
# time axis; each grid step rescales a running (max, denominator, output)
# triple held in VMEM scratch. This is the TPU shape of the paper's GPU
# decode hot loop -- the (h, T_TILE, dh) K/V tiles stream HBM->VMEM while
# the softmax state never leaves VMEM.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T_TILE = 128  # cache positions per grid step


def _decode_attn_kernel(q_ref, k_ref, v_ref, t_ref, o_ref, m_ref, l_ref, acc_ref):
    # q_ref: (h, dh); k_ref/v_ref: (h, T_TILE, dh); t_ref: (1,) valid length.
    # Scratch: m_ref (h,), l_ref (h,), acc_ref (h, dh) persist across steps.
    step = pl.program_id(0)
    h, dh = q_ref.shape

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full((h,), -jnp.inf, jnp.float32)
        l_ref[...] = jnp.zeros((h,), jnp.float32)
        acc_ref[...] = jnp.zeros((h, dh), jnp.float32)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    t = t_ref[0]

    scores = jnp.einsum("hd,htd->ht", q, k) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)
    )  # (h, T_TILE)
    pos = step * T_TILE + jnp.arange(T_TILE, dtype=jnp.int32)
    scores = jnp.where(pos[None, :] < t, scores, -jnp.inf)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=1))
    # exp(-inf - -inf) guards: where m_cur is -inf the whole tile is masked.
    safe_m = jnp.where(jnp.isfinite(m_cur), m_cur, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    p = jnp.exp(scores - safe_m[:, None])
    p = jnp.where(jnp.isfinite(scores), p, 0.0)
    l_cur = alpha * l_prev + jnp.sum(p, axis=1)
    acc_cur = alpha[:, None] * acc_prev + jnp.einsum("ht,htd->hd", p, v)
    m_ref[...], l_ref[...], acc_ref[...] = m_cur, l_cur, acc_cur

    @pl.when(step == pl.num_programs(0) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / l_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, t, interpret=True):
    """Single-step decode attention.

    q (h, dh); k_cache/v_cache (h, T, dh); t scalar int32 valid length.
    Returns (h, dh) f32. T must be a multiple of T_TILE (or <= T_TILE).
    """
    h, dh = q.shape
    T = k_cache.shape[1]
    tile = min(T_TILE, T)
    assert T % tile == 0, (T, tile)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)
    grid = (T // tile,)
    return pl.pallas_call(
        functools.partial(_decode_attn_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, dh), lambda i: (0, 0)),
            pl.BlockSpec((h, tile, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((h, tile, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((h, dh), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, t_arr)
