# Analytic cost model for the L1/L2 artifacts (DESIGN.md Sec 8).
#
# interpret=True gives CPU-numpy timings only, so TPU efficiency is
# *estimated* from first principles here: VMEM footprint per tile, HBM
# traffic, MXU/VPU FLOPs and the resulting arithmetic intensity. The same
# numbers are emitted into artifacts/manifest.json so the rust hwmodel can
# cross-check its FPGA/GPU models against the TPU mapping.

MXU_FLOPS = 2 * 128 * 128  # MACs/cycle on one MXU pass, f32 systolic
VMEM_BYTES = 16 * 2**20  # ~16 MiB usable VMEM per core
HBM_BW = 1.2e12  # bytes/s (TPU v4-ish)
PEAK_BF16 = 275e12  # FLOP/s


def lut_cost(m, dsub):
    """LUT build: (m, 256, dsub) broadcast-sub-square-reduce (VPU)."""
    flops = 3 * m * 256 * dsub  # sub, mul, add-reduce
    vmem = 4 * (m * dsub + m * 256 * dsub + m * 256)
    return {"flops": flops, "vmem_bytes": vmem, "unit": "vpu"}


def adc_scan_cost(n, m, n_tile=None):
    """One-hot-MXU ADC: contraction (n_tile, m*256) x (m*256,) per tile."""
    if n_tile is None:
        n_tile = max(128, 8192 // m)  # mirrors kernels.pq_scan.n_tile
    flops = 2 * n * m * 256  # the one-hot contraction as dense MACs
    useful_flops = 2 * n * m  # lookups+adds actually needed
    hbm = n * m * 4  # int32 codes streamed (bf16 LUT stays resident)
    vmem_tile = 4 * (n_tile * m + n_tile * m * 256 + m * 256 + n_tile)
    return {
        "flops": flops,
        "useful_flops": useful_flops,
        "hbm_bytes": hbm,
        "vmem_bytes_per_tile": vmem_tile,
        "mxu_utilization_est": round(useful_flops / flops, 6),
        "arithmetic_intensity": flops / hbm,
        "unit": "mxu",
    }


def ivf_scan_cost(b, nlist, d, c_tile=1024):
    flops = 2 * b * nlist * d
    hbm = 4 * (nlist * d + b * d + b * nlist)
    vmem_tile = 4 * (b * d + c_tile * d + b * c_tile)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "vmem_bytes_per_tile": vmem_tile,
        "arithmetic_intensity": flops / hbm,
        "unit": "mxu",
    }


def decode_step_cost(cfg):
    """Per-token FLOPs/bytes for one decode step of a ModelConfig."""
    d, l, v = cfg.dim, cfg.n_layers, cfg.vocab
    ffn = cfg.ffn_dim
    attn_proj = 4 * d * d
    cross = 4 * d * d if cfg.is_encdec else 0
    per_layer = 2 * (attn_proj + cross + 2 * d * ffn)
    flops = l * per_layer + 2 * v * d
    param_bytes = 4 * cfg.param_count()
    kv_bytes = 4 * l * 2 * d * cfg.max_seq
    return {
        "flops": flops,
        "param_bytes": param_bytes,
        "kv_bytes": kv_bytes,
        # decode is bandwidth-bound: every param read once per token
        "arithmetic_intensity": flops / max(param_bytes, 1),
        "unit": "mxu",
    }


def estimate_tpu_latency_s(cost):
    """Roofline latency: max(compute, memory) given the cost dict."""
    t_compute = cost.get("flops", 0) / PEAK_BF16
    t_mem = cost.get("hbm_bytes", cost.get("param_bytes", 0)) / HBM_BW
    return max(t_compute, t_mem)
