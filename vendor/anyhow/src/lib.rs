//! Offline vendored substrate for `anyhow` — the API subset this
//! repository uses, implemented from scratch (the build has no crates.io
//! access, mirroring the other from-scratch substrates in `util/`).
//!
//! Supported surface:
//! * [`Error`]: type-erased error with a context chain; `Display` shows the
//!   outermost message, `{:#}` the full `a: b: c` chain, `Debug` the chain
//!   over multiple lines (what `Result`-returning `main` prints).
//! * [`Result<T>`] alias with `E = Error`.
//! * Blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts concrete errors ([`Error`] itself intentionally does *not*
//!   implement `std::error::Error`, exactly like the real crate).
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`Error::downcast_ref`] walking the context chain.
//! * The `anyhow!`, `bail!` and `ensure!` macros.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with a type-erased error, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    /// A concrete boxed error.
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
    /// An ad-hoc message (from `anyhow!`/`bail!`/`ensure!`).
    Msg(String),
    /// A context layer wrapped around a cause.
    Context { msg: String, source: Box<Error> },
}

/// A type-erased error with context.
pub struct Error {
    repr: Repr,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { repr: Repr::Msg(msg.to_string()) }
    }

    /// Build an error from a concrete `std::error::Error`.
    pub fn new<E>(err: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { repr: Repr::Boxed(Box::new(err)) }
    }

    /// Wrap this error in a context message.
    pub fn context<C: fmt::Display>(self, msg: C) -> Error {
        Error { repr: Repr::Context { msg: msg.to_string(), source: Box::new(self) } }
    }

    /// Downcast against the concrete errors anywhere in the chain.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        match &self.repr {
            Repr::Boxed(e) => e.downcast_ref::<T>(),
            Repr::Msg(_) => None,
            Repr::Context { source, .. } => source.downcast_ref::<T>(),
        }
    }

    /// The outermost message of the chain.
    fn head(&self) -> String {
        match &self.repr {
            Repr::Boxed(e) => e.to_string(),
            Repr::Msg(m) => m.clone(),
            Repr::Context { msg, .. } => msg.clone(),
        }
    }

    /// The error one level beneath this one, if any.
    fn source_err(&self) -> Option<&Error> {
        match &self.repr {
            Repr::Context { source, .. } => Some(source),
            _ => None,
        }
    }

    /// Messages from outermost to root cause.
    fn chain_msgs(&self) -> Vec<String> {
        let mut out = vec![self.head()];
        let mut cur = self.source_err();
        while let Some(e) = cur {
            out.push(e.head());
            cur = e.source_err();
        }
        // Also surface the std source chain of the innermost boxed error.
        if let Some(last) = self.innermost_boxed() {
            let mut src = last.source();
            while let Some(s) = src {
                out.push(s.to_string());
                src = s.source();
            }
        }
        out
    }

    fn innermost_boxed(&self) -> Option<&(dyn StdError + 'static)> {
        match &self.repr {
            Repr::Boxed(e) => Some(e.as_ref()),
            Repr::Msg(_) => None,
            Repr::Context { source, .. } => source.innermost_boxed(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, as the real crate does.
            write!(f, "{}", self.chain_msgs().join(": "))
        } else {
            write!(f, "{}", self.head())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_msgs();
        write!(f, "{}", msgs[0])?;
        if msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// Attach context to errors (and to `None`).
pub trait Context<T, E> {
    /// Wrap the error with a message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-evaluated message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an ad-hoc [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an ad-hoc error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::TimedOut, "slow")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn downcast_through_context() {
        fn inner() -> Result<()> {
            Err(io_err()).context("outer")
        }
        let e = inner().unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("downcast");
        assert_eq!(io.kind(), std::io::ErrorKind::TimedOut);
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e = Error::new(io_err()).context("reading frame").context("serving");
        let s = format!("{e:#}");
        assert!(s.contains("serving"), "{s}");
        assert!(s.contains("reading frame"), "{s}");
        assert!(s.contains("slow"), "{s}");
        assert_eq!(format!("{e}"), "serving");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let w: Option<u32> = Some(7);
        assert_eq!(w.with_context(|| "never").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(inner(5).unwrap(), 5);
        assert_eq!(format!("{}", inner(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", inner(3).unwrap_err()), "unlucky 3");
        let e = anyhow!("ad hoc {}", 1);
        assert_eq!(format!("{e}"), "ad hoc 1");
    }
}
