//! Offline stub of the `xla_extension` bindings used by `runtime/`.
//!
//! The container this repository builds in has no XLA/PJRT shared library,
//! so this crate keeps the *host-side* surface fully functional
//! ([`Literal`] construction, reshape, readback — enough for the tensor
//! round-trip unit tests) while the *device-side* entry point
//! ([`PjRtClient::cpu`]) reports `PJRT unavailable`. Everything that needs
//! a live accelerator (integration tests, `chameleon demo/serve`, the
//! measured bench sections) detects that error and skips; the modeled
//! paper-scale reports and the native scan engines are unaffected.
//!
//! Swap this path dependency for the real `xla` crate (see
//! /opt/xla-example in the original environment) to execute the AOT
//! artifacts for real — the API is a strict subset of that crate.

use std::fmt;

/// Stub error type.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (offline xla stub; link the real xla_extension to execute artifacts)"
    )))
}

/// Element types surfaced by artifact outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
}

/// Array shape of a non-tuple literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side XLA literal (dense array or tuple).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

/// Sealed-ish conversion trait for the element types `Literal::vec1` and
/// `Literal::to_vec` support.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralDataWrapper;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

/// Opaque constructor payload (keeps `LiteralData` private).
pub struct LiteralDataWrapper(LiteralData);

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralDataWrapper {
        LiteralDataWrapper(LiteralData::F32(data))
    }
    fn unwrap(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralDataWrapper {
        LiteralDataWrapper(LiteralData::I32(data))
    }
    fn unwrap(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            LiteralData::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// A rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let LiteralDataWrapper(inner) = T::wrap(data.to_vec());
        Literal { dims: vec![data.len() as i64], data: inner }
    }

    /// Reshape to new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!("reshape {have} elements to {dims:?}")));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(_) => 0,
        }
    }

    /// Shape of a dense (non-tuple) literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
            LiteralData::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy the elements out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.data {
            LiteralData::Tuple(parts) => Ok(std::mem::take(parts)),
            _ => Err(Error("not a tuple literal".into())),
        }
    }

    /// Build a tuple literal (used by tests of the stub itself).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: LiteralData::Tuple(parts) }
    }
}

/// Parsed HLO module handle (stub: parsing requires the real bindings).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation handle built from a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        // Unreachable without a client, but kept functional for symmetry.
        Ok(PjRtBuffer { literal: lit.clone() })
    }
}

/// Compiled executable handle (stub: execution always fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Device buffer handle (stub: holds the host literal).
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn tuple_decomposes() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).decompose_tuple().is_err());
    }

    #[test]
    fn pjrt_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
