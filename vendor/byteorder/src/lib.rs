//! Offline vendored substrate for `byteorder` — the API subset this
//! repository uses (little-endian framed I/O in `net/protocol` and
//! `ivf/persist`), implemented on std only.

use std::io::{self, Read, Write};

/// Byte-order abstraction over fixed-width encode/decode.
pub trait ByteOrder {
    fn read_u16(buf: &[u8; 2]) -> u16;
    fn read_u32(buf: &[u8; 4]) -> u32;
    fn read_u64(buf: &[u8; 8]) -> u64;
    fn write_u16(x: u16) -> [u8; 2];
    fn write_u32(x: u32) -> [u8; 4];
    fn write_u64(x: u64) -> [u8; 8];
}

/// Little-endian byte order.
#[derive(Clone, Copy, Debug)]
pub enum LittleEndian {}

/// Big-endian byte order.
#[derive(Clone, Copy, Debug)]
pub enum BigEndian {}

impl ByteOrder for LittleEndian {
    fn read_u16(buf: &[u8; 2]) -> u16 {
        u16::from_le_bytes(*buf)
    }
    fn read_u32(buf: &[u8; 4]) -> u32 {
        u32::from_le_bytes(*buf)
    }
    fn read_u64(buf: &[u8; 8]) -> u64 {
        u64::from_le_bytes(*buf)
    }
    fn write_u16(x: u16) -> [u8; 2] {
        x.to_le_bytes()
    }
    fn write_u32(x: u32) -> [u8; 4] {
        x.to_le_bytes()
    }
    fn write_u64(x: u64) -> [u8; 8] {
        x.to_le_bytes()
    }
}

impl ByteOrder for BigEndian {
    fn read_u16(buf: &[u8; 2]) -> u16 {
        u16::from_be_bytes(*buf)
    }
    fn read_u32(buf: &[u8; 4]) -> u32 {
        u32::from_be_bytes(*buf)
    }
    fn read_u64(buf: &[u8; 8]) -> u64 {
        u64::from_be_bytes(*buf)
    }
    fn write_u16(x: u16) -> [u8; 2] {
        x.to_be_bytes()
    }
    fn write_u32(x: u32) -> [u8; 4] {
        x.to_be_bytes()
    }
    fn write_u64(x: u64) -> [u8; 8] {
        x.to_be_bytes()
    }
}

/// Network byte order.
pub type NetworkEndian = BigEndian;

/// Extension methods for reading fixed-width values.
pub trait ReadBytesExt: Read {
    fn read_u8(&mut self) -> io::Result<u8> {
        let mut b = [0u8; 1];
        self.read_exact(&mut b)?;
        Ok(b[0])
    }

    fn read_u16<T: ByteOrder>(&mut self) -> io::Result<u16> {
        let mut b = [0u8; 2];
        self.read_exact(&mut b)?;
        Ok(T::read_u16(&b))
    }

    fn read_u32<T: ByteOrder>(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(T::read_u32(&b))
    }

    fn read_u64<T: ByteOrder>(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(T::read_u64(&b))
    }

    fn read_i32<T: ByteOrder>(&mut self) -> io::Result<i32> {
        Ok(self.read_u32::<T>()? as i32)
    }

    fn read_i64<T: ByteOrder>(&mut self) -> io::Result<i64> {
        Ok(self.read_u64::<T>()? as i64)
    }

    fn read_f32<T: ByteOrder>(&mut self) -> io::Result<f32> {
        Ok(f32::from_bits(self.read_u32::<T>()?))
    }

    fn read_f64<T: ByteOrder>(&mut self) -> io::Result<f64> {
        Ok(f64::from_bits(self.read_u64::<T>()?))
    }
}

impl<R: Read + ?Sized> ReadBytesExt for R {}

/// Extension methods for writing fixed-width values.
pub trait WriteBytesExt: Write {
    fn write_u8(&mut self, x: u8) -> io::Result<()> {
        self.write_all(&[x])
    }

    fn write_u16<T: ByteOrder>(&mut self, x: u16) -> io::Result<()> {
        self.write_all(&T::write_u16(x))
    }

    fn write_u32<T: ByteOrder>(&mut self, x: u32) -> io::Result<()> {
        self.write_all(&T::write_u32(x))
    }

    fn write_u64<T: ByteOrder>(&mut self, x: u64) -> io::Result<()> {
        self.write_all(&T::write_u64(x))
    }

    fn write_i32<T: ByteOrder>(&mut self, x: i32) -> io::Result<()> {
        self.write_u32::<T>(x as u32)
    }

    fn write_i64<T: ByteOrder>(&mut self, x: i64) -> io::Result<()> {
        self.write_u64::<T>(x as u64)
    }

    fn write_f32<T: ByteOrder>(&mut self, x: f32) -> io::Result<()> {
        self.write_u32::<T>(x.to_bits())
    }

    fn write_f64<T: ByteOrder>(&mut self, x: f64) -> io::Result<()> {
        self.write_u64::<T>(x.to_bits())
    }
}

impl<W: Write + ?Sized> WriteBytesExt for W {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_le() {
        let mut buf = Vec::new();
        buf.write_u32::<LittleEndian>(0xDEADBEEF).unwrap();
        buf.write_u64::<LittleEndian>(42).unwrap();
        buf.write_f32::<LittleEndian>(1.5).unwrap();
        buf.write_f64::<LittleEndian>(-2.25).unwrap();
        let mut r = &buf[..];
        assert_eq!(r.read_u32::<LittleEndian>().unwrap(), 0xDEADBEEF);
        assert_eq!(r.read_u64::<LittleEndian>().unwrap(), 42);
        assert_eq!(r.read_f32::<LittleEndian>().unwrap(), 1.5);
        assert_eq!(r.read_f64::<LittleEndian>().unwrap(), -2.25);
    }

    #[test]
    fn le_layout() {
        let mut buf = Vec::new();
        buf.write_u32::<LittleEndian>(1).unwrap();
        assert_eq!(buf, vec![1, 0, 0, 0]);
        let mut buf = Vec::new();
        buf.write_u32::<BigEndian>(1).unwrap();
        assert_eq!(buf, vec![0, 0, 0, 1]);
    }

    #[test]
    fn short_read_errors() {
        let mut r: &[u8] = &[1, 2];
        assert!(r.read_u32::<LittleEndian>().is_err());
    }
}
