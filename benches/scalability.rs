//! Bench: Fig 10 — query latency scaling out memory nodes (LogGP
//! extrapolation, the paper's own method), plus measured multi-node
//! dispatch through the in-process dispatcher and over real sockets.
//!
//! Run: `cargo bench --bench scalability`

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::client::NodeClient;
use chameleon::net::server::NodeServer;
use chameleon::util::timer::Bench;

fn main() {
    println!("{}", chameleon::report::fig10_scalability(10_000, 64, 42));

    // Measured: in-process dispatcher with 1..8 nodes over a scaled db.
    let ds = config::dataset_by_name("SYN-512").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 10_000, 64, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 100, 5);
    let mut bench = Bench::new("measured_dispatch");
    for &n_nodes in &[1usize, 2, 4, 8] {
        let nodes: Vec<MemoryNode> = (0..n_nodes)
            .map(|i| {
                MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, 100)
            })
            .collect();
        let mut disp = Dispatcher::new(nodes, 100);
        let mut qi = 0usize;
        bench.case(&format!("inproc_{n_nodes}nodes"), || {
            qi = (qi + 1) % data.n_queries;
            let q = data.query(qi);
            let lists = index.probe(q, ds.nprobe);
            disp.search(q, &index.pq.centroids, &lists, ds.nprobe).unwrap().topk.len()
        });
    }

    // Measured: networked nodes over localhost TCP.
    let mut bench = Bench::new("measured_networked");
    for &n_nodes in &[1usize, 2, 4] {
        let servers: Vec<NodeServer> = (0..n_nodes)
            .map(|node_id| {
                let data = SyntheticDataset::generate_sized(ds, 10_000, 64, 3);
                let index =
                    IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 100, 5);
                let cb = index.pq.centroids.clone();
                NodeServer::spawn_with(
                    move || {
                        MemoryNode::new(
                            Shard::carve(&index, node_id, n_nodes),
                            ScanEngine::Native,
                            100,
                        )
                    },
                    cb,
                    ds.nprobe,
                )
                .unwrap()
            })
            .collect();
        let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
        let mut client = NodeClient::connect(&addrs, 100).unwrap();
        let mut qi = 0usize;
        bench.case_n(&format!("tcp_{n_nodes}nodes"), 2, 12, || {
            qi = (qi + 1) % data.n_queries;
            let q = data.query(qi);
            let lists = index.probe(q, ds.nprobe);
            client.search(qi as u64, q, &lists).unwrap().0.len()
        });
        client.shutdown_nodes();
    }
}
