//! Bench: Fig 10 — query latency scaling out memory nodes (LogGP
//! extrapolation, the paper's own method), plus measured multi-node
//! dispatch through the in-process thread-pooled dispatcher (worker
//! sweep: wall-clock must drop monotonically 1 -> 4 threads on a 4-node
//! index) and over real sockets.
//!
//! Run: `cargo bench --bench scalability`

use chameleon::chamvs::dispatcher::{BatchQuery, Dispatcher};
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::client::NodeClient;
use chameleon::net::server::NodeServer;
use chameleon::util::timer::Bench;

fn main() {
    println!("{}", chameleon::report::fig10_scalability(10_000, 64, 42));

    // Measured: in-process dispatcher with 1..8 nodes over a scaled db
    // (one worker thread per node — the default fan-out).
    let ds = config::dataset_by_name("SYN-512").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 10_000, 64, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 100, 5);
    let mut bench = Bench::new("measured_dispatch");
    for &n_nodes in &[1usize, 2, 4, 8] {
        let nodes: Vec<MemoryNode> = (0..n_nodes)
            .map(|i| {
                MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, 100)
            })
            .collect();
        let mut disp = Dispatcher::new(nodes, 100);
        let mut qi = 0usize;
        bench.case(&format!("inproc_{n_nodes}nodes"), || {
            qi = (qi + 1) % data.n_queries;
            let q = data.query(qi);
            let lists = index.probe(q, ds.nprobe);
            disp.search(q, &index.pq.centroids, &lists, ds.nprobe).unwrap().topk.len()
        });
    }

    // Measured: worker-thread sweep on a fixed 4-node index. Probes are
    // precomputed so the timed region is purely the dispatch round; each
    // round pushes a 16-query batch through per-node work queues. Wall
    // clock must improve monotonically 1 -> 2 -> 4 threads while the CPU
    // total (sum across nodes) stays flat — the wall/cpu split
    // `SearchResult` now reports.
    const BATCH: usize = 16;
    let nodes: Vec<MemoryNode> = (0..4)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 4), ScanEngine::Native, 100))
        .collect();
    let mut disp = Dispatcher::new(nodes, 100);
    let queries: Vec<Vec<f32>> = (0..data.n_queries)
        .map(|i| data.query(i).to_vec())
        .collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    let mut bench = Bench::new("measured_thread_sweep_4nodes");
    let mut walls: Vec<(usize, f64)> = Vec::new();
    for &threads in &[1usize, 2, 4] {
        disp.n_threads = threads;
        let mut start = 0usize;
        let mut cpu_sum = 0.0f64;
        let mut rounds = 0u64;
        let s = bench.case(&format!("batch{BATCH}_{threads}threads"), || {
            let batch: Vec<BatchQuery> = (0..BATCH)
                .map(|j| {
                    let i = (start + j) % queries.len();
                    BatchQuery { query: &queries[i], lists: &lists[i], trace_id: 0 }
                })
                .collect();
            start = (start + BATCH) % queries.len();
            let rs = disp
                .search_batch(&batch, &index.pq.centroids, ds.nprobe)
                .unwrap();
            cpu_sum += rs.iter().map(|r| r.measured_cpu_s).sum::<f64>();
            rounds += 1;
            rs.len()
        });
        println!(
            "    -> per-round wall p50 {:.3} ms | node-cpu per round {:.3} ms (sum across nodes)",
            s.p50 * 1e3,
            cpu_sum / rounds as f64 * 1e3,
        );
        walls.push((threads, s.p50));
    }
    for w in walls.windows(2) {
        let (t0, w0) = w[0];
        let (t1, w1) = w[1];
        println!(
            "    -> {t0} -> {t1} threads: wall {:.3} -> {:.3} ms ({:.2}x){}",
            w0 * 1e3,
            w1 * 1e3,
            w0 / w1.max(1e-12),
            if w1 < w0 { "" } else { "  ** NOT monotonic **" },
        );
    }

    // Measured: networked nodes over localhost TCP.
    let mut bench = Bench::new("measured_networked");
    for &n_nodes in &[1usize, 2, 4] {
        let servers: Vec<NodeServer> = (0..n_nodes)
            .map(|node_id| {
                let data = SyntheticDataset::generate_sized(ds, 10_000, 64, 3);
                let index =
                    IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 100, 5);
                let cb = index.pq.centroids.clone();
                NodeServer::spawn_with(
                    move || {
                        MemoryNode::new(
                            Shard::carve(&index, node_id, n_nodes),
                            ScanEngine::Native,
                            100,
                        )
                    },
                    cb,
                    ds.nprobe,
                )
                .unwrap()
            })
            .collect();
        let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
        let mut client = NodeClient::connect(&addrs, 100).unwrap();
        let mut qi = 0usize;
        bench.case_n(&format!("tcp_{n_nodes}nodes"), 2, 12, || {
            qi = (qi + 1) % data.n_queries;
            let q = data.query(qi);
            let lists = index.probe(q, ds.nprobe);
            client.search(q, &lists).unwrap().topk.len()
        });
        client.shutdown_nodes();
    }
}
