//! Bench: multi-client coordinator throughput — cross-connection dynamic
//! batching (concurrent event loop) vs the one-connection-at-a-time
//! sequential baseline, at 4 GPU clients over loopback TCP.
//!
//! The concurrent server amortizes the per-round dispatch overhead
//! (thread-pool fan-out, frame decode) across the batch and overlaps the
//! clients' network round trips, so it must sustain >= 1.5x the
//! sequential queries/s (the PR acceptance bar; per-query results are
//! pinned bit-identical by rust/tests/concurrent_serving.rs).
//!
//! Second bar: end-to-end tracing must be effectively free. The same
//! concurrent workload runs with a live span ring, and the best-of-3
//! traced q/s must stay within 5% of the best-of-3 untraced q/s
//! (recording is a few atomics per span; rust/tests/trace_alloc.rs pins
//! the zero-allocation half of that claim).
//!
//! Third bar: the event loop must scale in connection count, not thread
//! count. A sweep over 4 / 64 / 512 simultaneously-open pipelined
//! connections (same total request volume) must hold q/s at 512 within
//! 20% of the 4-connection figure, without growing the server's thread
//! count (fixed poll pool — checked via /proc/self/status) or its
//! resident memory unboundedly. The thread-per-connection mode runs the
//! small points as the A/B baseline.
//!
//! Fourth bar: the live telemetry plane must be effectively free. The
//! same workload runs with the full plane on (SLO burn tracking,
//! per-tenant windowed histograms, tail sampling) AND a scraper thread
//! hammering the Prometheus listener the whole run, vs
//! `Telemetry::off()`; best-of-3 q/s with telemetry on must stay within
//! 3% of best-of-3 with it off.
//!
//! Run: `cargo bench --bench coordinator_throughput`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorClient, CoordinatorServer, ServeMode};
use chameleon::coordinator::{QosConfig, SloObjective};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::telemetry::{MetricsServer, Telemetry};
use chameleon::trace::{SpanKind, Tracer};

const CLIENTS: usize = 4;
const PER_CLIENT: usize = 96;
const N: usize = 6000;
const NODES: usize = 2;
const K: usize = 10;

fn build_retriever(seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, N, 16, seed);
    let nlist = (N as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..NODES)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, NODES), ScanEngine::Native, K))
        .collect();
    let corpus = Corpus::generate(N, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, K), corpus)
}

/// Serve CLIENTS x `per_client` blocking retrievals and return (q/s,
/// rounds, max batch). The retriever is built untimed and moved in.
fn run(mode: ServeMode, per_client: usize) -> (f64, u64, u64) {
    run_traced(mode, per_client, Tracer::off())
}

fn run_traced(mode: ServeMode, per_client: usize, tracer: Tracer) -> (f64, u64, u64) {
    let retriever = build_retriever(7);
    let mut server =
        CoordinatorServer::spawn_traced(move || retriever, mode, tracer).unwrap();
    let addr = server.addr;
    let qdata = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        64,
        64,
        9,
    );
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let qdata = &qdata;
            s.spawn(move || {
                let mut client = CoordinatorClient::connect(addr, c as u32).unwrap();
                for i in 0..per_client {
                    let q = qdata.query((c * 13 + i) % qdata.n_queries);
                    client.retrieve(q, &[], K, false).unwrap();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let out = (
        (CLIENTS * per_client) as f64 / wall,
        stats.rounds(),
        stats.max_batch(),
    );
    server.shutdown();
    out
}

/// q/s for one telemetry-overhead arm. With `on` the server runs the
/// full plane — SLO objectives on both QoS classes (burn tracking,
/// per-tenant windowed histograms, tail sampling) — plus a Prometheus
/// listener with a scraper thread hammering it for the whole run. With
/// `!on` the plane is [`Telemetry::off`], so per-request observation is
/// a branch-and-return.
fn run_telemetry_arm(policy: BatchPolicy, on: bool) -> f64 {
    let retriever = build_retriever(7);
    let mode = ServeMode::Concurrent(policy);
    let mut server = if on {
        let qos = QosConfig {
            slo_interactive: Some(SloObjective::default()),
            slo_batch: Some(SloObjective::default()),
            ..QosConfig::default()
        };
        CoordinatorServer::spawn_qos(move || retriever, mode, qos, Tracer::off()).unwrap()
    } else {
        CoordinatorServer::spawn_telemetry(
            move || retriever,
            mode,
            QosConfig::default(),
            Tracer::off(),
            Telemetry::off(),
        )
        .unwrap()
    };
    let addr = server.addr;
    let stop = Arc::new(AtomicBool::new(false));
    let mut metrics = None;
    let mut scraper = None;
    if on {
        let m = MetricsServer::spawn("127.0.0.1:0", server.telemetry()).unwrap();
        let maddr = m.addr;
        metrics = Some(m);
        let stop2 = Arc::clone(&stop);
        scraper = Some(std::thread::spawn(move || {
            use std::io::{Read, Write};
            let mut scrapes = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                if let Ok(mut s) = std::net::TcpStream::connect(maddr) {
                    let mut body = String::new();
                    if s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").is_ok()
                        && s.read_to_string(&mut body).is_ok()
                        && body.contains("coordinator_requests")
                    {
                        scrapes += 1;
                    }
                }
            }
            scrapes
        }));
    }
    let qdata = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        64,
        64,
        9,
    );
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let qdata = &qdata;
            s.spawn(move || {
                let mut client = CoordinatorClient::connect(addr, c as u32).unwrap();
                for i in 0..PER_CLIENT {
                    let q = qdata.query((c * 13 + i) % qdata.n_queries);
                    client.retrieve(q, &[], K, false).unwrap();
                }
            });
        }
    });
    let qps = (CLIENTS * PER_CLIENT) as f64 / t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = scraper {
        let scrapes = h.join().unwrap();
        assert!(scrapes > 0, "scraper never completed a scrape during the run");
    }
    if let Some(m) = metrics.as_mut() {
        m.shutdown();
    }
    server.shutdown();
    qps
}

/// Read an integer field from /proc/self/status (`Threads`, `VmRSS` in
/// kB). Returns None off-Linux so the sweep's resource checks degrade to
/// prints instead of failing.
fn proc_status(field: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = text.lines().find(|l| l.starts_with(field))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Open `conns` simultaneous connections, then drive ~`total` requests
/// through them from a bounded driver pool, pipelined in windows of 8.
/// Returns (q/s, process thread count while all connections sat open).
fn run_conn_point(addr: std::net::SocketAddr, conns: usize, total: usize) -> (f64, u64) {
    let qdata = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        64,
        64,
        9,
    );
    let per_conn = (total / conns).max(8);
    let mut clients: Vec<CoordinatorClient> = (0..conns)
        .map(|c| CoordinatorClient::connect(addr, c as u32).unwrap())
        .collect();
    // Every connection is open and registered right now: a
    // thread-per-connection server would show `conns` extra threads here.
    let threads_open = proc_status("Threads").unwrap_or(0);
    const DRIVERS: usize = 32;
    let chunk = conns.div_ceil(DRIVERS);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for group in clients.chunks_mut(chunk) {
            let qdata = &qdata;
            s.spawn(move || {
                for client in group {
                    let queries: Vec<&[f32]> = (0..per_conn)
                        .map(|i| qdata.query(i % qdata.n_queries))
                        .collect();
                    for win in queries.chunks(8) {
                        let got = client.retrieve_pipelined(win, K, false).unwrap();
                        assert_eq!(got.len(), win.len());
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    ((conns * per_conn) as f64 / wall, threads_open)
}

/// The connection-count sweep: one event-loop server, 4 -> 512 open
/// connections at fixed request volume; q/s must not fall off a cliff
/// and server threads/memory must stay flat.
fn conn_sweep(policy: BatchPolicy) {
    chameleon::util::poll::raise_nofile(4096);
    const TOTAL: usize = 4096;
    let retriever = build_retriever(7);
    let mut server =
        CoordinatorServer::spawn(move || retriever, ServeMode::Concurrent(policy))
            .unwrap();
    let addr = server.addr;
    let pool = chameleon::coordinator::QosConfig::default().poll_threads;
    let threads_base = proc_status("Threads").unwrap_or(0);
    let rss_base_kb = proc_status("VmRSS").unwrap_or(0);

    println!("  conn sweep (event loop, {TOTAL} requests/point):");
    let mut qps_at = Vec::new();
    for &conns in &[4usize, 64, 512] {
        let (qps, threads_open) = run_conn_point(addr, conns, TOTAL);
        let rss_kb = proc_status("VmRSS").unwrap_or(0);
        println!(
            "    {conns:>4} conns : {qps:>8.0} q/s  (threads {threads_open}, rss {} MiB)",
            rss_kb / 1024
        );
        if threads_base > 0 {
            // Driver threads haven't started at sample time; the only
            // growth allowed is scheduler jitter, never one-per-conn.
            assert!(
                threads_open <= threads_base + 2,
                "server grew threads with connection count: {threads_open} vs \
                 base {threads_base} at {conns} conns (pool={pool})"
            );
        }
        if rss_base_kb > 0 {
            assert!(
                rss_kb <= rss_base_kb + 1024 * 1024,
                "resident set grew unboundedly: {rss_kb} kB vs base {rss_base_kb} kB"
            );
        }
        qps_at.push((conns, qps));
    }
    server.shutdown();
    let q4 = qps_at[0].1;
    let q512 = qps_at[2].1;
    println!("    512-conn retention: {:.2}x of 4-conn (bar: >= 0.8x)", q512 / q4);
    assert!(
        q512 >= 0.8 * q4,
        "event loop q/s fell off with connections: {q512:.0} q/s at 512 conns \
         vs {q4:.0} q/s at 4 (bar: within 20%)"
    );

    // A/B: the retained thread-per-connection mode at the small points.
    let retriever = build_retriever(7);
    let mut ab =
        CoordinatorServer::spawn(move || retriever, ServeMode::Threaded(policy))
            .unwrap();
    for &conns in &[4usize, 64] {
        let (qps, threads_open) = run_conn_point(ab.addr, conns, TOTAL);
        println!(
            "    {conns:>4} conns : {qps:>8.0} q/s  (threaded A/B baseline, \
             threads {threads_open})"
        );
    }
    ab.shutdown();
}

fn main() {
    let policy = BatchPolicy {
        max_batch: CLIENTS,
        max_wait: Duration::from_millis(2),
    };

    // Throwaway warmup (page cache, thread stacks, allocator arenas).
    run(ServeMode::Concurrent(policy), 8);

    let (seq_qps, seq_rounds, _) = run(ServeMode::Sequential, PER_CLIENT);
    let (conc_qps, conc_rounds, conc_max) =
        run(ServeMode::Concurrent(policy), PER_CLIENT);

    println!("coordinator throughput — {CLIENTS} clients x {PER_CLIENT} queries, {NODES} nodes, n={N}");
    println!("  sequential : {seq_qps:>8.0} q/s  ({seq_rounds} rounds of 1)");
    println!(
        "  concurrent : {conc_qps:>8.0} q/s  ({conc_rounds} rounds, max batch {conc_max}, policy max_batch={} max_wait={}us)",
        policy.max_batch,
        policy.max_wait.as_micros()
    );
    let speedup = conc_qps / seq_qps;
    println!("  speedup    : {speedup:.2}x (acceptance bar: >= 1.5x)");
    assert!(
        conc_max >= 2,
        "batching not observed (max batch {conc_max})"
    );
    assert!(
        speedup >= 1.5,
        "concurrent batched server must sustain >= 1.5x sequential q/s, got {speedup:.2}x"
    );

    // Tracing-overhead A/B: best-of-3 each way to squeeze out scheduler
    // noise; the traced arm keeps a live 64K-slot ring the whole run.
    let best = |mk: &dyn Fn() -> f64| (0..3).map(|_| mk()).fold(0.0, f64::max);
    let untraced =
        best(&|| run(ServeMode::Concurrent(policy), PER_CLIENT).0);
    let mut spans = 0usize;
    let mut kinds_seen = Vec::new();
    let mut traced = 0.0f64;
    for _ in 0..3 {
        let tracer = Tracer::new(1 << 16);
        let qps = run_traced(
            ServeMode::Concurrent(policy),
            PER_CLIENT,
            tracer.clone(),
        )
        .0;
        traced = traced.max(qps);
        let events = tracer.snapshot();
        spans = events.len();
        kinds_seen = events.iter().map(|e| e.kind).collect();
        kinds_seen.sort_unstable();
        kinds_seen.dedup();
    }
    let ratio = traced / untraced;
    println!(
        "  tracing    : {traced:>8.0} q/s traced vs {untraced:>8.0} q/s untraced \
         ({ratio:.3}x, {spans} spans/run, bar: >= 0.95x)"
    );
    for kind in [
        SpanKind::QueueWait,
        SpanKind::LutBuild,
        SpanKind::NodeScan,
        SpanKind::Merge,
        SpanKind::ReplyWrite,
        SpanKind::Total,
    ] {
        assert!(
            kinds_seen.contains(&kind),
            "traced run missing {} spans",
            kind.name()
        );
    }
    assert!(
        ratio >= 0.95,
        "tracing overhead too high: traced {traced:.0} q/s vs untraced \
         {untraced:.0} q/s ({ratio:.3}x < 0.95x)"
    );

    // Telemetry-overhead A/B: full plane plus a live scraper vs the
    // disabled plane, best-of-3 each arm.
    let telem_off = best(&|| run_telemetry_arm(policy, false));
    let telem_on = best(&|| run_telemetry_arm(policy, true));
    let telem_ratio = telem_on / telem_off;
    println!(
        "  telemetry  : {telem_on:>8.0} q/s on vs {telem_off:>8.0} q/s off \
         ({telem_ratio:.3}x, scraper live, bar: >= 0.97x)"
    );
    assert!(
        telem_ratio >= 0.97,
        "telemetry overhead too high: {telem_on:.0} q/s on vs {telem_off:.0} q/s \
         off ({telem_ratio:.3}x < 0.97x)"
    );

    conn_sweep(policy);
    println!("coordinator_throughput OK");
}
