//! Bench: the `retcache` subsystem — modeled serving throughput of the
//! cached + speculative engine vs the seed synchronous path, sweeping
//! cache capacity x query-repeat ratio (Zipf skew), plus measured host
//! costs of the cache hot path.
//!
//! Acceptance tracked here: on a Zipf-skewed repeated-query workload the
//! cached+speculative engine must show >= 1.3x modeled tokens/s over the
//! synchronous path (also asserted by the unit test in
//! rust/src/retcache/model.rs).
//!
//! Run: `cargo bench --bench retrieval_cache`

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config::{CHUNK_LEN, DEC_S, SIFT};
use chameleon::coordinator::retriever::Retriever;
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::retcache::{
    repeat_fraction, zipf_stream, CacheConfig, CachedEntry, EvictionPolicy, KeyPolicy,
    RetrievalCache, ServeModel, SpecConfig,
};
use chameleon::util::timer::Bench;

fn build_retriever(seed: u64) -> (Retriever, SyntheticDataset) {
    let data = SyntheticDataset::generate_sized(&SIFT, 8000, 256, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, SIFT.m, 64, seed ^ 1);
    let nodes =
        vec![MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, 100)];
    let dispatcher = Dispatcher::new(nodes, 100);
    let corpus = Corpus::generate(data.n, 2048, CHUNK_LEN, seed ^ 2);
    (Retriever::new(&SIFT, index, dispatcher, corpus), data)
}

fn main() {
    let seed = 42u64;
    let (mut retriever, data) = build_retriever(seed);
    let sm = ServeModel::new(&DEC_S);

    // Part 1: capacity x repeat-ratio sweep (modeled paper-scale serving).
    println!("Retcache sweep — Dec-S over SIFT, 512 retrievals, 64 unique queries");
    println!(
        "capacity_B  zipf_a  repeat%  hit%   spec%  sync_tok/s  cached_tok/s  speedup"
    );
    let mut best = 0.0f64;
    for &cap in &[64usize << 10, 256 << 10, 1 << 20, 8 << 20] {
        for &alpha in &[0.5f64, 1.1, 2.0] {
            let stream = zipf_stream(64, alpha, 512, seed ^ 7);
            let repeat = repeat_fraction(&stream);
            let queries: Vec<Vec<f32>> = stream
                .iter()
                .map(|&i| data.query(i % data.n_queries).to_vec())
                .collect();
            retriever.enable_cache(CacheConfig {
                capacity_bytes: cap,
                policy: EvictionPolicy::Lru,
                key: KeyPolicy::Quantized(0.05),
            });
            retriever.enable_speculation(SpecConfig::default());
            retriever.reset_retcache_stats();
            let r = sm.run(&mut retriever, &queries).expect("serve model");
            best = best.max(r.speedup());
            println!(
                "{:<11} {:<7} {:>6.1}  {:>5.1}  {:>5.1}  {:>10.1} {:>13.1} {:>7.2}x",
                cap,
                alpha,
                repeat * 100.0,
                r.hit_rate() * 100.0,
                r.spec_hits as f64 / r.retrievals as f64 * 100.0,
                r.sync_tokens_per_s(),
                r.modeled_tokens_per_s(),
                r.speedup(),
            );
        }
    }
    println!(
        "best modeled speedup {best:.2}x (acceptance bar: >= 1.30x on skewed workloads)"
    );
    println!();
    print!("{}", retriever.cache_report());

    // Part 2: eviction-policy comparison under pressure (tight budget,
    // mixed-cost entries favour cost-aware eviction).
    println!("\nEviction policy at 64 KiB, zipf 1.1:");
    for policy in [EvictionPolicy::Lru, EvictionPolicy::CostAware] {
        let stream = zipf_stream(64, 1.1, 512, seed ^ 7);
        let queries: Vec<Vec<f32>> = stream
            .iter()
            .map(|&i| data.query(i % data.n_queries).to_vec())
            .collect();
        retriever.enable_cache(CacheConfig {
            capacity_bytes: 64 << 10,
            policy,
            key: KeyPolicy::Quantized(0.05),
        });
        retriever.enable_speculation(SpecConfig::default());
        retriever.reset_retcache_stats();
        let r = sm.run(&mut retriever, &queries).expect("serve model");
        println!(
            "  {:?}: hit {:.1}%, cached {:.1} tok/s, speedup {:.2}x",
            policy,
            r.hit_rate() * 100.0,
            r.modeled_tokens_per_s(),
            r.speedup(),
        );
    }

    // Part 3: measured host cost of the cache hot path (the number the
    // modeled CACHE_LOOKUP_S constant must stay honest against).
    let mut bench = Bench::new("measured_cache_hot_path");
    let mut cache = RetrievalCache::new(CacheConfig {
        capacity_bytes: 8 << 20,
        policy: EvictionPolicy::Lru,
        key: KeyPolicy::Quantized(0.05),
    });
    let queries: Vec<Vec<f32>> =
        (0..256).map(|i| data.query(i % data.n_queries).to_vec()).collect();
    for q in &queries {
        cache.insert(
            q,
            CachedEntry {
                ids: (0..100u64).collect(),
                dists: vec![0.5; 100],
                modeled_s: 1e-3,
            },
        );
    }
    let mut qi = 0usize;
    bench.case_n("get_hit_d128_k100", 10, 200, || {
        qi = (qi + 1) % queries.len();
        cache.get(&queries[qi]).is_some()
    });
    let mut qi = 0usize;
    bench.case_n("insert_evicting_d128_k100", 10, 200, || {
        qi = (qi + 1) % queries.len();
        cache.insert(
            &queries[qi],
            CachedEntry {
                ids: (0..100u64).collect(),
                dists: vec![0.5; 100],
                modeled_s: 1e-3,
            },
        );
    });
}
