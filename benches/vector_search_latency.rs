//! Bench: Fig 9 — vector search latency for the four system
//! configurations across datasets and batch sizes, plus the *measured*
//! hot-path costs on this host (native ADC scan, LUT build, end-to-end
//! dispatcher search) and the zero-copy scan-pipeline A/B
//! (EXPERIMENTS.md §Perf).
//!
//! The scan-pipeline part asserts the acceptance bars of the gather-free
//! rework — the fused path must beat the legacy copy-then-scan pipeline
//! by >= 1.3x per query, and the list-major batched round must beat the
//! query-major round by >= 1.5x at B=8, bit-identical to the flat-scan
//! reference in exact mode — and emits machine-readable `BENCH_scan.json`
//! so the perf trajectory is tracked across PRs (CI uploads it).
//!
//! Run: `cargo bench --bench vector_search_latency`
//! Quick CI profile: `CHAM_BENCH_QUICK=1 cargo bench --bench vector_search_latency`

use std::collections::BTreeMap;

use chameleon::chamvs::backend::{BackendKind, SearchBackend};
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::chamvs::{ScanBackend, ScanJob};
use chameleon::config::DATASETS;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::kselect::{ApproxHierarchicalQueue, FusedSelector, HierarchicalConfig, SelectMode};
use chameleon::pq::scan::{adc_scan, adc_scan_into, build_lut, scan_list_into_sink};
use chameleon::pq::simd::{self, IsaKind, ScanKernels};
use chameleon::util::json::{obj, Json};
use chameleon::util::rng::Rng;
use chameleon::util::timer::Bench;

/// The seed pipeline, reconstructed for the A/B: gather-copy every probed
/// list into fresh buffers, scan into a materialized distance vector,
/// push every distance through the (approximate) hierarchical queue.
fn legacy_copy_then_scan(
    shard: &Shard,
    lut: &[f32],
    lists: &[u32],
    kcfg: HierarchicalConfig,
) -> Vec<(f32, u64)> {
    let total = shard.scan_count(lists);
    let mut codes = Vec::with_capacity(total * shard.m);
    let mut ids = Vec::with_capacity(total);
    for &l in lists {
        codes.extend_from_slice(shard.list_codes(l as usize));
        ids.extend_from_slice(shard.list_ids(l as usize));
    }
    let mut scratch = vec![0.0f32; total];
    adc_scan_into(&codes, total, shard.m, lut, &mut scratch);
    let mut q = ApproxHierarchicalQueue::new(kcfg);
    for (i, &d) in scratch.iter().enumerate() {
        q.push(d, i as u64);
    }
    q.finalize()
        .into_iter()
        .map(|(d, local)| (d, ids[local as usize]))
        .collect()
}

/// Flat-scan reference for the bit-identity check.
fn flat_reference(index: &IvfPqIndex, lut: &[f32], lists: &[u32], k: usize) -> Vec<(f32, u64)> {
    let mut all: Vec<(f32, u64)> = Vec::new();
    for &l in lists {
        let ids = &index.list_ids[l as usize];
        let ds = adc_scan(&index.list_codes[l as usize], ids.len(), index.m, lut);
        for (i, &d) in ds.iter().enumerate() {
            all.push((d, ids[i]));
        }
    }
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    all.truncate(k);
    all
}

/// The zero-copy scan-pipeline A/B: gather-free fused vs legacy
/// copy-then-scan (single query), list-major vs query-major round (B=8),
/// and the selector ablation. Returns the §Perf JSON block plus the two
/// acceptance speedups — asserted by `main` *after* `BENCH_scan.json` is
/// written, so a failing bar still leaves the record for diagnosis.
fn scan_pipeline_ab(quick: bool) -> (Json, f64, f64) {
    let ds = &chameleon::config::SIFT;
    let n = if quick { 8_000 } else { 20_000 };
    let nlist = ((n as f64).sqrt() as usize).max(16);
    let data = SyntheticDataset::generate_sized(ds, n, 64, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, 5);
    let k = 100;
    let (warmup, iters) = if quick { (2, 8) } else { (3, 20) };

    let shard = Shard::carve(&index, 0, 1);
    let mut node = MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, k);
    let legacy_kcfg = node.kcfg; // the seed's default approximate queue
    let queries: Vec<Vec<f32>> = (0..data.n_queries)
        .map(|i| data.query(i).to_vec())
        .collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    let luts: Vec<Vec<f32>> = queries.iter().map(|q| build_lut(&index.pq, q)).collect();

    // Bit-identity: the fused exact path must reproduce the flat-scan
    // reference, distance bits and (single-node) ids.
    for qi in 0..3 {
        let r = node
            .scan(&luts[qi], &queries[qi], &index.pq.centroids, &lists[qi], ds.nprobe)
            .unwrap();
        let want = flat_reference(&index, &luts[qi], &lists[qi], k);
        assert_eq!(r.topk.len(), want.len());
        for (g, w) in r.topk.iter().zip(&want) {
            assert_eq!(g.0.to_bits(), w.0.to_bits(), "fused path diverged");
            assert_eq!(g.1, w.1, "fused path id order diverged");
        }
    }

    let mut bench = Bench::new("scan_pipeline_ab");
    let nq = queries.len();

    // A: legacy copy-then-scan (gather + scratch + hierarchical queue).
    let mut qi = 0usize;
    let legacy = bench.case_n("legacy_copy_then_scan", warmup, iters, || {
        qi = (qi + 1) % nq;
        legacy_copy_then_scan(&shard, &luts[qi], &lists[qi], legacy_kcfg)
    });

    // B: gather-free fused scan+select (the serving default).
    let mut qi = 0usize;
    let fused = bench.case_n("fused_gather_free", warmup, iters, || {
        qi = (qi + 1) % nq;
        node.scan(&luts[qi], &queries[qi], &index.pq.centroids, &lists[qi], ds.nprobe)
            .unwrap()
            .topk
    });
    let single_speedup = legacy.p50 / fused.p50;
    println!("    -> fused vs legacy speedup: {single_speedup:.2}x (bar: 1.3x)");

    // Batched round, B=8: query-major (the seed behavior — one legacy
    // pipeline per query) vs the list-major fused round.
    let b = 8usize;
    let qmajor = bench.case_n("batch8_query_major_legacy", warmup, iters, || {
        let mut out = 0usize;
        for j in 0..b {
            out += legacy_copy_then_scan(&shard, &luts[j], &lists[j], legacy_kcfg).len();
        }
        out
    });
    let jobs: Vec<ScanJob> = (0..b)
        .map(|j| ScanJob {
            query: &queries[j],
            lists: &lists[j],
            lut: &luts[j],
            nprobe: ds.nprobe,
        })
        .collect();
    let lmajor = bench.case_n("batch8_list_major_fused", warmup, iters, || {
        node.scan_jobs(&jobs, &index.pq.centroids).unwrap().len()
    });
    let batch_speedup = qmajor.p50 / lmajor.p50;
    println!("    -> list-major batch speedup at B=8: {batch_speedup:.2}x (bar: 1.5x)");

    // Selector ablation: same gather-free scan, hierarchical queue
    // (hardware-fidelity path) vs the fused exact selector.
    let mut hnode = MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, k);
    hnode.select = SelectMode::Hierarchical;
    let mut qi = 0usize;
    let hier = bench.case_n("selector_hierarchical", warmup, iters, || {
        qi = (qi + 1) % nq;
        hnode
            .scan(&luts[qi], &queries[qi], &index.pq.centroids, &lists[qi], ds.nprobe)
            .unwrap()
            .topk
    });
    let ablation = hier.p50 / fused.p50;
    println!("    -> fused selector vs hierarchical queue: {ablation:.2}x");

    let json = obj(vec![
        ("n_codes_indexed", Json::Num(n as f64)),
        ("k", Json::Num(k as f64)),
        (
            "fused_vs_legacy",
            obj(vec![
                ("legacy_p50_ms", Json::Num(legacy.p50 * 1e3)),
                ("fused_p50_ms", Json::Num(fused.p50 * 1e3)),
                ("speedup", Json::Num(single_speedup)),
            ]),
        ),
        (
            "batch8",
            obj(vec![
                ("query_major_p50_ms", Json::Num(qmajor.p50 * 1e3)),
                ("list_major_p50_ms", Json::Num(lmajor.p50 * 1e3)),
                ("speedup", Json::Num(batch_speedup)),
            ]),
        ),
        (
            "selector_ablation",
            obj(vec![
                ("hierarchical_p50_ms", Json::Num(hier.p50 * 1e3)),
                ("fused_p50_ms", Json::Num(fused.p50 * 1e3)),
                ("speedup", Json::Num(ablation)),
            ]),
        ),
    ]);
    (json, single_speedup, batch_speedup)
}

/// Scalar-vs-SIMD kernel ablation (ISSUE 8): GB/s/core per paper width
/// for the scalar reference kernels vs the runtime-dispatched active set,
/// with full-buffer bit-identity plus an end-to-end top-k pin through the
/// fused sink. Returns the JSON block and per-width speedups; `main`
/// asserts the >= 2x floor *after* `BENCH_scan.json` is written.
fn simd_ablation(quick: bool) -> (Json, Vec<(usize, f64)>) {
    let kernels = simd::active();
    let scalar = ScanKernels::scalar();
    let n = if quick { 20_000 } else { 60_000 };
    let (warmup, iters) = if quick { (2, 10) } else { (3, 30) };
    let mut bench = Bench::new("simd_vs_scalar_adc");
    let mut rng = Rng::new(7);
    let mut widths: BTreeMap<String, Json> = BTreeMap::new();
    let mut speedups = Vec::new();
    for m in [16usize, 32, 64] {
        let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..m * 256).map(|_| rng.f32()).collect();
        let mut out_sc = vec![0.0f32; n];
        let mut out_si = vec![0.0f32; n];

        // Full-buffer bit identity before timing anything.
        scalar.scan_into(&codes, n, m, &lut, &mut out_sc);
        kernels.scan_into(&codes, n, m, &lut, &mut out_si);
        for (a, b) in out_sc.iter().zip(&out_si) {
            assert_eq!(a.to_bits(), b.to_bits(), "m={m}: SIMD diverged from scalar");
        }

        // End-to-end top-k pin: the fused sink (which routes through the
        // active kernels via `adc_scan_into`) must reproduce a selector
        // fed by the scalar reference exactly — bits, ids, tie order.
        let ids: Vec<u64> = (0..n as u64).collect();
        let mut sel = FusedSelector::new(100);
        let mut scratch = Vec::new();
        scan_list_into_sink(&codes, m, &lut, &ids, 0, &mut scratch, &mut sel);
        let mut got = Vec::new();
        sel.emit_into(&mut got);
        let mut sel_ref = FusedSelector::new(100);
        for (i, &d) in out_sc.iter().enumerate() {
            sel_ref.offer(d, i as u64, ids[i]);
        }
        let mut want = Vec::new();
        sel_ref.emit_into(&mut want);
        assert_eq!(got.len(), want.len(), "m={m}: top-k lengths diverged");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.0.to_bits(), w.0.to_bits(), "m={m}: top-k bits diverged");
            assert_eq!(g.1, w.1, "m={m}: top-k ids/tie order diverged");
        }

        let bytes = (n * m) as f64;
        let sc = bench.case_n(&format!("scalar_m{m}"), warmup, iters, || {
            scalar.scan_into(&codes, n, m, &lut, &mut out_sc);
            out_sc[0]
        });
        let name = format!("{}_m{m}", kernels.kind.name());
        let si = bench.case_n(&name, warmup, iters, || {
            kernels.scan_into(&codes, n, m, &lut, &mut out_si);
            out_si[0]
        });
        let speedup = sc.p50 / si.p50;
        println!(
            "    -> m={m}: scalar {:.2} GB/s/core, {} {:.2} GB/s/core ({speedup:.2}x)",
            bytes / sc.p50 / 1e9,
            kernels.kind.name(),
            bytes / si.p50 / 1e9
        );
        widths.insert(
            format!("m{m}"),
            obj(vec![
                ("scalar_gb_per_s", Json::Num(bytes / sc.p50 / 1e9)),
                ("simd_gb_per_s", Json::Num(bytes / si.p50 / 1e9)),
                ("speedup", Json::Num(speedup)),
            ]),
        );
        speedups.push((m, speedup));
    }
    let json = obj(vec![
        ("isa_detected", Json::Str(simd::detect().name().to_string())),
        ("isa_features", Json::Str(simd::detected_features())),
        ("kernel_active", Json::Str(kernels.kind.name().to_string())),
        ("n_codes", Json::Num(n as f64)),
        ("widths", Json::Obj(widths)),
    ]);
    (json, speedups)
}

fn main() {
    let quick = std::env::var("CHAM_BENCH_QUICK").is_ok();

    // Part 1: the paper-scale Fig 9 table (modeled; printed as report).
    if !quick {
        println!("{}", chameleon::report::fig9_search_latency(10_000, 64, 42));
    }

    // Part 2: measured host-side scan costs backing the model's shapes.
    let mut bench = Bench::new("measured_adc_scan");
    let mut rng = Rng::new(1);
    let mut gb_per_s: BTreeMap<String, Json> = BTreeMap::new();
    for ds in DATASETS {
        // ~codes per probed query at paper scale, sharded.
        let n = if quick { 20_000 } else { 60_000 };
        let codes: Vec<u8> = (0..n * ds.m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..ds.m * 256).map(|_| rng.f32()).collect();
        let mut out = vec![0.0f32; n];
        let s = bench.case(&format!("native_m{}_{}k", ds.m, n / 1000), || {
            adc_scan_into(&codes, n, ds.m, &lut, &mut out);
            out[0]
        });
        let bytes = (n * ds.m) as f64;
        let gbs = bytes / s.p50 / 1e9;
        println!(
            "    -> {gbs:.2} GB/s/core (paper calibration: ~1 GB/s/core SIMD)"
        );
        // Keyed by dataset AND m: SIFT and Deep share m=16 and must both
        // stay visible in the tracked record.
        gb_per_s.insert(format!("{}_m{}", ds.name, ds.m), Json::Num(gbs));
    }

    // Part 2b: the zero-copy scan-pipeline A/B.
    let (ab, single_speedup, batch_speedup) = scan_pipeline_ab(quick);

    // Part 2c: scalar-vs-SIMD kernel ablation (ISSUE 8).
    let (simd_json, simd_speedups) = simd_ablation(quick);

    // Machine-readable §Perf record for the cross-PR trajectory — written
    // *before* the acceptance asserts so a failing bar still uploads the
    // numbers that explain it.
    let report = obj(vec![
        ("bench", Json::Str("scan_pipeline".to_string())),
        ("quick", Json::Bool(quick)),
        ("gb_per_s", Json::Obj(gb_per_s)),
        ("scan_pipeline", ab),
        ("simd_ablation", simd_json),
    ]);
    std::fs::write("BENCH_scan.json", report.dump()).expect("writing BENCH_scan.json");
    println!("\nwrote BENCH_scan.json");

    // Acceptance bars (ISSUE 4).
    assert!(
        single_speedup >= 1.3,
        "gather-free fused path must be >= 1.3x the legacy copy-then-scan \
         wall per query, got {single_speedup:.2}x"
    );
    assert!(
        batch_speedup >= 1.5,
        "list-major batched round at B=8 must be >= 1.5x the query-major \
         round's throughput, got {batch_speedup:.2}x"
    );

    // SIMD floor (ISSUE 8): >= 2x GB/s/core over the scalar unrolled
    // kernels at m=16/32. Only meaningful when a SIMD ISA is active —
    // forced-scalar runs and SIMD-less hosts skip with a printed reason.
    if simd::active().kind == IsaKind::Scalar {
        println!(
            "simd-vs-scalar floor skipped: active kernel set is scalar \
             (forced via env, or no SIMD ISA detected on this host)"
        );
    } else {
        for &(m, s) in &simd_speedups {
            if m == 64 {
                continue; // L1-blocked m=64 is reported, not gated
            }
            assert!(
                s >= 2.0,
                "SIMD ADC scan at m={m} must be >= 2x scalar GB/s/core, got {s:.2}x"
            );
        }
    }

    if quick {
        return;
    }

    // Part 3: end-to-end measured search through the dispatcher.
    let ds = &chameleon::config::SIFT;
    let data = SyntheticDataset::generate_sized(ds, 20_000, 64, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 141, 5);
    let mut bench = Bench::new("measured_end_to_end_search");
    for kind in BackendKind::ALL {
        let nodes =
            vec![MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, 100)];
        let mut backend =
            SearchBackend::new(kind, ds, Dispatcher::new(nodes, 100), true);
        let mut qi = 0usize;
        bench.case(kind.name(), || {
            qi = (qi + 1) % data.n_queries;
            backend.search(&index, data.query(qi), 100).unwrap().1.total()
        });
    }

    // Part 3b: parallel dispatch over 4 nodes — per-query wall (max
    // across nodes) vs cpu (sum across nodes), single and batched.
    let nodes: Vec<MemoryNode> = (0..4)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 4), ScanEngine::Native, 100))
        .collect();
    let mut disp = chameleon::chamvs::Dispatcher::new(nodes, 100);
    let queries: Vec<Vec<f32>> = (0..data.n_queries)
        .map(|i| data.query(i).to_vec())
        .collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    let mut bench = Bench::new("measured_parallel_dispatch_4nodes");
    let mut qi = 0usize;
    bench.case("single_query_round", || {
        qi = (qi + 1) % queries.len();
        let r = disp
            .search(&queries[qi], &index.pq.centroids, &lists[qi], ds.nprobe)
            .unwrap();
        (r.measured_wall_s, r.measured_cpu_s)
    });
    let mut start = 0usize;
    bench.case("batch8_round", || {
        let batch: Vec<chameleon::chamvs::BatchQuery> = (0..8)
            .map(|j| {
                let i = (start + j) % queries.len();
                chameleon::chamvs::BatchQuery {
                    query: &queries[i],
                    lists: &lists[i],
                    trace_id: 0,
                }
            })
            .collect();
        start = (start + 8) % queries.len();
        disp.search_batch(&batch, &index.pq.centroids, ds.nprobe).unwrap().len()
    });
    let r = disp
        .search(&queries[0], &index.pq.centroids, &lists[0], ds.nprobe)
        .unwrap();
    println!(
        "    -> sample query: wall {:.4} ms (max across nodes) vs cpu {:.4} ms (sum)",
        r.measured_wall_s * 1e3,
        r.measured_cpu_s * 1e3
    );

    // Part 4: LUT construction cost (shared stage of every backend).
    let mut bench = Bench::new("measured_lut_build");
    for ds in DATASETS {
        let q: Vec<f32> = (0..ds.d).map(|_| rng.f32()).collect();
        let cb = chameleon::pq::codebook::PqCodebook {
            d: ds.d,
            m: ds.m,
            centroids: (0..ds.m * 256 * ds.dsub()).map(|_| rng.f32()).collect(),
        };
        bench.case(&format!("m{}_d{}", ds.m, ds.d), || build_lut(&cb, &q));
    }
}
