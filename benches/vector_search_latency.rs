//! Bench: Fig 9 — vector search latency for the four system
//! configurations across datasets and batch sizes, plus the *measured*
//! hot-path costs on this host (native ADC scan, LUT build, end-to-end
//! dispatcher search).
//!
//! Run: `cargo bench --bench vector_search_latency`

use chameleon::chamvs::backend::{BackendKind, SearchBackend};
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config::DATASETS;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::pq::scan::{adc_scan_into, build_lut};
use chameleon::util::rng::Rng;
use chameleon::util::timer::Bench;

fn main() {
    // Part 1: the paper-scale Fig 9 table (modeled; printed as report).
    println!("{}", chameleon::report::fig9_search_latency(10_000, 64, 42));

    // Part 2: measured host-side scan costs backing the model's shapes.
    let mut bench = Bench::new("measured_adc_scan");
    let mut rng = Rng::new(1);
    for ds in DATASETS {
        let n = 60_000; // ~codes per probed query at paper scale, sharded
        let codes: Vec<u8> = (0..n * ds.m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..ds.m * 256).map(|_| rng.f32()).collect();
        let mut out = vec![0.0f32; n];
        let s = bench.case(&format!("native_m{}_60k", ds.m), || {
            adc_scan_into(&codes, n, ds.m, &lut, &mut out);
            out[0]
        });
        let bytes = (n * ds.m) as f64;
        println!(
            "    -> {:.2} GB/s/core (paper calibration: ~1 GB/s/core SIMD)",
            bytes / s.p50 / 1e9
        );
    }

    // Part 3: end-to-end measured search through the dispatcher.
    let ds = &chameleon::config::SIFT;
    let data = SyntheticDataset::generate_sized(ds, 20_000, 64, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 141, 5);
    let mut bench = Bench::new("measured_end_to_end_search");
    for kind in BackendKind::ALL {
        let nodes =
            vec![MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, 100)];
        let mut backend =
            SearchBackend::new(kind, ds, Dispatcher::new(nodes, 100), true);
        let mut qi = 0usize;
        bench.case(kind.name(), || {
            qi = (qi + 1) % data.n_queries;
            backend.search(&index, data.query(qi), 100).unwrap().1.total()
        });
    }

    // Part 4: LUT construction cost (shared stage of every backend).
    let mut bench = Bench::new("measured_lut_build");
    for ds in DATASETS {
        let q: Vec<f32> = (0..ds.d).map(|_| rng.f32()).collect();
        let cb = chameleon::pq::codebook::PqCodebook {
            d: ds.d,
            m: ds.m,
            centroids: (0..ds.m * 256 * ds.dsub()).map(|_| rng.f32()).collect(),
        };
        bench.case(&format!("m{}_d{}", ds.m, ds.d), || build_lut(&cb, &q));
    }
}
