//! Bench: Fig 9 — vector search latency for the four system
//! configurations across datasets and batch sizes, plus the *measured*
//! hot-path costs on this host (native ADC scan, LUT build, end-to-end
//! dispatcher search).
//!
//! Run: `cargo bench --bench vector_search_latency`

use chameleon::chamvs::backend::{BackendKind, SearchBackend};
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config::DATASETS;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::pq::scan::{adc_scan_into, build_lut};
use chameleon::util::rng::Rng;
use chameleon::util::timer::Bench;

fn main() {
    // Part 1: the paper-scale Fig 9 table (modeled; printed as report).
    println!("{}", chameleon::report::fig9_search_latency(10_000, 64, 42));

    // Part 2: measured host-side scan costs backing the model's shapes.
    let mut bench = Bench::new("measured_adc_scan");
    let mut rng = Rng::new(1);
    for ds in DATASETS {
        let n = 60_000; // ~codes per probed query at paper scale, sharded
        let codes: Vec<u8> = (0..n * ds.m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..ds.m * 256).map(|_| rng.f32()).collect();
        let mut out = vec![0.0f32; n];
        let s = bench.case(&format!("native_m{}_60k", ds.m), || {
            adc_scan_into(&codes, n, ds.m, &lut, &mut out);
            out[0]
        });
        let bytes = (n * ds.m) as f64;
        println!(
            "    -> {:.2} GB/s/core (paper calibration: ~1 GB/s/core SIMD)",
            bytes / s.p50 / 1e9
        );
    }

    // Part 3: end-to-end measured search through the dispatcher.
    let ds = &chameleon::config::SIFT;
    let data = SyntheticDataset::generate_sized(ds, 20_000, 64, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 141, 5);
    let mut bench = Bench::new("measured_end_to_end_search");
    for kind in BackendKind::ALL {
        let nodes =
            vec![MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, 100)];
        let mut backend =
            SearchBackend::new(kind, ds, Dispatcher::new(nodes, 100), true);
        let mut qi = 0usize;
        bench.case(kind.name(), || {
            qi = (qi + 1) % data.n_queries;
            backend.search(&index, data.query(qi), 100).unwrap().1.total()
        });
    }

    // Part 3b: parallel dispatch over 4 nodes — per-query wall (max
    // across nodes) vs cpu (sum across nodes), single and batched.
    let nodes: Vec<MemoryNode> = (0..4)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 4), ScanEngine::Native, 100))
        .collect();
    let mut disp = chameleon::chamvs::Dispatcher::new(nodes, 100);
    let queries: Vec<Vec<f32>> = (0..data.n_queries)
        .map(|i| data.query(i).to_vec())
        .collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    let mut bench = Bench::new("measured_parallel_dispatch_4nodes");
    let mut qi = 0usize;
    bench.case("single_query_round", || {
        qi = (qi + 1) % queries.len();
        let r = disp
            .search(&queries[qi], &index.pq.centroids, &lists[qi], ds.nprobe)
            .unwrap();
        (r.measured_wall_s, r.measured_cpu_s)
    });
    let mut start = 0usize;
    bench.case("batch8_round", || {
        let batch: Vec<chameleon::chamvs::BatchQuery> = (0..8)
            .map(|j| {
                let i = (start + j) % queries.len();
                chameleon::chamvs::BatchQuery {
                    query: &queries[i],
                    lists: &lists[i],
                }
            })
            .collect();
        start = (start + 8) % queries.len();
        disp.search_batch(&batch, &index.pq.centroids, ds.nprobe).unwrap().len()
    });
    let r = disp
        .search(&queries[0], &index.pq.centroids, &lists[0], ds.nprobe)
        .unwrap();
    println!(
        "    -> sample query: wall {:.4} ms (max across nodes) vs cpu {:.4} ms (sum)",
        r.measured_wall_s * 1e3,
        r.measured_cpu_s * 1e3
    );

    // Part 4: LUT construction cost (shared stage of every backend).
    let mut bench = Bench::new("measured_lut_build");
    for ds in DATASETS {
        let q: Vec<f32> = (0..ds.d).map(|_| rng.f32()).collect();
        let cb = chameleon::pq::codebook::PqCodebook {
            d: ds.d,
            m: ds.m,
            centroids: (0..ds.m * 256 * ds.dsub()).map(|_| rng.f32()).collect(),
        };
        bench.case(&format!("m{}_d{}", ds.m, ds.d), || build_lut(&cb, &q));
    }
}
