//! Bench: Fig 13 — the optimal GPU:ChamVS accelerator ratio across RALM
//! configurations, plus a partitioning-policy ablation (vector-sharded vs
//! list-sharded load balance — DESIGN.md Sec 7).
//!
//! Run: `cargo bench --bench accelerator_ratio`

use chameleon::ivf::layout::{scan_load_per_node, Partitioning};
use chameleon::util::rng::Rng;

fn main() {
    println!("{}", chameleon::report::fig13_ratio());

    // Ablation: load imbalance of the two partitioning schemes of Sec 4.3
    // over realistic skewed list sizes.
    println!("== ablation: partitioning load balance (max/mean per node) ==");
    println!("nodes  vector-sharded  list-sharded");
    let mut rng = Rng::new(9);
    // Zipf-ish list sizes: realistic IVF imbalance.
    let list_sizes: Vec<usize> =
        (0..1024).map(|i| 2000 / (1 + i % 37) + rng.below(500)).collect();
    for &n_nodes in &[2usize, 4, 8, 16] {
        let mut worst = [0.0f64; 2];
        for _ in 0..200 {
            let probed: Vec<u32> =
                (0..32).map(|_| rng.below(1024) as u32).collect();
            for (i, part) in
                [Partitioning::VectorSharded, Partitioning::ListSharded].iter().enumerate()
            {
                let load = scan_load_per_node(&list_sizes, &probed, n_nodes, *part);
                let max = *load.iter().max().unwrap() as f64;
                let mean =
                    load.iter().sum::<usize>() as f64 / n_nodes as f64;
                worst[i] = worst[i].max(max / mean.max(1.0));
            }
        }
        println!("{n_nodes:<6} {:<15.2} {:<12.2}", worst[0], worst[1]);
    }
    println!("(paper Sec 4.3: vector sharding keeps load always balanced)");
}
