//! Bench: the elastic retrieval tier — failover correctness/cost and the
//! hedged-dispatch tail-latency A/B (EXPERIMENTS.md §Cluster).
//!
//! Part 1 (failover): a 2-shard x 2-replica in-process cluster loses one
//! node mid-workload; every query must still succeed with top-k
//! bit-identical to a flat single-replica reference.
//!
//! Part 2 (hedging): one replica of shard 0 is an intermittent straggler
//! (sleeps 25 ms on every 5th scan). Static primary selection pins it as
//! primary in both arms, so the A/B isolates hedging: the no-hedge arm
//! eats the straggle at p99, the hedged arm fires a duplicate scan to the
//! healthy replica at the recent-p25 deadline and takes the first
//! response. The p99 improvement is asserted (>= 1.5x) *after*
//! `BENCH_cluster.json` is written, so a failing bar still uploads the
//! numbers that explain it.
//!
//! Part 3 (degraded serving): with a whole shard dark, FailFast vs
//! ServePartial availability A/B, plus an end-to-end deadline bounding a
//! straggling round's p99 to within 2x the budget.
//!
//! Run: `cargo bench --bench cluster_failover`
//! Quick CI profile: `CHAM_BENCH_QUICK=1 cargo bench --bench cluster_failover`

use std::time::{Duration, Instant};

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::chamvs::ScanBackend;
use chameleon::cluster::{
    ClusterConfig, ClusterEngine, ClusterMap, ClusterNode, DegradedPolicy,
    FailingBackend, HedgeConfig, RoundOptions, SelectPolicy, StragglerBackend,
};
use chameleon::config::SIFT;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::util::json::{obj, Json};
use chameleon::util::stats::Summary;

fn mk_node(index: &IvfPqIndex, shard: usize, n_shards: usize, k: usize) -> Box<dyn ScanBackend> {
    Box::new(MemoryNode::new(
        Shard::carve(index, shard, n_shards),
        ScanEngine::Native,
        k,
    ))
}

struct Workload {
    index: IvfPqIndex,
    queries: Vec<Vec<f32>>,
    lists: Vec<Vec<u32>>,
    k: usize,
}

fn build_workload(n: usize, n_queries: usize) -> Workload {
    let ds = &SIFT;
    let data = SyntheticDataset::generate_sized(ds, n, n_queries, 7);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 96, 9);
    let queries: Vec<Vec<f32>> =
        (0..n_queries).map(|i| data.query(i).to_vec()).collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    Workload { index, queries, lists, k: 10 }
}

/// Part 1: kill one node mid-workload at replication 2; count failures
/// and result divergence against the flat reference.
fn failover_part(w: &Workload) -> Json {
    let (n_nodes, replication) = (4usize, 2usize);
    let n_shards = n_nodes / replication;
    let nodes_flat: Vec<MemoryNode> = (0..n_shards)
        .map(|s| MemoryNode::new(Shard::carve(&w.index, s, n_shards), ScanEngine::Native, w.k))
        .collect();
    let mut flat = Dispatcher::new(nodes_flat, w.k);
    let nprobe = SIFT.nprobe;
    let want: Vec<Vec<(f32, u64)>> = w
        .queries
        .iter()
        .zip(&w.lists)
        .map(|(q, l)| {
            flat.search(q, &w.index.pq.centroids, l, nprobe).unwrap().topk
        })
        .collect();

    // Static selection pins the victim as shard 0's primary, so it
    // deterministically serves every shard-0 round until it dies at
    // `kill_at` — health-aware selection is sticky (only the serving
    // replica's EWMA warms) and could starve the victim of scans, making
    // the mid-run death a coin flip instead of a certainty.
    let kill_at = w.queries.len() / 6;
    let plan = ClusterMap::carve_plan(n_nodes, replication).unwrap();
    let nodes: Vec<ClusterNode> = plan
        .into_iter()
        .map(|(id, shard)| {
            let backend = mk_node(&w.index, shard, n_shards, w.k);
            let backend = if id == 0 {
                Box::new(FailingBackend::new(backend, kill_at)) as Box<dyn ScanBackend>
            } else {
                backend
            };
            ClusterNode { id, shard, backend }
        })
        .collect();
    let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
    let engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
    let mut disp = Dispatcher::clustered(engine, w.k);

    let mut failed = 0usize;
    let mut diverged = 0usize;
    let t0 = Instant::now();
    for ((q, l), wtop) in w.queries.iter().zip(&w.lists).zip(&want) {
        match disp.search(q, &w.index.pq.centroids, l, nprobe) {
            Ok(r) => {
                if &r.topk != wtop {
                    diverged += 1;
                }
            }
            Err(_) => failed += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = disp.cluster().unwrap().stats();
    println!(
        "  failover: {} queries, {failed} failed, {diverged} diverged, \
         {} retries, {} failovers ({:.1} ms total)",
        w.queries.len(),
        stats.retries,
        stats.failovers,
        wall * 1e3
    );
    assert_eq!(failed, 0, "replication 2 must absorb a single node death");
    assert_eq!(diverged, 0, "failover results must stay bit-identical");
    assert!(stats.failovers >= 1, "the dead node's replica must serve");
    obj(vec![
        ("queries", Json::Num(w.queries.len() as f64)),
        ("failed", Json::Num(failed as f64)),
        ("diverged", Json::Num(diverged as f64)),
        ("retries", Json::Num(stats.retries as f64)),
        ("failovers", Json::Num(stats.failovers as f64)),
        ("breaker_trips", Json::Num(stats.breaker_trips as f64)),
        ("wall_s", Json::Num(wall)),
    ])
}

/// One hedging arm: per-query latency samples under an injected
/// intermittent straggler, hedged or not.
fn hedge_arm(w: &Workload, hedge: bool, straggle: Duration, every: usize) -> (Summary, u64) {
    let nodes = vec![
        ClusterNode {
            id: 0,
            shard: 0,
            backend: Box::new(StragglerBackend::new(
                mk_node(&w.index, 0, 1, w.k),
                straggle,
                every,
            )) as Box<dyn ScanBackend>,
        },
        ClusterNode { id: 1, shard: 0, backend: mk_node(&w.index, 0, 1, w.k) },
    ];
    let cfg = ClusterConfig {
        // Static selection pins the straggler as primary in BOTH arms:
        // the A/B isolates hedging from health-aware routing (which
        // handles *persistent* slowness; hedging exists for the
        // unpredictable straggles selection cannot foresee).
        select: SelectPolicy::Static,
        hedge: hedge.then_some(HedgeConfig {
            quantile: 0.25,
            floor: Duration::from_micros(100),
        }),
        ..Default::default()
    };
    let engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
    let mut disp = Dispatcher::clustered(engine, w.k);
    let nprobe = SIFT.nprobe;
    // Warm the recent-latency window so the hedged arm has a deadline.
    for i in 0..12 {
        let qi = i % w.queries.len();
        disp.search(&w.queries[qi], &w.index.pq.centroids, &w.lists[qi], nprobe)
            .unwrap();
    }
    let mut samples = Vec::with_capacity(w.queries.len());
    for (q, l) in w.queries.iter().zip(&w.lists) {
        let t0 = Instant::now();
        disp.search(q, &w.index.pq.centroids, l, nprobe).unwrap();
        samples.push(t0.elapsed().as_secs_f64());
    }
    (Summary::of(&samples), disp.cluster().unwrap().stats().hedges)
}

/// One degraded-policy arm over a cluster whose shard 0 is completely
/// dark (both replicas dead from the first scan). Returns
/// (answered, partial, latency) — FailFast answers nothing that touches
/// the dark shard (i.e. nothing: every round fans out to all shards),
/// ServePartial answers everything as a coverage-tagged partial.
fn dark_shard_arm(w: &Workload, policy: DegradedPolicy) -> (usize, usize, Summary) {
    let (n_nodes, replication) = (4usize, 2usize);
    let n_shards = n_nodes / replication;
    let plan = ClusterMap::carve_plan(n_nodes, replication).unwrap();
    let nodes: Vec<ClusterNode> = plan
        .into_iter()
        .map(|(id, shard)| {
            let backend = mk_node(&w.index, shard, n_shards, w.k);
            let backend = if shard == 0 {
                Box::new(FailingBackend::new(backend, 0)) as Box<dyn ScanBackend>
            } else {
                backend
            };
            ClusterNode { id, shard, backend }
        })
        .collect();
    let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
    let engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
    let mut disp = Dispatcher::clustered(engine, w.k);
    let nprobe = SIFT.nprobe;
    let opts = RoundOptions { degraded: policy, deadline: None };
    let (mut ok, mut partial) = (0usize, 0usize);
    let mut samples = Vec::with_capacity(w.queries.len());
    for (qi, (q, l)) in w.queries.iter().zip(&w.lists).enumerate() {
        let t0 = Instant::now();
        if let Ok(r) =
            disp.search_opts(q, &w.index.pq.centroids, l, nprobe, qi as u64, &opts)
        {
            ok += 1;
            if r.is_partial() {
                partial += 1;
            }
        }
        samples.push(t0.elapsed().as_secs_f64());
    }
    (ok, partial, Summary::of(&samples))
}

/// Deadline arm: the only replica straggles on every scan; an end-to-end
/// budget plus ServePartial must bound the round at the deadline instead
/// of eating the full straggle. Returns (partials, latency).
fn deadline_arm(w: &Workload, budget: Duration, straggle: Duration) -> (usize, Summary) {
    let nodes = vec![ClusterNode {
        id: 0,
        shard: 0,
        backend: Box::new(StragglerBackend::new(mk_node(&w.index, 0, 1, w.k), straggle, 1))
            as Box<dyn ScanBackend>,
    }];
    let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
    let engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
    let mut disp = Dispatcher::clustered(engine, w.k);
    let nprobe = SIFT.nprobe;
    let mut partials = 0usize;
    let mut samples = Vec::with_capacity(w.queries.len());
    for (qi, (q, l)) in w.queries.iter().zip(&w.lists).enumerate() {
        let opts = RoundOptions {
            degraded: DegradedPolicy::ServePartial { min_coverage: 0.0 },
            deadline: Some(Instant::now() + budget),
        };
        let t0 = Instant::now();
        if let Ok(r) =
            disp.search_opts(q, &w.index.pq.centroids, l, nprobe, qi as u64, &opts)
        {
            if r.is_partial() {
                partials += 1;
            }
        }
        samples.push(t0.elapsed().as_secs_f64());
    }
    (partials, Summary::of(&samples))
}

fn main() {
    let quick = std::env::var("CHAM_BENCH_QUICK").is_ok();
    let (n, n_queries) = if quick { (6_000, 60) } else { (12_000, 150) };
    println!("== bench group: cluster_failover (n={n}, q={n_queries}) ==");
    let w = build_workload(n, n_queries);

    // Part 1: failover correctness under a mid-workload node death.
    let failover = failover_part(&w);

    // Part 2: hedged-dispatch tail-latency A/B under an intermittent
    // straggler (25 ms sleep on every 5th scan of shard 0's primary).
    let straggle = Duration::from_millis(25);
    let every = 5;
    let (no_hedge, _) = hedge_arm(&w, false, straggle, every);
    let (hedged, hedges_fired) = hedge_arm(&w, true, straggle, every);
    let improvement = no_hedge.p99 / hedged.p99.max(1e-9);
    println!("{}", no_hedge.render_ms("no_hedge"));
    println!("{}", hedged.render_ms(&format!("hedged ({hedges_fired} fired)")));
    println!("    -> p99 improvement: {improvement:.2}x (bar: 1.5x)");

    // Part 3: degraded-mode ablation (ISSUE 9). Shard 0 is completely
    // dark (both replicas dead): FailFast loses every query, ServePartial
    // answers all of them at coverage 1/2. Then the deadline arm bounds a
    // straggling round at an end-to-end budget.
    let (ff_ok, _, ff_lat) = dark_shard_arm(&w, DegradedPolicy::FailFast);
    let (sp_ok, sp_partial, sp_lat) =
        dark_shard_arm(&w, DegradedPolicy::ServePartial { min_coverage: 0.0 });
    println!(
        "  dark shard: fail_fast answered {ff_ok}/{} (p99 {:.2} ms), \
         serve_partial answered {sp_ok}/{} ({sp_partial} partial, p99 {:.2} ms)",
        w.queries.len(),
        ff_lat.p99 * 1e3,
        w.queries.len(),
        sp_lat.p99 * 1e3,
    );
    assert_eq!(ff_ok, 0, "FailFast must drop every round touching the dark shard");
    assert_eq!(sp_ok, w.queries.len(), "ServePartial must answer every round");
    assert_eq!(sp_partial, w.queries.len(), "every answer must be coverage-tagged");

    let budget = Duration::from_millis(10);
    let (dl_partials, dl_lat) = deadline_arm(&w, budget, straggle);
    println!(
        "  deadline: {:.0} ms budget under a 25 ms every-scan straggler -> \
         p99 {:.2} ms, {dl_partials}/{} partial (bar: p99 <= 2x budget)",
        budget.as_secs_f64() * 1e3,
        dl_lat.p99 * 1e3,
        w.queries.len(),
    );

    // Machine-readable record, written BEFORE the acceptance assert so a
    // failing bar still leaves the numbers that explain it (house rule
    // from BENCH_scan.json).
    let report = obj(vec![
        ("bench", Json::Str("cluster_failover".to_string())),
        ("quick", Json::Bool(quick)),
        ("failover", failover),
        (
            "hedge",
            obj(vec![
                ("straggle_ms", Json::Num(straggle.as_secs_f64() * 1e3)),
                ("straggle_every", Json::Num(every as f64)),
                ("hedges_fired", Json::Num(hedges_fired as f64)),
                ("no_hedge_p50_ms", Json::Num(no_hedge.p50 * 1e3)),
                ("no_hedge_p99_ms", Json::Num(no_hedge.p99 * 1e3)),
                ("hedged_p50_ms", Json::Num(hedged.p50 * 1e3)),
                ("hedged_p99_ms", Json::Num(hedged.p99 * 1e3)),
                ("p99_improvement", Json::Num(improvement)),
            ]),
        ),
        (
            "degraded",
            obj(vec![
                ("fail_fast_answered", Json::Num(ff_ok as f64)),
                ("fail_fast_p99_ms", Json::Num(ff_lat.p99 * 1e3)),
                ("serve_partial_answered", Json::Num(sp_ok as f64)),
                ("serve_partial_partial", Json::Num(sp_partial as f64)),
                ("serve_partial_p99_ms", Json::Num(sp_lat.p99 * 1e3)),
                ("deadline_budget_ms", Json::Num(budget.as_secs_f64() * 1e3)),
                ("deadline_p99_ms", Json::Num(dl_lat.p99 * 1e3)),
                ("deadline_partials", Json::Num(dl_partials as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_cluster.json", report.dump())
        .expect("writing BENCH_cluster.json");
    println!("\nwrote BENCH_cluster.json");

    // Acceptance bar (ISSUE 5): hedged dispatch must show a measured p99
    // improvement under the injected straggler.
    assert!(
        improvement >= 1.5,
        "hedged dispatch must improve p99 by >= 1.5x under the injected \
         straggler, got {improvement:.2}x"
    );

    // Acceptance bar (ISSUE 9): an end-to-end budget must bound the tail
    // of a straggling round — p99 within 2x the budget, not the straggle.
    assert!(
        dl_lat.p99 <= 2.0 * budget.as_secs_f64(),
        "deadline must bound the round: p99 {:.2} ms > 2x {:.0} ms budget",
        dl_lat.p99 * 1e3,
        budget.as_secs_f64() * 1e3
    );
}
