//! Bench: Table 4 + Table 5 — FPGA resource fractions and energy per
//! query, plus the TPU roofline estimates for the L1 kernels
//! (DESIGN.md Sec 8 — interpret=True forbids wallclock TPU numbers, so
//! structure-derived estimates are the deliverable).
//!
//! Run: `cargo bench --bench energy`

use chameleon::config::DATASETS;
use chameleon::hwmodel::fpga::FpgaModel;
use chameleon::hwmodel::tpu;

fn main() {
    println!("{}", chameleon::report::table4_resources());
    println!("{}", chameleon::report::table5_energy());

    // Sec 6.2 cost-efficiency discussion: "increasing the number of
    // memory channels to, e.g., 12, would lead to around 3x PQ-code scan
    // performance", and HBM-class bandwidth beyond that.
    println!("== ablation: memory-system variants (SIFT paper-scale scan) ==");
    println!("variant           channels  scan GB/s  query_ms  speedup");
    let codes = (1e9 * 32.0 / 32768.0) as usize;
    let base = FpgaModel::default();
    let base_ms = base.query_latency(codes, 16, 32, 100).total() * 1e3;
    for (name, channels, clock) in [
        ("U250 (paper)", 4usize, 140e6),
        ("12-channel", 12, 140e6),
        ("HBM-class", 32, 225e6),
    ] {
        let f = FpgaModel { n_channels: channels, clock_hz: clock, ..base };
        let ms = f.query_latency(codes, 16, 32, 100).total() * 1e3;
        println!(
            "{name:<17} {channels:<9} {:<10.1} {ms:<9.3} {:.2}x",
            f.scan_bandwidth() / 1e9,
            base_ms / ms
        );
    }
    println!();

    println!("== TPU roofline estimates for L1 kernels (per query) ==");
    println!("kernel           flops      hbm_bytes  AI     vmem/tile  mxu_util  est_us");
    for ds in DATASETS {
        let n = (ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64) as usize;
        let e = tpu::adc_scan_estimate(n, ds.m, tpu::adc_n_tile(ds.m));
        println!(
            "adc_scan_{:<7} {:>10.2e} {:>10.2e} {:>6.1} {:>10.2e} {:>8.4} {:>7.1}",
            ds.name,
            e.flops,
            e.hbm_bytes,
            e.intensity(),
            e.vmem_bytes_per_tile,
            e.mxu_utilization,
            e.latency_s() * 1e6,
        );
        assert!(e.fits_vmem());
    }
    for ds in DATASETS {
        let e = tpu::lut_estimate(ds.m, ds.dsub());
        println!(
            "lut_{:<12} {:>10.2e} {:>10.2e} {:>6.1} {:>10.2e} {:>8} {:>7.2}",
            ds.name,
            e.flops,
            e.hbm_bytes,
            e.intensity(),
            e.vmem_bytes_per_tile,
            "vpu",
            e.latency_s() * 1e6,
        );
    }
    let e = tpu::ivf_scan_estimate(1, 32_768, 512, 1024);
    println!(
        "ivf_scan_b1      {:>10.2e} {:>10.2e} {:>6.1} {:>10.2e} {:>8.1} {:>7.1}",
        e.flops,
        e.hbm_bytes,
        e.intensity(),
        e.vmem_bytes_per_tile,
        e.mxu_utilization,
        e.latency_s() * 1e6,
    );
}
