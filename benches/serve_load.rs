//! Bench: open-loop offered-load sweep against the traced concurrent
//! coordinator (EXPERIMENTS.md §Serve).
//!
//! A short saturation burst first estimates the server's capacity, then
//! the sweep offers 0.25x / 0.5x / 1x / 2x that estimate. Open-loop
//! arrivals keep sending on schedule regardless of replies, so the
//! latency-vs-load curve shows the real knee: goodput flattens at
//! capacity while p99 (measured from the *scheduled* arrival) blows up
//! past it. The per-stage trace left by the run is fitted into the
//! LogGP/M/M/1 capacity planner, whose predicted knee must land within
//! 6x of the measured one — a deliberately loose band (the model ignores
//! batching overlap) that still pins the order of magnitude.
//!
//! `BENCH_serve.json` is written BEFORE the acceptance asserts, so a
//! failing bar still uploads the numbers that explain it.
//!
//! Run: `cargo bench --bench serve_load`
//! Quick CI profile: `CHAM_BENCH_QUICK=1 cargo bench --bench serve_load`

use std::time::Duration;

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorServer, ServeMode};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::hwmodel::{CapacityPlanner, StageTimes};
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::loadgen::{drive, measured_knee_qps, schedule, LoadgenConfig, OpenLoopReport};
use chameleon::trace::{analyze, SpanKind, Tracer};
use chameleon::util::json::{obj, Json};

const NODES: usize = 2;
const K: usize = 10;

fn build_retriever(n: usize, seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, n, 16, seed);
    let nlist = (n as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..NODES)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, NODES), ScanEngine::Native, K))
        .collect();
    let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, K), corpus)
}

fn run_point(
    addr: std::net::SocketAddr,
    queries: &[Vec<f32>],
    qps: f64,
    n_requests: usize,
    seed: u64,
) -> OpenLoopReport {
    let cfg = LoadgenConfig {
        qps,
        n_requests,
        n_unique: queries.len(),
        seed,
        ..LoadgenConfig::default()
    };
    let sched = schedule(&cfg);
    let deadline = Duration::from_secs_f64(sched.span_s() + 30.0);
    drive(addr, queries, K, &sched, 4, deadline).expect("open-loop run")
}

fn main() {
    let quick = std::env::var("CHAM_BENCH_QUICK").is_ok();
    let (n, reqs) = if quick { (4_000, 150) } else { (8_000, 400) };
    println!("== bench group: serve_load (n={n}, reqs/point={reqs}) ==");

    let retriever = build_retriever(n, 7);
    let tracer = Tracer::new(1 << 17);
    let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) };
    let mut server = CoordinatorServer::spawn_traced(
        move || retriever,
        ServeMode::Concurrent(policy),
        tracer.clone(),
    )
    .unwrap();
    let addr = server.addr;

    let ds = config::dataset_by_name("SIFT").unwrap();
    let qdata = SyntheticDataset::generate_sized(ds, 64, 64, 9);
    let queries: Vec<Vec<f32>> =
        (0..64).map(|i| qdata.query(i % qdata.n_queries).to_vec()).collect();

    // Throwaway warmup (connection setup, page cache, allocator arenas),
    // then a saturation burst: offer far beyond capacity; goodput ~
    // capacity.
    run_point(addr, &queries, 500.0, 50, 0);
    let calib = run_point(addr, &queries, 50_000.0, reqs, 1);
    let cap = calib.goodput_qps;
    println!("  calibration: ~{cap:.0} q/s capacity estimate");

    let mut points = Vec::new();
    let mut sweep = Vec::new();
    for (i, frac) in [0.25, 0.5, 1.0, 2.0].iter().enumerate() {
        let qps = (cap * frac).max(10.0);
        let rep = run_point(addr, &queries, qps, reqs, 2 + i as u64);
        println!(
            "  offered {:>7.0} q/s -> goodput {:>7.0} q/s  p50 {:8.2} ms  p99 {:8.2} ms  ({}/{})",
            rep.offered_qps,
            rep.goodput_qps,
            rep.latency.p50 * 1e3,
            rep.latency.p99 * 1e3,
            rep.received,
            rep.sent,
        );
        points.push(obj(vec![
            ("offered_qps", Json::Num(rep.offered_qps)),
            ("goodput_qps", Json::Num(rep.goodput_qps)),
            ("received", Json::Num(rep.received as f64)),
            ("p50_ms", Json::Num(rep.latency.p50 * 1e3)),
            ("p99_ms", Json::Num(rep.latency.p99 * 1e3)),
        ]));
        sweep.push(rep);
    }
    let knee = measured_knee_qps(&sweep).max(calib.goodput_qps);
    server.shutdown();

    // Fit the capacity model from the spans the whole run left behind.
    let events = tracer.snapshot();
    let a = analyze(&events);
    print!("{}", a.render());
    let st = StageTimes::from_analysis(&a, NODES);
    let planner = CapacityPlanner::new(st, 4 * ds.d, 12 * K);
    let predicted = planner.saturation_qps(NODES);
    println!("  measured knee {knee:.0} q/s, planner-predicted {predicted:.0} q/s");

    let report = obj(vec![
        ("bench", Json::Str("serve_load".to_string())),
        ("quick", Json::Bool(quick)),
        ("n", Json::Num(n as f64)),
        ("nodes", Json::Num(NODES as f64)),
        ("requests_per_point", Json::Num(reqs as f64)),
        ("calibration_goodput_qps", Json::Num(cap)),
        ("sweep", Json::Arr(points)),
        ("measured_knee_qps", Json::Num(knee)),
        ("predicted_knee_qps", Json::Num(predicted)),
        (
            "stages",
            obj(vec![
                ("lut_s", Json::Num(st.lut_s)),
                ("scan_s", Json::Num(st.scan_s)),
                ("merge_s", Json::Num(st.merge_s)),
                ("reply_s", Json::Num(st.reply_s)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serve.json", report.dump()).expect("writing BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");

    // Acceptance: the sweep saw a real knee (goodput stops tracking
    // offered load) and latency degrades across it.
    assert!(knee > 0.0 && knee.is_finite(), "no measured knee");
    let under = &sweep[0];
    let over = &sweep[3];
    assert!(
        over.goodput_qps < over.offered_qps * 0.9,
        "2x-capacity point did not saturate: goodput {:.0} of offered {:.0}",
        over.goodput_qps,
        over.offered_qps
    );
    assert!(
        over.latency.p99 > under.latency.p99,
        "p99 must degrade past the knee: {:.2} ms vs {:.2} ms",
        over.latency.p99 * 1e3,
        under.latency.p99 * 1e3
    );
    // Core stages all traced.
    for kind in
        [SpanKind::QueueWait, SpanKind::LutBuild, SpanKind::NodeScan, SpanKind::Merge]
    {
        assert!(
            a.kinds_present().contains(&kind),
            "trace missing {} spans",
            kind.name()
        );
    }
    // The fitted planner pins the knee's order of magnitude.
    assert!(
        predicted >= knee / 6.0 && predicted <= knee * 6.0,
        "planner knee {predicted:.0} q/s outside 6x of measured {knee:.0} q/s"
    );
    println!("serve_load OK");
}
