//! Bench: Fig 11/12 — end-to-end RALM inference latency + throughput,
//! Chameleon (FPGA-GPU retrieval) vs the CPU-GPU baseline, plus measured
//! decode-step costs of the scaled models through PJRT.
//!
//! Run: `cargo bench --bench ralm_inference`

use chameleon::chamlm::worker::GpuWorker;
use chameleon::config;
use chameleon::runtime::Runtime;
use chameleon::util::timer::Bench;

fn main() {
    println!("{}", chameleon::report::fig11_latency(512));
    println!("{}", chameleon::report::fig12_throughput(512));

    // Measured: the scaled decode step through the AOT artifact (the
    // request-path cost the modeled numbers stand on).
    let artifacts =
        std::env::var("CHAMELEON_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let runtime = match Runtime::new(&artifacts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping measured section (run `make artifacts`): {e}");
            return;
        }
    };
    let mut bench = Bench::new("measured_decode_step");
    let mut w = GpuWorker::new(&runtime, &config::DEC_TINY, 0, 7).unwrap();
    let ids = vec![1u32; w.knn_k];
    let dd = vec![1.0f32; w.knn_k];
    let mut tok = 1u32;
    let s = bench.case_n("dec_tiny_b1", 3, 30, || {
        if w.steps as usize >= config::DEC_TINY.max_seq {
            w.reset();
        }
        let out = w.step(tok, (&ids, &dd)).unwrap();
        tok = (tok + 1) % 100;
        out.probs.len()
    });
    println!("    -> {:.1} tokens/s measured (scaled model, CPU PJRT)", 1.0 / s.p50);

    let mut we = GpuWorker::new(&runtime, &config::ENCDEC_TINY, 0, 7).unwrap();
    let chunks: Vec<u32> = (0..we.enc_tokens() as u32).collect();
    we.encode(&chunks).unwrap();
    let s = bench.case_n("encdec_tiny_b1", 3, 30, || {
        if we.steps as usize >= config::ENCDEC_TINY.max_seq {
            we.reset();
            we.encode(&chunks).unwrap();
        }
        we.step(1, (&[], &[])).unwrap().probs.len()
    });
    println!("    -> {:.1} tokens/s measured", 1.0 / s.p50);

    let mut bench = Bench::new("measured_encode");
    bench.case_n("encdec_tiny_encoder", 2, 15, || we.encode(&chunks).unwrap());
}
