//! Bench: Fig 7/8 — K-selection analysis: binomial distribution, resource
//! savings, the agreement rate of the approximate hierarchical queue, and
//! the ablation of DESIGN.md Sec 7 (approximate vs exact).
//!
//! Run: `cargo bench --bench kselection`

use chameleon::kselect::hierarchical::{agreement_rate, ApproxHierarchicalQueue};
use chameleon::kselect::HierarchicalConfig;
use chameleon::util::rng::Rng;
use chameleon::util::timer::Bench;

fn main() {
    println!("{}", chameleon::report::fig7_probability());
    println!("{}", chameleon::report::fig8_resources());

    // Ablation: exact vs approximate agreement + resources.
    println!("== ablation: approximate vs exact hierarchical queue ==");
    println!("lanes depth agree%   resource_units");
    for &lanes in &[4usize, 8, 16, 32] {
        for quantile in [0.9, 0.99, 0.999] {
            let cfg = HierarchicalConfig::approximate(100, lanes, quantile);
            let rate = agreement_rate(cfg, 8192, 200, 7);
            println!(
                "{lanes:<5} {:<5} {:<8.1} {} (target {quantile})",
                cfg.l1_depth,
                rate * 100.0,
                cfg.resource_units()
            );
        }
    }

    // Measured software throughput of the queue simulator (the hardware
    // rate is 1 element/lane/2 cycles by construction; this measures the
    // simulator itself, which sits on the measured request path).
    let mut bench = Bench::new("queue_sim_throughput");
    let mut rng = Rng::new(1);
    let dists: Vec<f32> = (0..65_536).map(|_| rng.f32()).collect();
    for &lanes in &[16usize, 32] {
        for (nm, cfg) in [
            ("exact", HierarchicalConfig::exact(100, lanes)),
            ("approx99", HierarchicalConfig::approximate(100, lanes, 0.99)),
        ] {
            let s = bench.case(&format!("{nm}_lanes{lanes}_64k"), || {
                let mut q = ApproxHierarchicalQueue::new(cfg);
                q.push_block(&dists, 0);
                q.finalize().len()
            });
            println!("    -> {:.1} M elems/s", dists.len() as f64 / s.p50 / 1e6);
        }
    }
}
