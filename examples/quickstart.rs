//! Quickstart: the smallest end-to-end Chameleon flow.
//!
//! Builds a scaled SIFT-like database, trains IVF-PQ from scratch, stands
//! up two disaggregated memory nodes + one ChamLM worker (the AOT-compiled
//! dec_tiny decode step via PJRT), and generates a retrieval-augmented
//! sequence.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use chameleon::chamlm::pool::WorkerPool;
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::engine::RalmEngine;
use chameleon::coordinator::retriever::Retriever;
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::runtime::Runtime;

fn main() -> chameleon::Result<()> {
    let seed = 42;
    let ds = config::dataset_by_name("SIFT").unwrap();

    // 1. Database: synthetic vectors + IVF-PQ index (built from scratch).
    println!("[1/4] generating data + training IVF-PQ ...");
    let data = SyntheticDataset::generate_sized(ds, 8000, 16, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 64, seed);
    println!("      {} vectors, {} lists, m={}", index.len(), index.nlist, index.m);

    // 2. ChamVS: two disaggregated memory nodes (vector-sharded).
    println!("[2/4] carving 2 memory-node shards ...");
    let k = config::DEC_TINY.k;
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, k))
        .collect();
    let dispatcher = Dispatcher::new(nodes, k);
    let corpus =
        Corpus::generate(data.n, config::DEC_TINY.vocab, config::CHUNK_LEN, seed);
    let mut retriever = Retriever::new(ds, index, dispatcher, corpus);

    // 3. One standalone retrieval, printed.
    println!("[3/4] one vector search:");
    let r = retriever.retrieve(data.query(0))?;
    println!("      top-5 ids {:?}", &r.ids[..5]);
    println!(
        "      modeled paper-scale latency {:.3} ms (GPU idx + FPGA scan + net)",
        r.modeled_s * 1e3
    );

    // 4. RALM generation through the PJRT-compiled decode step.
    println!("[4/4] generating 32 retrieval-augmented tokens (dec_tiny) ...");
    let runtime = Runtime::new(
        &std::env::var("CHAMELEON_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let pool = WorkerPool::new(&runtime, &config::DEC_TINY, 1, seed)?;
    let mut engine = RalmEngine::new(pool, retriever, &config::DEC_S);
    let stats = engine.generate(1, 32, seed)?;
    println!("      tokens: {:?}", &stats.tokens[..16]);
    println!(
        "      {:.1} ms/token measured (scaled), {:.2} ms/token modeled (Dec-S paper-scale)",
        stats.measured_total() / 32.0 * 1e3,
        stats.modeled_total() / 32.0 * 1e3,
    );
    println!("quickstart OK");
    Ok(())
}
