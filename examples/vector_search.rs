//! Vector-search deep dive: runs all four Fig 9 system configurations on
//! one dataset, printing measured (scaled) + modeled (paper-scale)
//! latency summaries and verifying recall against exact ground truth.
//!
//! Run: `cargo run --release --example vector_search -- [--dataset SIFT] [--n 20000] [--pjrt]`

use chameleon::chamvs::backend::{BackendKind, SearchBackend};
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::data::recall::{ground_truth, mean_recall};
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::runtime::Runtime;
use chameleon::util::cli::Args;
use chameleon::util::stats::Summary;

fn main() -> chameleon::Result<()> {
    let args = Args::parse();
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 20_000);
    let n_queries = args.get_usize("queries", 32);
    let seed = args.get_u64("seed", 7);
    let k = 100;

    println!("== dataset {} (scaled n={n}, paper n=1e9) ==", ds.name);
    let data = SyntheticDataset::generate_sized(ds, n, 256, seed);
    let nlist = (n as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed);

    // Recall vs exact ground truth (Sec 6.1 sanity).
    let gt = ground_truth(&data.data, data.n, data.d, &data.queries, n_queries, 10);
    let mut results = Vec::new();
    for q in 0..n_queries {
        let (ids, _) = index.search(data.query(q), ds.nprobe, 10);
        results.push(ids);
    }
    println!("R@10 at nprobe={}: {:.3}", ds.nprobe, mean_recall(&results, &gt));

    // The four Fig 9 backends, sharing one index.
    for kind in BackendKind::ALL {
        let use_pjrt = args.flag("pjrt") && kind.uses_fpga_scan();
        let nodes: Vec<MemoryNode> = if use_pjrt {
            let rt = Runtime::new(
                &std::env::var("CHAMELEON_ARTIFACTS")
                    .unwrap_or_else(|_| "artifacts".into()),
            )?;
            vec![MemoryNode::with_pjrt(Shard::carve(&index, 0, 1), &rt, k, seed)?]
        } else {
            vec![MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, k)]
        };
        let mut backend =
            SearchBackend::new(kind, ds, Dispatcher::new(nodes, k), true);
        let mut modeled = Vec::new();
        let mut wall = Vec::new();
        let mut cpu = Vec::new();
        for qi in 0..n_queries {
            let (res, lat) = backend.search(&index, data.query(qi), k)?;
            modeled.push(lat.total());
            wall.push(res.measured_wall_s);
            cpu.push(res.measured_cpu_s);
        }
        println!(
            "{}",
            Summary::of(&modeled).render_ms(&format!("{} modeled(paper)", kind.name()))
        );
        println!(
            "{}",
            Summary::of(&wall)
                .render_ms(&format!("{} measured wall(scaled)", kind.name()))
        );
        println!(
            "{}",
            Summary::of(&cpu)
                .render_ms(&format!("{} measured cpu(scaled)", kind.name()))
        );
    }
    Ok(())
}
