//! RALM serving: load a small real model (the AOT dec_tiny/encdec_tiny
//! artifacts), serve batched generation requests through the full
//! coordinator path, and report latency + throughput — the serving-paper
//! end-to-end driver (Fig 11/12 shape at scaled size).
//!
//! Run: `cargo run --release --example ralm_serve -- [--model dec_tiny]
//!       [--sequences 4] [--tokens 48] [--interval 1]
//!       [--nodes 1] [--dispatch-threads 0]`
//!
//! `--nodes <n>` shards the index over n memory nodes and
//! `--dispatch-threads <t>` sets the dispatcher's fan-out width
//! (0 = one worker per node; 1 = sequential baseline).
//!
//! Retcache knobs (see rust/src/retcache/): `--cache-kb <n>` enables the
//! retrieval cache with an n-KiB byte budget (0 = off, the default),
//! `--eviction lru|cost` picks the eviction policy, `--key-grid <step>`
//! the embedding quantization step (0 = exact keys), and `--speculate`
//! turns on speculative prefetching (`--spec-tolerance <msd>` sets the
//! verification tolerance). With any of these on, the serve report ends
//! with the cache hit/miss + speculation-accuracy counter block.

use chameleon::chamlm::pool::WorkerPool;
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::engine::RalmEngine;
use chameleon::coordinator::retriever::Retriever;
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::retcache::{CacheConfig, EvictionPolicy, KeyPolicy, SpecConfig};
use chameleon::runtime::Runtime;
use chameleon::util::cli::Args;
use chameleon::util::stats::Summary;

fn main() -> chameleon::Result<()> {
    let args = Args::parse();
    let seed = args.get_u64("seed", 11);
    let n_seq = args.get_usize("sequences", 4);
    let n_tokens = args.get_usize("tokens", 48);
    let model = match args.get_or("model", "dec_tiny") {
        "dec_tiny" => &config::DEC_TINY,
        "encdec_tiny" => &config::ENCDEC_TINY,
        other => anyhow::bail!("unknown model {other}"),
    };
    let paper = if model.is_encdec() { &config::ENCDEC_S } else { &config::DEC_S };
    let ds = config::dataset_by_name("SIFT").unwrap();

    let n_nodes = args.get_usize("nodes", 1).max(1);
    let dispatch_threads = args.get_usize("dispatch-threads", 0);

    println!("== building retrieval stack ({n_nodes} memory node(s)) ==");
    let data = SyntheticDataset::generate_sized(ds, 8000, 16, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 64, seed);
    let nodes: Vec<MemoryNode> = (0..n_nodes)
        .map(|i| {
            MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, model.k)
        })
        .collect();
    let corpus = Corpus::generate(data.n, model.vocab, config::CHUNK_LEN, seed);
    let dispatcher = Dispatcher::new(nodes, model.k).with_threads(dispatch_threads);
    println!(
        "== dispatch: {} worker thread(s) over {n_nodes} node(s) ==",
        dispatcher.effective_threads()
    );
    let retriever = Retriever::new(ds, index, dispatcher, corpus);

    println!("== loading model '{}' via PJRT ==", model.name);
    let runtime = Runtime::new(
        &std::env::var("CHAMELEON_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let pool = WorkerPool::new(&runtime, model, 1, seed)?;
    let mut engine = RalmEngine::new(pool, retriever, paper);

    // Retcache: optional cache + speculation in front of ChamVS.
    let cache_kb = args.get_usize("cache-kb", 0);
    let cache_cfg = (cache_kb > 0).then(|| {
        let policy = match args.get_or("eviction", "lru") {
            "cost" => EvictionPolicy::CostAware,
            _ => EvictionPolicy::Lru,
        };
        let grid = args.get_f64("key-grid", 0.05) as f32;
        let key = if grid > 0.0 { KeyPolicy::Quantized(grid) } else { KeyPolicy::Exact };
        CacheConfig { capacity_bytes: cache_kb << 10, policy, key }
    });
    let spec_cfg = args.flag("speculate").then(|| SpecConfig {
        tolerance: args.get_f64("spec-tolerance", 1e-4) as f32,
        ..SpecConfig::default()
    });
    if cache_cfg.is_some() || spec_cfg.is_some() {
        println!(
            "== retcache on: cache {:?}, speculation {:?} ==",
            cache_cfg.as_ref().map(|c| (c.capacity_bytes, c.policy)),
            spec_cfg.as_ref().map(|s| s.tolerance),
        );
        engine.enable_retcache(cache_cfg, spec_cfg);
    }

    println!("== serving {n_seq} sequences x {n_tokens} tokens ==");
    let prompts: Vec<u32> = (0..n_seq as u32).map(|i| i * 3 + 1).collect();
    let stats = engine.serve_batch(&prompts, n_tokens, seed)?;

    // Per-step latency summary of the first sequence (Fig 11 shape).
    let s0 = &stats.per_sequence[0];
    let retr_steps: Vec<f64> = s0
        .retrieval_steps
        .iter()
        .map(|&s| s0.step_measured_s[s])
        .collect();
    let plain_steps: Vec<f64> = s0
        .step_measured_s
        .iter()
        .enumerate()
        .filter(|(i, _)| !s0.retrieval_steps.contains(i))
        .map(|(_, &t)| t)
        .collect();
    println!(
        "{}",
        Summary::of(&s0.step_measured_s).render_ms("step latency (all, measured)")
    );
    if !retr_steps.is_empty() {
        println!(
            "{}",
            Summary::of(&retr_steps).render_ms("  retrieval steps")
        );
    }
    if !plain_steps.is_empty() {
        println!("{}", Summary::of(&plain_steps).render_ms("  plain steps"));
    }
    println!(
        "throughput: measured {:.1} tok/s (scaled CPU execution), modeled {:.1} tok/s ({} paper-scale)",
        stats.tokens as f64 / stats.measured_s,
        stats.modeled_tokens_per_s(),
        paper.name
    );
    let cache_block = engine.cache_report();
    if !cache_block.is_empty() {
        print!("{cache_block}");
    }
    Ok(())
}
