//! RALM serving: load a small real model (the AOT dec_tiny/encdec_tiny
//! artifacts), serve batched generation requests through the full
//! coordinator path, and report latency + throughput — the serving-paper
//! end-to-end driver (Fig 11/12 shape at scaled size).
//!
//! Run: `cargo run --release --example ralm_serve -- [--model dec_tiny]
//!       [--sequences 4] [--tokens 48] [--interval 1]
//!       [--nodes 1] [--dispatch-threads 0]`
//!
//! `--nodes <n>` shards the index over n memory nodes and
//! `--dispatch-threads <t>` sets the dispatcher's fan-out width
//! (0 = one worker per node; 1 = sequential baseline).
//!
//! Retcache knobs (see rust/src/retcache/): `--cache-kb <n>` enables the
//! retrieval cache with an n-KiB byte budget (0 = off, the default),
//! `--eviction lru|cost` picks the eviction policy, `--key-grid <step>`
//! the embedding quantization step (0 = exact keys), and `--speculate`
//! turns on speculative prefetching (`--spec-tolerance <msd>` sets the
//! verification tolerance). With any of these on, the serve report ends
//! with the cache hit/miss + speculation-accuracy counter block.
//!
//! Coordinator batching knobs: `--max-batch <n>` / `--max-wait-us <us>`
//! set the dynamic-batching policy (printed at startup). With
//! `--net-clients <n> [--net-queries <q>]` the example serves the
//! retrieval tier over TCP instead of running the engine: n concurrent
//! GPU clients drive the multi-connection coordinator event loop
//! (reader threads -> shared batcher -> dispatch loop -> reply routing)
//! and the run reports queries/s plus the observed batch sizes.

use std::time::Duration;

use chameleon::chamlm::pool::WorkerPool;
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::engine::RalmEngine;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorClient, CoordinatorServer, ServeMode};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::retcache::{CacheConfig, EvictionPolicy, KeyPolicy, SpecConfig};
use chameleon::runtime::Runtime;
use chameleon::util::cli::Args;
use chameleon::util::stats::Summary;

fn main() -> chameleon::Result<()> {
    let args = Args::parse();
    let seed = args.get_u64("seed", 11);
    let n_seq = args.get_usize("sequences", 4);
    let n_tokens = args.get_usize("tokens", 48);
    let model = match args.get_or("model", "dec_tiny") {
        "dec_tiny" => &config::DEC_TINY,
        "encdec_tiny" => &config::ENCDEC_TINY,
        other => anyhow::bail!("unknown model {other}"),
    };
    let paper = if model.is_encdec() { &config::ENCDEC_S } else { &config::DEC_S };
    let ds = config::dataset_by_name("SIFT").unwrap();

    let n_nodes = args.get_usize("nodes", 1).max(1);
    let dispatch_threads = args.get_usize("dispatch-threads", 0);
    let policy = BatchPolicy {
        max_batch: args.get_usize("max-batch", 16).max(1),
        max_wait: Duration::from_micros(args.get_u64("max-wait-us", 200)),
    };
    println!(
        "== batch policy: max_batch={} max_wait={}us ==",
        policy.max_batch,
        policy.max_wait.as_micros()
    );

    println!("== building retrieval stack ({n_nodes} memory node(s)) ==");
    let data = SyntheticDataset::generate_sized(ds, 8000, 16, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 64, seed);
    let nodes: Vec<MemoryNode> = (0..n_nodes)
        .map(|i| {
            MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, model.k)
        })
        .collect();
    let corpus = Corpus::generate(data.n, model.vocab, config::CHUNK_LEN, seed);
    let dispatcher = Dispatcher::new(nodes, model.k).with_threads(dispatch_threads);
    println!(
        "== dispatch: {} worker thread(s) over {n_nodes} node(s) ==",
        dispatcher.effective_threads()
    );
    let mut retriever = Retriever::new(ds, index, dispatcher, corpus);

    // Retcache: optional cache + speculation in front of ChamVS.
    let cache_kb = args.get_usize("cache-kb", 0);
    let cache_cfg = (cache_kb > 0).then(|| {
        let policy = match args.get_or("eviction", "lru") {
            "cost" => EvictionPolicy::CostAware,
            _ => EvictionPolicy::Lru,
        };
        let grid = args.get_f64("key-grid", 0.05) as f32;
        let key = if grid > 0.0 { KeyPolicy::Quantized(grid) } else { KeyPolicy::Exact };
        CacheConfig { capacity_bytes: cache_kb << 10, policy, key }
    });
    let spec_cfg = args.flag("speculate").then(|| SpecConfig {
        tolerance: args.get_f64("spec-tolerance", 1e-4) as f32,
        ..SpecConfig::default()
    });
    if cache_cfg.is_some() || spec_cfg.is_some() {
        println!(
            "== retcache on: cache {:?}, speculation {:?} ==",
            cache_cfg.as_ref().map(|c| (c.capacity_bytes, c.policy)),
            spec_cfg.as_ref().map(|s| s.tolerance),
        );
    }

    // Networked serving mode: drive the concurrent coordinator event
    // loop with N clients instead of running the generation engine.
    let net_clients = args.get_usize("net-clients", 0);
    if net_clients > 0 {
        if let Some(c) = cache_cfg {
            retriever.enable_cache(c);
        }
        if let Some(s) = spec_cfg {
            retriever.enable_speculation(s);
        }
        return serve_net_clients(
            retriever,
            policy,
            net_clients,
            args.get_usize("net-queries", 24),
            model.k,
            &data,
        );
    }

    println!("== loading model '{}' via PJRT ==", model.name);
    let runtime = Runtime::new(
        &std::env::var("CHAMELEON_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let pool = WorkerPool::new(&runtime, model, 1, seed)?;
    let mut engine = RalmEngine::new(pool, retriever, paper);
    if cache_cfg.is_some() || spec_cfg.is_some() {
        engine.enable_retcache(cache_cfg, spec_cfg);
    }

    println!("== serving {n_seq} sequences x {n_tokens} tokens ==");
    let prompts: Vec<u32> = (0..n_seq as u32).map(|i| i * 3 + 1).collect();
    let stats = engine.serve_batch(&prompts, n_tokens, seed)?;

    // Per-step latency summary of the first sequence (Fig 11 shape).
    let s0 = &stats.per_sequence[0];
    let retr_steps: Vec<f64> = s0
        .retrieval_steps
        .iter()
        .map(|&s| s0.step_measured_s[s])
        .collect();
    let plain_steps: Vec<f64> = s0
        .step_measured_s
        .iter()
        .enumerate()
        .filter(|(i, _)| !s0.retrieval_steps.contains(i))
        .map(|(_, &t)| t)
        .collect();
    println!(
        "{}",
        Summary::of(&s0.step_measured_s).render_ms("step latency (all, measured)")
    );
    if !retr_steps.is_empty() {
        println!(
            "{}",
            Summary::of(&retr_steps).render_ms("  retrieval steps")
        );
    }
    if !plain_steps.is_empty() {
        println!("{}", Summary::of(&plain_steps).render_ms("  plain steps"));
    }
    println!(
        "throughput: measured {:.1} tok/s (scaled CPU execution), modeled {:.1} tok/s ({} paper-scale)",
        stats.tokens as f64 / stats.measured_s,
        stats.modeled_tokens_per_s(),
        paper.name
    );
    let cache_block = engine.cache_report();
    if !cache_block.is_empty() {
        print!("{cache_block}");
    }
    Ok(())
}

/// Serve the retrieval tier over TCP: spawn the concurrent coordinator
/// under `policy` and drive it with `n_clients` concurrent GPU clients,
/// reporting throughput and the observed batch shapes.
fn serve_net_clients(
    retriever: Retriever,
    policy: BatchPolicy,
    n_clients: usize,
    per_client: usize,
    k: usize,
    data: &SyntheticDataset,
) -> chameleon::Result<()> {
    let per_client = per_client.max(1);
    let mut server =
        CoordinatorServer::spawn(move || retriever, ServeMode::Concurrent(policy))?;
    let addr = server.addr;
    println!(
        "== serving retrieval over TCP on {addr}: {n_clients} clients x {per_client} queries =="
    );
    let failed = std::sync::Mutex::new(None::<anyhow::Error>);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let failed = &failed;
            s.spawn(move || {
                let run = || -> chameleon::Result<()> {
                    let mut client = CoordinatorClient::connect(addr, c as u32)?;
                    for i in 0..per_client {
                        let q = data.query((c * 7 + i) % data.n_queries);
                        client.retrieve(q, &[], k, false)?;
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    *failed.lock().unwrap() = Some(e);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    if let Some(e) = failed.into_inner().unwrap() {
        return Err(e);
    }
    let total = (n_clients * per_client) as f64;
    let stats = server.stats();
    println!(
        "served {total:.0} retrievals in {wall:.3}s -> {:.0} q/s",
        total / wall
    );
    println!(
        "dispatch rounds={} mean_batch={:.2} max_batch={} rounds_with_batch>=2: {}",
        stats.rounds(),
        total / stats.rounds().max(1) as f64,
        stats.max_batch(),
        stats.batches_ge2()
    );
    server.shutdown();
    Ok(())
}
