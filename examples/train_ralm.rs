//! End-to-end validation driver: train a RALM decoder with the AOT-lowered
//! jax train step (fwd + bwd + Adam, compiled once, executed from rust via
//! PJRT) on a synthetic Markov corpus, logging the loss curve.
//!
//! All optimizer state stays device-resident: each step's outputs (new
//! params, new Adam moments) are fed back as the next step's parameter
//! buffers without host round-trips.
//!
//! Default model is the scaled `dec_tiny`; pass `--model dec_s` for the
//! ~101M-parameter Dec-S (Table 2) — the EXPERIMENTS.md run — after
//! building its artifact with `make artifacts-full`.
//!
//! Run: `cargo run --release --example train_ralm -- [--steps 300] [--model dec_tiny]`

use chameleon::data::corpus::training_sequences;
use chameleon::runtime::{HostTensor, Runtime};
use chameleon::util::cli::Args;

fn main() -> chameleon::Result<()> {
    let args = Args::parse();
    let steps = args.get_usize("steps", 200);
    let seed = args.get_u64("seed", 5);
    let model = args.get_or("model", "dec_tiny").to_string();
    let artifact = format!("train_{model}");
    let log_every = args.get_usize("log-every", 10);

    let runtime = Runtime::new(
        &std::env::var("CHAMELEON_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    println!("== compiling {artifact} (one-time XLA compile) ==");
    let t0 = std::time::Instant::now();
    let mut exe = runtime.executor(&artifact, seed)?;
    println!("   compiled in {:.1}s, {} parameter tensors resident",
        t0.elapsed().as_secs_f64(), exe.n_params());

    let spec = exe.spec.clone();
    let batch = spec.static_usize("batch").unwrap();
    let seq = spec.static_usize("seq").unwrap();
    let n_params = spec.static_usize("n_params").unwrap_or(0);
    let vocab = spec
        .inputs
        .iter()
        .find(|t| t.name == "embed")
        .map(|t| t.shape[0])
        .unwrap();
    println!(
        "   model ~{:.1}M params, batch={batch}, seq={seq}, vocab={vocab}",
        n_params as f64 / 1e6
    );

    // Synthetic Markov corpus: learnable n-gram structure (loss must fall
    // from ~ln(vocab) toward the Markov entropy ~ln(5)). For large-vocab
    // models the corpus is confined to a sub-vocabulary so the structure
    // is learnable within a few hundred steps of a 1-core run: the model
    // first learns the support (loss -> ln(corpus_vocab)), then the
    // transitions.
    let corpus_vocab = args
        .get_usize("corpus-vocab", vocab.min(4096))
        .min(vocab);
    let corpus = training_sequences(steps * batch, seq, corpus_vocab, seed ^ 9);

    println!("== training {steps} steps ==");
    println!("step  loss      tok/s");
    let mut losses = Vec::with_capacity(steps);
    let train_t0 = std::time::Instant::now();
    for step in 0..steps {
        let mut toks = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            toks.extend(corpus[step * batch + b].iter().map(|&t| t as i32));
        }
        let arg_step = HostTensor::i32(&[], vec![step as i32]);
        let arg_toks = HostTensor::i32(&[batch, seq], toks);
        let outs = exe.call(&[arg_step, arg_toks])?;
        // Output 0: loss; outputs 1..=3n: new params + Adam moments, fed
        // back as the next step's parameter buffers.
        let loss = outs[0].as_f32()?[0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        losses.push(loss as f64);
        for (i, t) in outs.iter().enumerate().skip(1) {
            exe.set_param(i - 1, t)?;
        }
        if step % log_every == 0 || step + 1 == steps {
            let tps = ((step + 1) * batch * seq) as f64
                / train_t0.elapsed().as_secs_f64();
            println!("{step:<5} {loss:<9.4} {tps:.0}");
        }
    }

    let first = losses[..5.min(losses.len())].iter().sum::<f64>()
        / 5.min(losses.len()) as f64;
    let last = losses[losses.len().saturating_sub(5)..].iter().sum::<f64>()
        / 5.min(losses.len()) as f64;
    println!(
        "\nloss: {first:.4} -> {last:.4} over {steps} steps ({:.1} min)",
        train_t0.elapsed().as_secs_f64() / 60.0
    );
    println!(
        "uniform ln({vocab}) = {:.3}; corpus support ln({corpus_vocab}) = {:.3}; markov floor ln(5) = {:.3}",
        (vocab as f64).ln(),
        (corpus_vocab as f64).ln(),
        5f64.ln()
    );
    anyhow::ensure!(last < first, "loss did not decrease");
    println!("train_ralm OK");
    Ok(())
}
