//! Disaggregation demo over real sockets: spins up N ChamVS memory-node
//! servers (each with a vector-sharded slice of the database, like the
//! paper's FPGA nodes behind their TCP/IP stacks), connects the
//! coordinator-side client, broadcasts queries, and k-way-merges replies.
//! Verifies the networked results equal the monolithic search bit-for-bit.
//!
//! Run: `cargo run --release --example disaggregated -- [--nodes 4] [--n 10000]`

use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::kselect::HierarchicalConfig;
use chameleon::net::client::NodeClient;
use chameleon::net::server::NodeServer;
use chameleon::util::cli::Args;
use chameleon::util::stats::Summary;

fn main() -> chameleon::Result<()> {
    let args = Args::parse();
    let n_nodes = args.get_usize("nodes", 4);
    let n = args.get_usize("n", 10_000);
    let n_queries = args.get_usize("queries", 32);
    let seed = args.get_u64("seed", 3);
    let k = 10;
    let ds = config::dataset_by_name("SIFT").unwrap();

    println!("== coordinator: building reference index ==");
    let data = SyntheticDataset::generate_sized(ds, n, 256, seed);
    let nlist = (n as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, n, data.d, ds.m, nlist, seed ^ 1);

    println!("== spawning {n_nodes} memory-node servers (localhost TCP) ==");
    let servers: Vec<NodeServer> = (0..n_nodes)
        .map(|node_id| {
            // Each node process rebuilds its shard deterministically from
            // the shared (dataset, seed) contract — the same bytes the
            // coordinator would otherwise ship into its DRAM.
            let data = SyntheticDataset::generate_sized(ds, n, 256, seed);
            let index =
                IvfPqIndex::build(&data.data, n, data.d, ds.m, nlist, seed ^ 1);
            let cb = index.pq.centroids.clone();
            NodeServer::spawn_with(
                move || {
                    let mut node = MemoryNode::new(
                        Shard::carve(&index, node_id, n_nodes),
                        ScanEngine::Native,
                        k,
                    );
                    // Exact queues for the bit-exactness check below.
                    node.kcfg = HierarchicalConfig::exact(k, node.kcfg.num_lanes);
                    node
                },
                cb,
                ds.nprobe,
            )
            .unwrap()
        })
        .collect();
    for s in &servers {
        println!("   node at {}", s.addr);
    }

    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let mut client = NodeClient::connect(&addrs, k)?;

    println!("== broadcasting {n_queries} queries ==");
    let mut lat = Vec::new();
    let mut node_wall = Vec::new();
    let mut mismatches = 0usize;
    for qi in 0..n_queries {
        let q = data.query(qi % data.n_queries);
        let lists = index.probe(q, ds.nprobe);
        let t0 = std::time::Instant::now();
        let r = client.search(q, &lists)?;
        lat.push(t0.elapsed().as_secs_f64());
        // Node-side scan wall carried in the responses (no more zeros on
        // the networked path).
        node_wall.push(r.measured_wall_s);
        let (_, want) = index.search(q, ds.nprobe, k);
        for (g, w) in r.topk.iter().zip(&want) {
            if (g.0 - w).abs() > 1e-4 {
                mismatches += 1;
            }
        }
    }
    println!("{}", Summary::of(&lat).render_ms("networked search (measured)"));
    println!("{}", Summary::of(&node_wall).render_ms("node-side scan wall"));
    println!(
        "distributed == monolithic: {} ({} mismatched ranks / {})",
        if mismatches == 0 { "YES" } else { "NO" },
        mismatches,
        n_queries * k
    );
    client.shutdown_nodes();
    anyhow::ensure!(mismatches == 0, "distributed results diverged");
    println!("disaggregated OK");
    Ok(())
}
