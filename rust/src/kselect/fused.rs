//! Fused scan+select: the fast exact K-selector of the zero-copy scan
//! pipeline (EXPERIMENTS.md §Perf).
//!
//! [`FusedSelector`] is a bounded max-heap of at most `k` entries whose
//! root is the current kth-best distance. The ADC scan offers every
//! distance through [`DistanceSink::offer`]; once the heap is full, a
//! single compare against the root rejects the overwhelming majority of
//! codes without touching the heap — the selection cost all but vanishes
//! next to the scan itself. This is the serving-default replacement for
//! pushing every code through the cycle-accurate
//! [`ApproxHierarchicalQueue`](super::hierarchical::ApproxHierarchicalQueue)
//! (which stays available behind [`SelectMode::Hierarchical`] as the
//! hardware-fidelity path; its per-push systolic swap waves cost O(depth)
//! per code).
//!
//! Determinism: entries carry an explicit `order` key (the code's position
//! in the query's probed-list gather order), and the heap keeps the k
//! smallest by the lexicographic `(dist, order)` key. That makes the
//! result independent of the order codes are offered in — a list-major
//! batched round and a query-major single scan produce bit-identical
//! top-K lists, both equal to a stable sort of all distances in gather
//! order (the flat-scan reference).

use super::hierarchical::ApproxHierarchicalQueue;

/// How a memory node selects its local top-K during a scan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SelectMode {
    /// Fused exact selection ([`FusedSelector`]): the serving default.
    #[default]
    Exact,
    /// The cycle-accurate (approximate) hierarchical priority queue — the
    /// software model of the FPGA K-selection module (paper Sec 4.2).
    Hierarchical,
}

/// Anything an ADC scan can stream `(distance, order, id)` triples into.
///
/// `order` is the code's position in the query's gather order (probed
/// lists concatenated in probe order) and only breaks distance ties;
/// `id` is the global vector id returned to the caller.
pub trait DistanceSink {
    fn offer(&mut self, dist: f32, order: u64, id: u64);
}

/// Bounded max-heap K-selector with current-kth threshold pruning.
///
/// Reusable across queries via [`reset`](FusedSelector::reset): the heap
/// buffer is retained, so steady-state operation allocates nothing.
pub struct FusedSelector {
    k: usize,
    /// Max-heap by `(dist, order)`; `heap[0]` is the current kth-best.
    heap: Vec<(f32, u64, u64)>,
}

/// Lexicographic `(dist, order)` greater-than (the heap ordering; `id` is
/// payload only). Orders are unique within a query, so this is total.
#[inline]
fn key_gt(a: &(f32, u64, u64), b: &(f32, u64, u64)) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 > b.1)
}

impl FusedSelector {
    pub fn new(k: usize) -> FusedSelector {
        FusedSelector { k, heap: Vec::with_capacity(k) }
    }

    /// Retarget to a (possibly different) `k`, clearing entries but
    /// keeping the buffer.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.heap.clear();
        self.heap.reserve(k);
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current kth-best distance — the pruning threshold. `INFINITY`
    /// until the heap is full (everything is accepted); `NEG_INFINITY`
    /// for a `k = 0` selector (nothing is ever accepted).
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.k == 0 {
            f32::NEG_INFINITY
        } else if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Offer one scanned distance. Hot path: once full, a code whose
    /// distance exceeds the current kth is rejected with one compare.
    #[inline]
    pub fn offer(&mut self, dist: f32, order: u64, id: u64) {
        if self.heap.len() < self.k {
            self.heap.push((dist, order, id));
            self.sift_up();
        } else if self.k > 0 {
            // Threshold prune: the common case is a plain reject.
            let root = self.heap[0];
            if dist > root.0 || (dist == root.0 && order > root.1) {
                return;
            }
            self.heap[0] = (dist, order, id);
            self.sift_down();
        }
    }

    fn sift_up(&mut self) {
        let mut i = self.heap.len() - 1;
        while i > 0 {
            let parent = (i - 1) / 2;
            if key_gt(&self.heap[i], &self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self) {
        let n = self.heap.len();
        let mut i = 0;
        loop {
            let l = 2 * i + 1;
            if l >= n {
                break;
            }
            let r = l + 1;
            let mut big = l;
            if r < n && key_gt(&self.heap[r], &self.heap[l]) {
                big = r;
            }
            if key_gt(&self.heap[big], &self.heap[i]) {
                self.heap.swap(i, big);
                i = big;
            } else {
                break;
            }
        }
    }

    /// Drain the selection, ascending by `(dist, order)`, into `out` as
    /// `(dist, id)` pairs. The selector is left empty (same `k`) and its
    /// buffer retained; the sort is in-place (no allocation).
    pub fn emit_into(&mut self, out: &mut Vec<(f32, u64)>) {
        self.heap
            .sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        out.clear();
        out.extend(self.heap.iter().map(|&(d, _, id)| (d, id)));
        self.heap.clear();
    }
}

impl DistanceSink for FusedSelector {
    #[inline]
    fn offer(&mut self, dist: f32, order: u64, id: u64) {
        FusedSelector::offer(self, dist, order, id)
    }
}

/// The hierarchical queue ingests the same stream (ids as payload; the
/// lane round-robin depends only on offer order, which the scan keeps in
/// gather order for this mode).
impl DistanceSink for ApproxHierarchicalQueue {
    #[inline]
    fn offer(&mut self, dist: f32, _order: u64, id: u64) {
        self.push(dist, id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Reference: stable sort by distance over offer order, truncate k.
    fn stable_reference(dists: &[f32], k: usize) -> Vec<(f32, u64)> {
        let mut all: Vec<(f32, u64)> =
            dists.iter().enumerate().map(|(i, &d)| (d, i as u64)).collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        all.truncate(k);
        all
    }

    #[test]
    fn prop_matches_stable_sort_reference() {
        prop::check(
            "fused-selector-matches",
            |rng| {
                let k = 1 + rng.below(60);
                let n = 1 + rng.below(500);
                // Coarse quantization forces plenty of distance ties.
                let dists: Vec<f32> =
                    (0..n).map(|_| (rng.below(32) as f32) * 0.5).collect();
                (k, dists)
            },
            |(k, dists)| {
                let mut sel = FusedSelector::new(*k);
                for (i, &d) in dists.iter().enumerate() {
                    sel.offer(d, i as u64, 1000 + i as u64);
                }
                let mut got = Vec::new();
                sel.emit_into(&mut got);
                let want = stable_reference(dists, *k);
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.0.to_bits(), w.0.to_bits());
                    assert_eq!(g.1, 1000 + w.1, "tie order must be stable");
                }
            },
        );
    }

    #[test]
    fn offer_order_does_not_change_result() {
        // The (dist, order) key makes the selection independent of the
        // order codes are offered — the list-major batched invariance.
        let mut rng = Rng::new(7);
        let dists: Vec<f32> = (0..300).map(|_| (rng.below(16) as f32) * 0.25).collect();
        let mut forward = FusedSelector::new(10);
        let mut backward = FusedSelector::new(10);
        for (i, &d) in dists.iter().enumerate() {
            forward.offer(d, i as u64, i as u64);
        }
        for (i, &d) in dists.iter().enumerate().rev() {
            backward.offer(d, i as u64, i as u64);
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        forward.emit_into(&mut a);
        backward.emit_into(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn threshold_tracks_kth_best() {
        let mut sel = FusedSelector::new(2);
        assert_eq!(sel.threshold(), f32::INFINITY);
        sel.offer(5.0, 0, 0);
        assert_eq!(sel.threshold(), f32::INFINITY);
        sel.offer(3.0, 1, 1);
        assert_eq!(sel.threshold(), 5.0);
        sel.offer(1.0, 2, 2);
        assert_eq!(sel.threshold(), 3.0);
        sel.offer(9.0, 3, 3); // pruned
        assert_eq!(sel.threshold(), 3.0);
    }

    #[test]
    fn reset_reuses_buffer_without_allocating() {
        let mut sel = FusedSelector::new(8);
        for i in 0..100u64 {
            sel.offer(i as f32, i, i);
        }
        let cap = sel.heap.capacity();
        sel.reset(8);
        assert!(sel.is_empty());
        assert_eq!(sel.heap.capacity(), cap);
    }

    #[test]
    fn k_zero_selects_nothing() {
        let mut sel = FusedSelector::new(0);
        sel.offer(1.0, 0, 0);
        let mut out = vec![(0.0, 0)];
        sel.emit_into(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn hierarchical_sink_matches_direct_push() {
        use crate::kselect::HierarchicalConfig;
        let mut rng = Rng::new(3);
        let dists: Vec<f32> = (0..200).map(|_| rng.f32()).collect();
        let cfg = HierarchicalConfig::exact(9, 4);
        let mut via_sink = ApproxHierarchicalQueue::new(cfg);
        let mut direct = ApproxHierarchicalQueue::new(cfg);
        for (i, &d) in dists.iter().enumerate() {
            DistanceSink::offer(&mut via_sink, d, i as u64, i as u64);
            direct.push(d, i as u64);
        }
        assert_eq!(via_sink.finalize(), direct.finalize());
    }
}
