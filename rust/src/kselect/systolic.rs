//! Cycle-level simulator of the register-array systolic priority queue
//! (paper Sec 4.2.1, Fig 6; Leiserson '79 / Huang '14 style).
//!
//! The hardware repeats a two-cycle procedure per replace operation: an
//! odd cycle substitutes the incoming element into the leftmost node and
//! swaps even/odd neighbor pairs; the even cycle swaps the complementary
//! pairs. The simulator reproduces that schedule exactly so (a) results
//! match the hardware semantics (a *largest-out* replace queue keeping the
//! K smallest) and (b) cycle counts feed the FPGA performance model.

/// One entry: (distance, payload id). `f32::INFINITY` marks an empty slot.
pub type Entry = (f32, u64);

/// Register-array systolic priority queue of fixed length K.
///
/// Semantics: after any number of `replace` operations, the array holds
/// the K smallest elements ever inserted; `replace` costs two cycles.
pub struct SystolicQueue {
    regs: Vec<Entry>,
    cycles: u64,
}

impl SystolicQueue {
    pub fn new(k: usize) -> SystolicQueue {
        assert!(k >= 1);
        SystolicQueue { regs: vec![(f32::INFINITY, u64::MAX); k], cycles: 0 }
    }

    pub fn len(&self) -> usize {
        self.regs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regs.iter().all(|e| e.0 == f32::INFINITY)
    }

    /// Cycles consumed so far (2 per replace).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Hardware replace operation (2 cycles): if `x` is smaller than the
    /// current maximum (leftmost register), it displaces it; the systolic
    /// swap waves then restore order towards the right.
    pub fn replace(&mut self, x: Entry) {
        // Odd cycle: leftmost keeps min(incoming, leftmost) — the larger
        // value is discarded (dequeued); then swap pairs (0,1), (2,3), ...
        // so larger values drift left, smaller right.
        let left = self.regs[0];
        if x.0 < left.0 {
            self.regs[0] = x;
        }
        for i in (0..self.regs.len() - 1).step_by(2) {
            // Keep descending order left->right: larger stays left.
            if self.regs[i].0 < self.regs[i + 1].0 {
                self.regs.swap(i, i + 1);
            }
        }
        // Even cycle: swap pairs (1,2), (3,4), ...
        for i in (1..self.regs.len().saturating_sub(1)).step_by(2) {
            if self.regs[i].0 < self.regs[i + 1].0 {
                self.regs.swap(i, i + 1);
            }
        }
        self.cycles += 2;
    }

    /// Drain the queue: ascending (distance, id) list of the K smallest.
    /// (In hardware this is the final right-to-left readout.)
    ///
    /// Note: a single pass of the two swap waves per insert does not fully
    /// sort the register array, but it maintains the *set* of K smallest;
    /// full ordering emerges over subsequent operations exactly as in the
    /// real systolic design, and readout sorts the registers.
    pub fn drain_sorted(&self) -> Vec<Entry> {
        let mut out: Vec<Entry> =
            self.regs.iter().filter(|e| e.0 != f32::INFINITY).cloned().collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    /// Current maximum (head of the replace comparison).
    pub fn current_max(&self) -> f32 {
        self.regs[0].0
    }

    /// Hardware cost model handle: registers + compare-swap units scale
    /// linearly with length (paper: "resource consumption ... proportional
    /// to the queue size").
    pub fn resource_units(&self) -> usize {
        self.regs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// The queue must hold exactly the K smallest of any input stream.
    fn check_holds_k_smallest(values: &[f32], k: usize) {
        let mut q = SystolicQueue::new(k);
        for (i, &v) in values.iter().enumerate() {
            q.replace((v, i as u64));
        }
        let got: Vec<f32> = q.drain_sorted().iter().map(|e| e.0).collect();
        let mut expect = values.to_vec();
        expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
        expect.truncate(k.min(values.len()));
        assert_eq!(got.len(), expect.len());
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g, e, "got {got:?} expect {expect:?}");
        }
    }

    #[test]
    fn small_cases() {
        check_holds_k_smallest(&[5.0, 1.0, 3.0, 2.0, 4.0], 3);
        check_holds_k_smallest(&[1.0], 4);
        check_holds_k_smallest(&[2.0, 2.0, 2.0], 2);
    }

    #[test]
    fn descending_and_ascending_streams() {
        let desc: Vec<f32> = (0..100).rev().map(|i| i as f32).collect();
        let asc: Vec<f32> = (0..100).map(|i| i as f32).collect();
        check_holds_k_smallest(&desc, 10);
        check_holds_k_smallest(&asc, 10);
    }

    #[test]
    fn two_cycles_per_replace() {
        let mut q = SystolicQueue::new(8);
        for i in 0..50 {
            q.replace((i as f32, i));
        }
        assert_eq!(q.cycles(), 100);
    }

    #[test]
    fn prop_random_streams() {
        prop::check(
            "systolic-holds-k-smallest",
            |rng: &mut Rng| {
                let k = 1 + rng.below(64);
                let vals = prop::gen_distances(rng, 500);
                (k, vals)
            },
            |(k, vals)| check_holds_k_smallest(vals, *k),
        );
    }

    #[test]
    fn resource_units_linear() {
        assert_eq!(SystolicQueue::new(100).resource_units(), 100);
        assert_eq!(SystolicQueue::new(20).resource_units(), 20);
    }
}
