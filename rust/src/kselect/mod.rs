//! K-selection hardware designs (paper Sec 4.2): the register-array
//! systolic priority queue primitive, the exact hierarchical arrangement,
//! and the paper's contribution — the *approximate* hierarchical priority
//! queue whose truncated L1 queues save an order of magnitude of hardware
//! while keeping >= 99% of queries bit-identical.
//!
//! [`fused`] adds the software serving path: a threshold-pruned bounded
//! max-heap ([`FusedSelector`]) that the ADC scan streams into directly
//! (no materialized distance buffer), selectable per memory node via
//! [`SelectMode`].

pub mod binomial;
pub mod fused;
pub mod hierarchical;
pub mod systolic;

pub use binomial::{exceed_probability, required_depth};
pub use fused::{DistanceSink, FusedSelector, SelectMode};
pub use hierarchical::{ApproxHierarchicalQueue, HierarchicalConfig};
pub use systolic::SystolicQueue;
