//! K-selection hardware designs (paper Sec 4.2): the register-array
//! systolic priority queue primitive, the exact hierarchical arrangement,
//! and the paper's contribution — the *approximate* hierarchical priority
//! queue whose truncated L1 queues save an order of magnitude of hardware
//! while keeping >= 99% of queries bit-identical.

pub mod binomial;
pub mod hierarchical;
pub mod systolic;

pub use binomial::{exceed_probability, required_depth};
pub use hierarchical::{ApproxHierarchicalQueue, HierarchicalConfig};
pub use systolic::SystolicQueue;
