//! Binomial analysis of the approximate hierarchical priority queue
//! (paper Sec 4.2.2, Fig 7/8).
//!
//! With distances dealt uniformly to `num_queues` L1 queues, the count of
//! true top-K results landing in one queue is Binomial(K, 1/num_queues):
//! `p(k) = C(K, k) (1/Q)^k (1 - 1/Q)^(K-k)`. Truncating each L1 queue to
//! the smallest depth whose exceedance probability is below a target keeps
//! results identical for (e.g.) 99% of queries at ~10x less hardware.

/// P[one queue holds exactly `k` of the top-K] (paper's p(k)).
pub fn hold_probability(big_k: usize, num_queues: usize, k: usize) -> f64 {
    if k > big_k {
        return 0.0;
    }
    let p = 1.0 / num_queues as f64;
    ln_choose(big_k, k).exp()
        * p.powi(k as i32)
        * (1.0 - p).powi((big_k - k) as i32)
}

/// P[one queue holds more than `depth` of the top-K] (tail beyond the
/// truncated queue's capacity).
pub fn exceed_probability(big_k: usize, num_queues: usize, depth: usize) -> f64 {
    let mut cum = 0.0;
    for k in 0..=depth.min(big_k) {
        cum += hold_probability(big_k, num_queues, k);
    }
    (1.0 - cum).max(0.0)
}

/// P[*any* of the queues overflows] via the union bound — the per-query
/// probability that the approximate module's output differs from exact.
pub fn any_queue_exceed_probability(big_k: usize, num_queues: usize, depth: usize) -> f64 {
    (num_queues as f64 * exceed_probability(big_k, num_queues, depth)).min(1.0)
}

/// Smallest per-queue depth such that >= `quantile` of queries (e.g. 0.99)
/// are guaranteed identical to exact K-selection (union bound).
pub fn required_depth(big_k: usize, num_queues: usize, quantile: f64) -> usize {
    let target = (1.0 - quantile) / num_queues as f64;
    for depth in 1..=big_k {
        if exceed_probability(big_k, num_queues, depth) <= target {
            return depth;
        }
    }
    big_k
}

/// ln C(n, k) via lgamma, stable for the K≈100 regime of the paper.
fn ln_choose(n: usize, k: usize) -> f64 {
    ln_gamma((n + 1) as f64) - ln_gamma((k + 1) as f64) - ln_gamma((n - k + 1) as f64)
}

/// Lanczos approximation of ln Γ(x) (x > 0).
fn ln_gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients.
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn probabilities_sum_to_one() {
        let total: f64 =
            (0..=100).map(|k| hold_probability(100, 16, k)).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn paper_fig7_shape() {
        // Fig 7: with 16 queues and K=100, mean is 6.25 and holding more
        // than 20 is vanishingly unlikely.
        let mean: f64 =
            (0..=100).map(|k| k as f64 * hold_probability(100, 16, k)).sum();
        assert!((mean - 6.25).abs() < 1e-6, "mean {mean}");
        assert!(exceed_probability(100, 16, 20) < 1e-5);
        // The mode sits near the mean.
        let mode = (0..=100)
            .max_by(|&a, &b| {
                hold_probability(100, 16, a)
                    .partial_cmp(&hold_probability(100, 16, b))
                    .unwrap()
            })
            .unwrap();
        assert!((5..=7).contains(&mode), "mode {mode}");
    }

    #[test]
    fn required_depth_monotone_in_queues() {
        // More queues => fewer of the top-K per queue => shallower queues.
        let d4 = required_depth(100, 4, 0.99);
        let d16 = required_depth(100, 16, 0.99);
        let d64 = required_depth(100, 64, 0.99);
        assert!(d4 > d16 && d16 > d64, "{d4} {d16} {d64}");
        // Fig 8: order-of-magnitude savings at 16+ queues.
        assert!(d16 <= 20, "depth {d16}");
        assert!(d64 * 64 < 100 * 64 / 8, "no 8x saving: {d64}");
    }

    #[test]
    fn exceedance_matches_monte_carlo() {
        // Empirically deal 100 ranks into 16 queues and count overflows.
        let mut rng = Rng::new(9);
        let (big_k, q, depth) = (100usize, 16usize, 10usize);
        let trials = 20_000;
        let mut exceed = 0usize;
        for _ in 0..trials {
            let mut counts = vec![0usize; q];
            for _ in 0..big_k {
                counts[rng.below(q)] += 1;
            }
            if counts[0] > depth {
                exceed += 1;
            }
        }
        let emp = exceed as f64 / trials as f64;
        let ana = exceed_probability(big_k, q, depth);
        assert!(
            (emp - ana).abs() < 0.01,
            "empirical {emp} vs analytic {ana}"
        );
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(11.0) - (3628800.0f64).ln()).abs() < 1e-8);
    }

    #[test]
    fn union_bound_upper_bounds() {
        let single = exceed_probability(100, 16, 12);
        let any = any_queue_exceed_probability(100, 16, 12);
        assert!(any >= single);
        assert!(any <= 16.0 * single + 1e-12);
    }
}
