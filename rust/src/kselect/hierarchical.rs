//! The (approximate) hierarchical priority queue (paper Sec 4.2).
//!
//! Level-1: one truncated systolic queue per producer lane (two per PQ
//! decoding unit in hardware — a queue ingests one element per two
//! cycles while a decoding unit emits one per cycle). Level-2: an exact
//! merge of the lane survivors. With `l1_depth == k` the module is exact;
//! the paper's contribution is truncating `l1_depth` to the binomial bound
//! (Sec 4.2.2) for ~10x resource savings (Fig 8).

use super::binomial::required_depth;
use super::systolic::{Entry, SystolicQueue};

/// Sizing of a hierarchical K-selection module.
#[derive(Clone, Copy, Debug)]
pub struct HierarchicalConfig {
    pub k: usize,
    pub num_lanes: usize,
    /// Per-lane L1 queue depth. `k` = exact module.
    pub l1_depth: usize,
}

impl HierarchicalConfig {
    /// Exact configuration (L1 queues of full length K).
    pub fn exact(k: usize, num_lanes: usize) -> Self {
        HierarchicalConfig { k, num_lanes, l1_depth: k }
    }

    /// Approximate configuration sized for `quantile` identical queries
    /// (paper uses 0.99).
    pub fn approximate(k: usize, num_lanes: usize, quantile: f64) -> Self {
        HierarchicalConfig {
            k,
            num_lanes,
            l1_depth: required_depth(k, num_lanes, quantile).min(k),
        }
    }

    /// Total register/compare-swap units across L1 + L2 queues — the
    /// resource proxy of Fig 8 (hardware cost is ~linear in queue length).
    pub fn resource_units(&self) -> usize {
        self.num_lanes * self.l1_depth + self.k
    }
}

/// A software-simulated hierarchical priority queue processing a stream of
/// (distance, id) entries dealt round-robin across lanes — exactly how the
/// PQ decoding units feed the hardware queues.
pub struct ApproxHierarchicalQueue {
    pub cfg: HierarchicalConfig,
    lanes: Vec<SystolicQueue>,
    next_lane: usize,
}

impl ApproxHierarchicalQueue {
    pub fn new(cfg: HierarchicalConfig) -> Self {
        let lanes = (0..cfg.num_lanes).map(|_| SystolicQueue::new(cfg.l1_depth)).collect();
        ApproxHierarchicalQueue { cfg, lanes, next_lane: 0 }
    }

    /// Ingest one distance (round-robin lane assignment).
    #[inline]
    pub fn push(&mut self, dist: f32, id: u64) {
        self.lanes[self.next_lane].replace((dist, id));
        self.next_lane = (self.next_lane + 1) % self.cfg.num_lanes;
    }

    /// Ingest a slice of distances with ids `base..base+n`.
    pub fn push_block(&mut self, dists: &[f32], base: u64) {
        for (i, &d) in dists.iter().enumerate() {
            self.push(d, base + i as u64);
        }
    }

    /// L2 merge: exact top-K over all lane survivors, ascending.
    pub fn finalize(&self) -> Vec<Entry> {
        let mut all: Vec<Entry> =
            self.lanes.iter().flat_map(|q| q.drain_sorted()).collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        all.truncate(self.cfg.k);
        all
    }

    /// Simulated hardware cycles: lanes run in parallel, so the maximum
    /// lane cycle count is the module's latency contribution.
    pub fn cycles(&self) -> u64 {
        self.lanes.iter().map(SystolicQueue::cycles).max().unwrap_or(0)
    }
}

/// Exact software top-k (ascending) — oracle for tests and agreement
/// measurements.
pub fn exact_topk(dists: &[f32], k: usize) -> Vec<Entry> {
    let mut all: Vec<Entry> =
        dists.iter().enumerate().map(|(i, &d)| (d, i as u64)).collect();
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    all.truncate(k);
    all
}

/// Fraction of streams (over `trials` random shuffles) where the
/// approximate module's result *distances* exactly match exact top-K —
/// the "99% identical" metric of Sec 4.2.2.
pub fn agreement_rate(
    cfg: HierarchicalConfig,
    n: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut agree = 0usize;
    for _ in 0..trials {
        let dists: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut q = ApproxHierarchicalQueue::new(cfg);
        q.push_block(&dists, 0);
        let approx = q.finalize();
        let exact = exact_topk(&dists, cfg.k);
        let same = approx.len() == exact.len()
            && approx.iter().zip(&exact).all(|(a, e)| a.0 == e.0);
        agree += usize::from(same);
    }
    agree as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_config_matches_oracle() {
        prop::check(
            "hier-exact-matches",
            |rng| {
                let k = 1 + rng.below(50);
                let lanes = 1 + rng.below(8);
                let dists = prop::gen_distances(rng, 400);
                (k, lanes, dists)
            },
            |(k, lanes, dists)| {
                let cfg = HierarchicalConfig::exact(*k, *lanes);
                let mut q = ApproxHierarchicalQueue::new(cfg);
                q.push_block(dists, 0);
                let got = q.finalize();
                let expect = exact_topk(dists, *k);
                assert_eq!(got.len(), expect.len());
                for (g, e) in got.iter().zip(&expect) {
                    assert_eq!(g.0, e.0, "dists differ");
                }
            },
        );
    }

    #[test]
    fn approximate_agrees_at_target_quantile() {
        // Paper claim: sized for 99%, the approximate queue returns
        // identical results for >= 99% of queries.
        let cfg = HierarchicalConfig::approximate(100, 16, 0.99);
        assert!(cfg.l1_depth < 100, "should truncate, got {}", cfg.l1_depth);
        let rate = agreement_rate(cfg, 4096, 400, 7);
        assert!(rate >= 0.985, "agreement {rate}");
    }

    #[test]
    fn resource_savings_order_of_magnitude() {
        // Fig 8: approximate vs exact resources at 16 lanes, K=100.
        let exact = HierarchicalConfig::exact(100, 16).resource_units();
        let approx =
            HierarchicalConfig::approximate(100, 16, 0.99).resource_units();
        assert!(
            exact as f64 / approx as f64 > 4.0,
            "savings only {exact}/{approx}"
        );
    }

    #[test]
    fn ids_track_distances() {
        let dists = vec![9.0, 1.0, 8.0, 0.5, 7.0, 0.25];
        let cfg = HierarchicalConfig::exact(3, 2);
        let mut q = ApproxHierarchicalQueue::new(cfg);
        q.push_block(&dists, 100);
        let got = q.finalize();
        assert_eq!(got[0], (0.25, 105));
        assert_eq!(got[1], (0.5, 103));
        assert_eq!(got[2], (1.0, 101));
    }

    #[test]
    fn parallel_lanes_cycle_count() {
        // 16 lanes, 1600 pushes round-robin -> 100 replaces per lane ->
        // 200 cycles max (2 per replace).
        let cfg = HierarchicalConfig::exact(10, 16);
        let mut q = ApproxHierarchicalQueue::new(cfg);
        for i in 0..1600 {
            q.push(i as f32, i);
        }
        assert_eq!(q.cycles(), 200);
    }
}
