//! `chamvs-node` — a standalone ChamVS disaggregated memory-node server.
//!
//! The coordinator and the nodes agree on (dataset, n, seed, shard,
//! shards), so each process deterministically rebuilds its shard; in the
//! paper the coordinator ships the shard into the node's DRAM at init
//! time, which here would move the same bytes over localhost.
//!
//! Usage:
//!   chamvs-node --dataset SIFT --n 20000 --node-id 0 --nodes 2 [--k 100]
//!              [--shard S --shards N]
//! `--shard`/`--shards` pick the `Shard::carve` slice explicitly so
//! several processes can serve *replicas* of the same shard (defaults:
//! shard = node-id, shards = nodes — the unreplicated legacy layout).
//! Prints `LISTENING <addr>` once ready; the coordinator (see
//! examples/disaggregated.rs) connects to that address. The process exits
//! on a client Shutdown frame, or after a Drain frame once its last
//! connection closes.

use anyhow::Result;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::server::NodeServer;
use chameleon::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("chamvs-node error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse();
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 20_000);
    let node_id = args.get_usize("node-id", 0);
    let n_nodes = args.get_usize("nodes", 1);
    // Replication: several node processes may carve the SAME shard.
    let shard_id = args.get_usize("shard", node_id);
    let n_shards = args.get_usize("shards", n_nodes).max(1);
    let k = args.get_usize("k", 100);
    let seed = args.get_u64("seed", 42);
    anyhow::ensure!(
        shard_id < n_shards,
        "--shard {shard_id} out of range for --shards {n_shards}"
    );

    eprintln!(
        "[chamvs-node {node_id}/{n_nodes}] building shard {shard_id}/{n_shards} \
         ({} n={n})",
        ds.name
    );
    let data = SyntheticDataset::generate_sized(ds, n, 16, seed);
    let nlist = (n as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let codebook = index.pq.centroids.clone();

    let mut server = NodeServer::spawn_with(
        move || {
            let shard = Shard::carve(&index, shard_id, n_shards);
            MemoryNode::new(shard, ScanEngine::Native, k)
        },
        codebook,
        ds.nprobe,
    )?;
    println!("LISTENING {}", server.addr);
    // Stdout may be piped (CI parses the address from a file): flush now.
    use std::io::Write;
    std::io::stdout().flush()?;
    eprintln!("[chamvs-node {node_id}] serving on {}", server.addr);
    // Park the main thread until a client Shutdown frame stops the
    // server, then exit cleanly (CI smoke runs depend on this).
    while !server.stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    eprintln!("[chamvs-node {node_id}] shutdown requested, exiting");
    server.shutdown();
    Ok(())
}
