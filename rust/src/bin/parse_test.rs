// Debug utility: parse / compile / execute an artifact step by step.
use chameleon::runtime::{HostTensor, Runtime};

fn run() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "decode_dec_tiny_b1".into());
    let stage = std::env::args().nth(2).unwrap_or_else(|| "exec".into());
    let rt = Runtime::new("artifacts")?;
    let spec = rt.manifest.get(&name)?.clone();
    eprintln!("parse+spec OK: {} inputs {} outputs", spec.inputs.len(), spec.outputs.len());
    if stage == "parse" { return Ok(()); }
    let exe = rt.executor(&name, 7)?;
    eprintln!("compile+params OK ({} params)", exe.n_params());
    if stage == "compile" { return Ok(()); }
    let args: Vec<HostTensor> = spec.args().map(HostTensor::zeros).collect();
    eprintln!("calling with {} zero args ...", args.len());
    let outs = exe.call(&args)?;
    eprintln!("exec OK: {} outputs, out0 len {}", outs.len(), outs[0].len());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    std::thread::Builder::new()
        .stack_size(512 << 20)
        .spawn(run)?
        .join()
        .unwrap()
}
