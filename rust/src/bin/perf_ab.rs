// §Perf A/B harness for the ADC scan path, clean core:
//  * scalar vs SIMD GB/s/core per paper PQ width (m = 16/32/64),
//  * the historical m=64 unblocked vs L1-blocked scalar comparison,
//  * scalar vs SIMD LUT build over the shipped dataset geometries.
//
// `--kernel scalar|simd|avx2|avx512|neon|auto` picks the SIMD side without
// env vars (requests are clamped to host capability); `--n` / `--iters`
// resize the scan workload.
use chameleon::pq::scan::{scan_blocked_64, scan_unrolled_m64_unblocked};
use chameleon::pq::simd::{self, IsaKind, ScanKernels};
use chameleon::util::cli::Args;
use chameleon::util::rng::Rng;
use chameleon::util::stats::Summary;
use chameleon::util::timer::sample;

fn main() {
    let args = Args::parse();
    let req = args.get_or("kernel", "auto");
    let Some(kind) = IsaKind::parse(req) else {
        eprintln!("unknown --kernel '{req}' (want scalar|simd|avx2|avx512|neon|auto)");
        std::process::exit(2);
    };
    let simd_set = ScanKernels::for_kind(kind);
    let scalar_set = ScanKernels::scalar();
    let n = args.get_usize("n", 60_000);
    let iters = args.get_usize("iters", 30);

    println!(
        "detected ISA: {} ({})",
        simd::detect().name(),
        simd::detected_features()
    );
    let active = simd::active();
    for m in [16usize, 32, 64] {
        println!("installed kernel m={m:>2}: {}", active.kernel_name(m));
    }
    println!(
        "A/B kernel set: {} (requested '{req}', clamped to host)",
        simd_set.kind.name()
    );

    // Scalar vs SIMD ADC scan, one row per paper width. Outputs are also
    // checked bit-identical so the harness can't silently compare
    // different answers.
    println!("\nADC scan, n={n} codes/list:");
    println!(
        "{:<6} {:>12} {:>12} {:>9}",
        "width", "scalar GB/s", "simd GB/s", "speedup"
    );
    let mut rng = Rng::new(1);
    for m in [16usize, 32, 64] {
        let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..m * 256).map(|_| rng.f32()).collect();
        let bytes = (n * m) as f64;
        let mut out_sc = vec![0.0f32; n];
        let mut out_si = vec![0.0f32; n];
        scalar_set.scan_into(&codes, n, m, &lut, &mut out_sc);
        simd_set.scan_into(&codes, n, m, &lut, &mut out_si);
        assert_eq!(
            out_sc.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            out_si.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            "m={m}: SIMD kernel diverged from scalar reference"
        );
        let sc = Summary::of(&sample(3, iters, || {
            scalar_set.scan_into(&codes, n, m, &lut, &mut out_sc);
            out_sc[0]
        }));
        let si = Summary::of(&sample(3, iters, || {
            simd_set.scan_into(&codes, n, m, &lut, &mut out_si);
            out_si[0]
        }));
        println!(
            "m={m:<4} {:>12.2} {:>12.2} {:>8.2}x",
            bytes / sc.p50 / 1e9,
            bytes / si.p50 / 1e9,
            sc.p50 / si.p50
        );
    }

    // Historical scalar-vs-scalar A/B: is L1 column blocking still worth
    // it at m=64 on this host?
    {
        let m = 64usize;
        let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..m * 256).map(|_| rng.f32()).collect();
        let mut out = vec![0.0f32; n];
        let bytes = (n * m) as f64;
        let a = Summary::of(&sample(3, iters, || {
            scan_unrolled_m64_unblocked(&codes, n, &lut, &mut out);
            out[0]
        }));
        let b = Summary::of(&sample(3, iters, || {
            scan_blocked_64(&codes, n, &lut, &mut out);
            out[0]
        }));
        println!("\nm=64 scalar blocking A/B:");
        println!(
            "unblocked: p50={:.3}ms  {:.2} GB/s/core",
            a.p50 * 1e3,
            bytes / a.p50 / 1e9
        );
        println!(
            "blocked:   p50={:.3}ms  {:.2} GB/s/core",
            b.p50 * 1e3,
            bytes / b.p50 / 1e9
        );
        println!("speedup: {:.2}x", a.p50 / b.p50);
    }

    // Scalar vs SIMD LUT build over the shipped dataset geometries.
    println!("\nLUT build (one query), scalar vs simd:");
    println!(
        "{:<10} {:>9} {:>13} {:>11} {:>9}",
        "dataset", "m x dsub", "scalar us", "simd us", "speedup"
    );
    for (name, m, dsub) in [
        ("sift", 16usize, 8usize),
        ("deep", 16, 6),
        ("syn512", 32, 16),
        ("syn1024", 64, 16),
    ] {
        let centroids: Vec<f32> = (0..m * 256 * dsub).map(|_| rng.f32()).collect();
        let query: Vec<f32> = (0..m * dsub).map(|_| rng.f32()).collect();
        let mut lut_sc = vec![0.0f32; m * 256];
        let mut lut_si = vec![0.0f32; m * 256];
        scalar_set.build_lut_into(&centroids, &query, m, dsub, &mut lut_sc);
        simd_set.build_lut_into(&centroids, &query, m, dsub, &mut lut_si);
        assert_eq!(
            lut_sc.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            lut_si.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            "{name}: SIMD LUT build diverged from scalar reference"
        );
        let sc = Summary::of(&sample(10, 200, || {
            scalar_set.build_lut_into(&centroids, &query, m, dsub, &mut lut_sc);
            lut_sc[0]
        }));
        let si = Summary::of(&sample(10, 200, || {
            simd_set.build_lut_into(&centroids, &query, m, dsub, &mut lut_si);
            lut_si[0]
        }));
        let geom = format!("{m}x{dsub}");
        println!(
            "{name:<10} {geom:>9} {:>13.2} {:>11.2} {:>8.2}x",
            sc.p50 * 1e6,
            si.p50 * 1e6,
            sc.p50 / si.p50
        );
    }
}
