// §Perf A/B harness: unblocked vs L1-blocked m=64 ADC scan, clean core.
use chameleon::pq::scan::{adc_scan_into, scan_unrolled_m64_unblocked};
use chameleon::util::rng::Rng;
use chameleon::util::timer::sample;
use chameleon::util::stats::Summary;

fn main() {
    let mut rng = Rng::new(1);
    let (n, m) = (60_000usize, 64usize);
    let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
    let lut: Vec<f32> = (0..m * 256).map(|_| rng.f32()).collect();
    let mut out = vec![0.0f32; n];
    let bytes = (n * m) as f64;
    let a = Summary::of(&sample(5, 30, || {
        scan_unrolled_m64_unblocked(&codes, n, &lut, &mut out);
        out[0]
    }));
    let b = Summary::of(&sample(5, 30, || {
        adc_scan_into(&codes, n, m, &lut, &mut out);
        out[0]
    }));
    println!("m64 unblocked: p50={:.3}ms  {:.2} GB/s/core", a.p50*1e3, bytes/a.p50/1e9);
    println!("m64 blocked:   p50={:.3}ms  {:.2} GB/s/core", b.p50*1e3, bytes/b.p50/1e9);
    println!("speedup: {:.2}x", a.p50 / b.p50);
}
