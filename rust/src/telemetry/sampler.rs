//! Tail-based continuous trace sampling.
//!
//! Keeps three things, all bounded and preallocated-ish (the vectors are
//! reserved to capacity up front; steady state never grows them):
//!
//! - an Algorithm-R reservoir of exemplar requests, a uniform sample of
//!   all traffic;
//! - every flagged request (SLO breach / partial / shed), newest-wins in
//!   a bounded ring;
//! - the latest exemplar per latency histogram bucket, so "p99 regressed"
//!   links straight to a trace id living in the regressed bucket.
//!
//! This is a cold-ish path (one short uncontended mutex per completed
//! request, orders of magnitude cheaper than the retrieval it annotates);
//! the serving hot loop never blocks on a reader because snapshots copy
//! out under the same short lock.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::hist::{bucket_index, N_BUCKETS};

/// How a request ended, from the SLO's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Ok,
    SloBreach,
    Partial,
    Shed,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Ok => "ok",
            Verdict::SloBreach => "slo_breach",
            Verdict::Partial => "partial",
            Verdict::Shed => "shed",
        }
    }

    pub fn flagged(&self) -> bool {
        !matches!(self, Verdict::Ok)
    }
}

/// One sampled request.
#[derive(Clone, Copy, Debug)]
pub struct TailRecord {
    pub trace_id: u64,
    pub tenant: u32,
    pub total_us: u64,
    pub verdict: Verdict,
}

impl TailRecord {
    pub fn bucket(&self) -> usize {
        bucket_index(self.total_us)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("trace_id", Json::Num(self.trace_id as f64)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("total_us", Json::Num(self.total_us as f64)),
            ("bucket", Json::Num(self.bucket() as f64)),
            ("verdict", Json::Str(self.verdict.as_str().to_string())),
        ])
    }
}

struct Inner {
    rng: Rng,
    seen: u64,
    reservoir: Vec<TailRecord>,
    flagged: VecDeque<TailRecord>,
    flagged_dropped: u64,
    /// Latest record per latency bucket; flagged records displace
    /// unflagged ones, never the other way around within a scrape
    /// interval — the exemplar a bucket links to should be the
    /// interesting one.
    exemplars: Vec<Option<TailRecord>>,
}

pub struct TailSampler {
    reservoir_cap: usize,
    flagged_cap: usize,
    inner: Mutex<Inner>,
}

impl TailSampler {
    pub fn new(reservoir_cap: usize, flagged_cap: usize, seed: u64) -> Self {
        let reservoir_cap = reservoir_cap.max(1);
        let flagged_cap = flagged_cap.max(1);
        TailSampler {
            reservoir_cap,
            flagged_cap,
            inner: Mutex::new(Inner {
                rng: Rng::new(seed ^ 0x7a11_5a3d_9e37_79b9),
                seen: 0,
                reservoir: Vec::with_capacity(reservoir_cap),
                flagged: VecDeque::with_capacity(flagged_cap),
                flagged_dropped: 0,
                exemplars: vec![None; N_BUCKETS],
            }),
        }
    }

    /// Offer a completed request to the sampler.
    pub fn offer(&self, rec: TailRecord) {
        let mut g = self.inner.lock().unwrap();
        g.seen += 1;

        // Algorithm R over all traffic.
        if g.reservoir.len() < self.reservoir_cap {
            g.reservoir.push(rec);
        } else {
            let seen = g.seen as usize;
            let j = g.rng.below(seen);
            if j < self.reservoir_cap {
                g.reservoir[j] = rec;
            }
        }

        // Every flagged trace is kept until the ring wraps.
        if rec.verdict.flagged() {
            if g.flagged.len() == self.flagged_cap {
                g.flagged.pop_front();
                g.flagged_dropped += 1;
            }
            g.flagged.push_back(rec);
        }

        // Bucket exemplar: flagged beats unflagged.
        let b = rec.bucket();
        match &g.exemplars[b] {
            Some(prev) if prev.verdict.flagged() && !rec.verdict.flagged() => {}
            _ => g.exemplars[b] = Some(rec),
        }
    }

    /// The latest exemplar whose latency fell in `bucket`.
    pub fn exemplar(&self, bucket: usize) -> Option<TailRecord> {
        let g = self.inner.lock().unwrap();
        g.exemplars.get(bucket).copied().flatten()
    }

    pub fn seen(&self) -> u64 {
        self.inner.lock().unwrap().seen
    }

    pub fn flagged_count(&self) -> usize {
        self.inner.lock().unwrap().flagged.len()
    }

    /// Copy out everything (bounded by the configured caps).
    pub fn snapshot(&self) -> TailSnapshot {
        let g = self.inner.lock().unwrap();
        TailSnapshot {
            seen: g.seen,
            flagged_dropped: g.flagged_dropped,
            reservoir: g.reservoir.clone(),
            flagged: g.flagged.iter().copied().collect(),
            exemplars: g
                .exemplars
                .iter()
                .enumerate()
                .filter_map(|(b, r)| r.map(|r| (b, r)))
                .collect(),
        }
    }
}

/// Plain-data copy of the sampler state.
#[derive(Clone, Debug)]
pub struct TailSnapshot {
    pub seen: u64,
    pub flagged_dropped: u64,
    pub reservoir: Vec<TailRecord>,
    pub flagged: Vec<TailRecord>,
    pub exemplars: Vec<(usize, TailRecord)>,
}

impl TailSnapshot {
    /// JSON for the stats frame / scrape. Caps the embedded lists so a
    /// stats reply stays small even with large reservoirs.
    pub fn to_json(&self, max_items: usize) -> Json {
        let arr = |v: &[TailRecord]| {
            Json::Arr(v.iter().take(max_items).map(|r| r.to_json()).collect())
        };
        obj(vec![
            ("seen", Json::Num(self.seen as f64)),
            ("flagged_total", Json::Num(self.flagged.len() as f64)),
            ("flagged_dropped", Json::Num(self.flagged_dropped as f64)),
            ("reservoir", arr(&self.reservoir)),
            (
                "flagged",
                Json::Arr(
                    self.flagged
                        .iter()
                        .rev()
                        .take(max_items)
                        .map(|r| r.to_json())
                        .collect(),
                ),
            ),
            (
                "exemplars",
                Json::Arr(
                    self.exemplars
                        .iter()
                        .map(|(b, r)| {
                            let mut j = r.to_json();
                            if let Json::Obj(m) = &mut j {
                                m.insert("bucket".to_string(), Json::Num(*b as f64));
                            }
                            j
                        })
                        .collect(),
                ),
            ),
        ])
    }
}
