//! Process-wide metrics registry: named counters, gauges, and windowed
//! histograms behind stable dotted names with optional labels.
//!
//! Registration (name lookup) takes a mutex and may allocate — callers do
//! it once and hold the returned `Arc` handle. The handles themselves are
//! plain atomics: the steady-state path never locks or allocates.
//! Snapshots are tear-free at the counter level: the reader loops until
//! two consecutive passes over every scalar agree, so a scrape observes
//! one consistent cut of related counters instead of a field-by-field
//! race.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::{obj, Json};

use super::hist::{bucket_upper_us, HistAgg, HistogramConfig, WindowedHistogram};

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time value (queue depth, cache bytes, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Label set: small sorted `(key, value)` list, e.g.
/// `[("class", "interactive"), ("tenant", "3")]`.
pub type Labels = Vec<(String, String)>;

fn labels_of(pairs: &[(&str, &str)]) -> Labels {
    let mut v: Labels = pairs
        .iter()
        .map(|(k, val)| (k.to_string(), val.to_string()))
        .collect();
    v.sort();
    v
}

#[derive(Clone)]
enum Slot {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<WindowedHistogram>),
}

/// One metric in a snapshot (plain data).
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub labels: Labels,
    pub value: SampleValue,
}

#[derive(Clone, Debug)]
pub enum SampleValue {
    Counter(u64),
    Gauge(u64),
    /// `(lifetime totals, sliding-window view)`.
    Histogram(HistAgg, HistAgg),
}

pub struct Registry {
    metrics: Mutex<BTreeMap<(String, Labels), Slot>>,
    hist_cfg: HistogramConfig,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new(HistogramConfig::default())
    }
}

impl Registry {
    pub fn new(hist_cfg: HistogramConfig) -> Self {
        Registry {
            metrics: Mutex::new(BTreeMap::new()),
            hist_cfg,
        }
    }

    /// The process-global registry. Layers with no natural owner (the
    /// net client's reconnect/poison counters) register here; servers
    /// own their own registry so concurrent tests don't cross-talk, and
    /// merge the global one into their scrape output.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::default)
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_with(name, &[])
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = (name.to_string(), labels_of(labels));
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key)
            .or_insert_with(|| Slot::Counter(Arc::new(Counter::default())))
        {
            Slot::Counter(c) => c.clone(),
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauge_with(name, &[])
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = (name.to_string(), labels_of(labels));
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key)
            .or_insert_with(|| Slot::Gauge(Arc::new(Gauge::default())))
        {
            Slot::Gauge(g) => g.clone(),
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<WindowedHistogram> {
        self.histogram_with(name, &[])
    }

    pub fn histogram_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<WindowedHistogram> {
        let key = (name.to_string(), labels_of(labels));
        let cfg = self.hist_cfg;
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key)
            .or_insert_with(|| Slot::Histogram(Arc::new(WindowedHistogram::new(cfg))))
        {
            Slot::Histogram(h) => h.clone(),
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    /// Tear-free snapshot: re-reads every scalar until two consecutive
    /// passes agree (bounded retries), so counters that move together
    /// (requests vs replies) are observed from one consistent cut.
    pub fn snapshot(&self) -> Vec<Sample> {
        let slots: Vec<((String, Labels), Slot)> = {
            let m = self.metrics.lock().unwrap();
            m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let read_scalars = |slots: &[((String, Labels), Slot)]| -> Vec<u64> {
            slots
                .iter()
                .map(|(_, s)| match s {
                    Slot::Counter(c) => c.get(),
                    Slot::Gauge(g) => g.get(),
                    Slot::Histogram(h) => h.totals().count,
                })
                .collect()
        };
        let mut prev = read_scalars(&slots);
        for _ in 0..16 {
            let cur = read_scalars(&slots);
            if cur == prev {
                break;
            }
            prev = cur;
        }
        slots
            .into_iter()
            .zip(prev)
            .map(|(((name, labels), slot), scalar)| Sample {
                name,
                labels,
                value: match slot {
                    Slot::Counter(_) => SampleValue::Counter(scalar),
                    Slot::Gauge(_) => SampleValue::Gauge(scalar),
                    Slot::Histogram(h) => {
                        SampleValue::Histogram(h.totals(), h.window_agg())
                    }
                },
            })
            .collect()
    }

    /// Prometheus text exposition (version 0.0.4). Dotted names become
    /// underscore names; histograms emit cumulative `_bucket{le=...}`,
    /// `_sum` (seconds), and `_count` series from the lifetime totals.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for s in self.snapshot() {
            let name = s.name.replace('.', "_");
            match &s.value {
                SampleValue::Counter(v) => {
                    out.push_str(&format!(
                        "# TYPE {name} counter\n{name}{} {v}\n",
                        prom_labels(&s.labels, &[])
                    ));
                }
                SampleValue::Gauge(v) => {
                    out.push_str(&format!(
                        "# TYPE {name} gauge\n{name}{} {v}\n",
                        prom_labels(&s.labels, &[])
                    ));
                }
                SampleValue::Histogram(tot, _) => {
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    let mut cum = 0u64;
                    for (b, c) in tot.counts.iter().enumerate() {
                        cum += c;
                        let le = if b >= super::hist::N_BUCKETS - 1 {
                            "+Inf".to_string()
                        } else {
                            format!("{:.6}", bucket_upper_us(b) as f64 / 1e6)
                        };
                        out.push_str(&format!(
                            "{name}_bucket{} {cum}\n",
                            prom_labels(&s.labels, &[("le", &le)])
                        ));
                    }
                    out.push_str(&format!(
                        "{name}_sum{} {:.6}\n",
                        prom_labels(&s.labels, &[]),
                        tot.sum_us as f64 / 1e6
                    ));
                    out.push_str(&format!(
                        "{name}_count{} {}\n",
                        prom_labels(&s.labels, &[]),
                        tot.count
                    ));
                }
            }
        }
        out
    }

    /// JSON view of the registry: counters and gauges keyed by
    /// `name{label=value,...}`, histograms with quantiles over the
    /// sliding window plus lifetime count/sum.
    pub fn to_json(&self) -> Json {
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut hists = Vec::new();
        for s in self.snapshot() {
            let key = flat_key(&s.name, &s.labels);
            match s.value {
                SampleValue::Counter(v) => counters.push((key, Json::Num(v as f64))),
                SampleValue::Gauge(v) => gauges.push((key, Json::Num(v as f64))),
                SampleValue::Histogram(tot, win) => hists.push((
                    key,
                    obj(vec![
                        ("count", Json::Num(tot.count as f64)),
                        ("sum_us", Json::Num(tot.sum_us as f64)),
                        ("window_count", Json::Num(win.count as f64)),
                        ("p50_us", Json::Num(win.quantile_us(0.50) as f64)),
                        ("p95_us", Json::Num(win.quantile_us(0.95) as f64)),
                        ("p99_us", Json::Num(win.quantile_us(0.99) as f64)),
                    ]),
                )),
            }
        }
        let owned = |v: Vec<(String, Json)>| Json::Obj(v.into_iter().collect());
        obj(vec![
            ("counters", owned(counters)),
            ("gauges", owned(gauges)),
            ("histograms", owned(hists)),
        ])
    }
}

fn flat_key(name: &str, labels: &Labels) -> String {
    if labels.is_empty() {
        name.to_string()
    } else {
        let inner: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
        format!("{name}{{{}}}", inner.join(","))
    }
}

fn prom_labels(labels: &Labels, extra: &[(&str, &str)]) -> String {
    if labels.is_empty() && extra.is_empty() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect();
    parts.extend(extra.iter().map(|(k, v)| format!("{k}=\"{v}\"")));
    format!("{{{}}}", parts.join(","))
}
