//! SLO objectives and multi-window burn-rate algebra.
//!
//! An objective says "p<target> latency stays under `latency_us`, and at
//! least `availability` of requests complete fully". Burn rate is the
//! standard SRE ratio: observed bad fraction over allowed bad fraction.
//! 1.0 means the error budget is being consumed exactly at the sustainable
//! pace; 10.0 means ten times too fast. Burn is computed over two windows
//! of the same windowed histograms — the newest window (fast: reacts
//! within one window to a breach) and the whole retained horizon (slow:
//! smooths transients) — so an alert can require both to fire.

use crate::util::json::{obj, Json};

/// Per-class service level objective.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloObjective {
    /// Latency threshold in microseconds.
    pub latency_us: u64,
    /// Quantile that must meet the threshold, e.g. 0.99 allows 1% of
    /// requests over `latency_us`.
    pub target: f64,
    /// Fraction of requests that must complete fully (not partial, not
    /// shed), e.g. 0.999 allows one bad request per thousand.
    pub availability: f64,
}

impl Default for SloObjective {
    fn default() -> Self {
        SloObjective {
            latency_us: 50_000,
            target: 0.99,
            availability: 0.999,
        }
    }
}

/// Burn rate: `(bad / total) / allowed_bad_fraction`.
///
/// Degenerate cases pin down to: no traffic burns nothing (0.0); a zero
/// error budget with any bad event burns infinitely fast.
pub fn burn_rate(bad: u64, total: u64, allowed_bad_fraction: f64) -> f64 {
    if total == 0 || bad == 0 {
        return 0.0;
    }
    let frac = bad as f64 / total as f64;
    if allowed_bad_fraction <= 0.0 {
        return f64::INFINITY;
    }
    frac / allowed_bad_fraction
}

/// Fast/slow burn pair for one dimension (latency or availability).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BurnPair {
    pub fast: f64,
    pub slow: f64,
}

impl BurnPair {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("fast", Json::Num(finite(self.fast))),
            ("slow", Json::Num(finite(self.slow))),
        ])
    }
}

/// JSON has no Infinity; clamp to a large sentinel the dashboards treat
/// as "budget exhausted instantly".
fn finite(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        1e9
    }
}

/// Burn-rate report for one tenant.
#[derive(Clone, Debug)]
pub struct BurnReport {
    pub tenant: u32,
    pub class: &'static str,
    pub objective: SloObjective,
    pub latency: BurnPair,
    pub availability: BurnPair,
    /// Sliding-window sample count backing the latency burn.
    pub window_count: u64,
    pub p99_us: u64,
}

impl BurnReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("tenant", Json::Num(self.tenant as f64)),
            ("class", Json::Str(self.class.to_string())),
            ("slo_latency_us", Json::Num(self.objective.latency_us as f64)),
            ("slo_target", Json::Num(self.objective.target)),
            ("slo_availability", Json::Num(self.objective.availability)),
            ("latency_burn", self.latency.to_json()),
            ("availability_burn", self.availability.to_json()),
            ("window_count", Json::Num(self.window_count as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
        ])
    }
}
