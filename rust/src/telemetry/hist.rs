//! Sliding-window log-bucketed latency histograms.
//!
//! The hot path (`record`) is lock-free and allocation-free: every window
//! slot is preallocated at construction and recycled in place with a
//! seqlock-style generation word, the same idiom as [`crate::trace::ring`].
//! Writers bump atomic bucket counters; readers double-check the slot
//! generation and treat a slot that changed mid-read as empty. A torn or
//! racing sample is dropped from the *window* view (never from the
//! cumulative totals), which is the right trade for a sampling
//! instrument — the serving path must never wait on the observer.
//!
//! Values are recorded in microseconds into power-of-two buckets: bucket 0
//! holds the value 0 and bucket `b >= 1` covers `[2^(b-1), 2^b - 1]` µs.
//! With 32 buckets the top bucket is open-ended (> ~35 min), far beyond
//! any deadline this system serves.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Number of log2 buckets. Bucket 31 is the +Inf bucket.
pub const N_BUCKETS: usize = 32;

/// Log2 bucket for a microsecond value: 0 -> 0, v -> floor(log2(v)) + 1,
/// clamped to the open-ended top bucket.
pub fn bucket_index(v_us: u64) -> usize {
    (64 - v_us.leading_zeros() as usize).min(N_BUCKETS - 1)
}

/// Inclusive upper bound of a bucket in microseconds (`u64::MAX` for the
/// open-ended top bucket). `bucket_index(bucket_upper_us(b)) == b` for
/// every closed bucket.
pub fn bucket_upper_us(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= N_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

/// Shape of the sliding window: `windows` slots of `window` each; the
/// retained horizon is their product. Burn-rate math reads the newest
/// slot as the fast window and the whole horizon as the slow window.
#[derive(Clone, Copy, Debug)]
pub struct HistogramConfig {
    pub window: Duration,
    pub windows: usize,
}

impl Default for HistogramConfig {
    fn default() -> Self {
        HistogramConfig {
            window: Duration::from_secs(10),
            windows: 6,
        }
    }
}

/// One recyclable window slot. `seq` holds `2 * n` while the slot stably
/// contains window number `n`, and an odd value while a writer is zeroing
/// it for reuse — readers that observe an odd or changed `seq` discard
/// the slot.
struct WindowSlot {
    seq: AtomicU64,
    counts: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl WindowSlot {
    fn new(window_no: u64) -> Self {
        WindowSlot {
            seq: AtomicU64::new(2 * window_no),
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

/// Aggregated view over one or more window slots (plain data, no atomics).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistAgg {
    pub counts: [u64; N_BUCKETS],
    pub count: u64,
    pub sum_us: u64,
}

impl HistAgg {
    /// Number of samples strictly greater than `threshold_us`. Exact when
    /// the threshold is a bucket boundary (`2^k - 1` µs); otherwise the
    /// threshold is rounded up to its bucket's upper bound, so the result
    /// is a lower bound on the true breach count.
    pub fn count_above(&self, threshold_us: u64) -> u64 {
        let b = bucket_index(threshold_us);
        self.counts[b + 1..].iter().sum()
    }

    /// Upper bucket bound of the q-quantile (q in [0, 1]).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let rank = rank.max(1);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_us(b);
            }
        }
        bucket_upper_us(N_BUCKETS - 1)
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn merge(&mut self, other: &HistAgg) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }
}

/// Sliding-window histogram with cumulative lifetime totals.
///
/// Window slots answer "what happened recently" (SLO burn, `top`); the
/// cumulative per-bucket totals back the Prometheus exposition, which
/// expects monotone counters.
pub struct WindowedHistogram {
    cfg: HistogramConfig,
    epoch: Instant,
    slots: Box<[WindowSlot]>,
    total_counts: [AtomicU64; N_BUCKETS],
    total_count: AtomicU64,
    total_sum_us: AtomicU64,
}

impl WindowedHistogram {
    pub fn new(cfg: HistogramConfig) -> Self {
        let windows = cfg.windows.max(2);
        let cfg = HistogramConfig {
            window: cfg.window.max(Duration::from_millis(1)),
            windows,
        };
        WindowedHistogram {
            cfg,
            epoch: Instant::now(),
            slots: (0..windows as u64).map(WindowSlot::new).collect(),
            total_counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total_count: AtomicU64::new(0),
            total_sum_us: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> HistogramConfig {
        self.cfg
    }

    /// Record a value now.
    pub fn record(&self, v_us: u64) {
        self.record_at(v_us, self.epoch.elapsed());
    }

    /// Record a value at an explicit offset from the histogram epoch.
    /// The deterministic entry point for rotation tests; `record` is a
    /// thin wrapper over this.
    pub fn record_at(&self, v_us: u64, elapsed: Duration) {
        let b = bucket_index(v_us);
        // Lifetime totals never miss a sample.
        self.total_counts[b].fetch_add(1, Ordering::Relaxed);
        self.total_count.fetch_add(1, Ordering::Relaxed);
        self.total_sum_us.fetch_add(v_us, Ordering::Relaxed);

        let wn = self.window_no(elapsed);
        let slot = &self.slots[(wn % self.cfg.windows as u64) as usize];
        // Claim the slot for window `wn`, recycling it if it still holds
        // an older window. `seq` stores the absolute window number, so a
        // slot lapped while we stalled shows `seq > 2 * wn` and the
        // sample stays totals-only.
        loop {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == 2 * wn {
                break;
            }
            if seq > 2 * wn {
                return;
            }
            if seq & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            // `2 * wn - 1` is the odd in-progress marker for window `wn`;
            // wn >= 1 here because slot i is born stable at window i.
            if slot
                .seq
                .compare_exchange(seq, 2 * wn - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                for c in slot.counts.iter() {
                    c.store(0, Ordering::Relaxed);
                }
                slot.count.store(0, Ordering::Relaxed);
                slot.sum_us.store(0, Ordering::Relaxed);
                slot.seq.store(2 * wn, Ordering::Release);
                break;
            }
        }
        slot.counts[b].fetch_add(1, Ordering::Relaxed);
        slot.count.fetch_add(1, Ordering::Relaxed);
        slot.sum_us.fetch_add(v_us, Ordering::Relaxed);
    }

    fn window_no(&self, elapsed: Duration) -> u64 {
        (elapsed.as_nanos() / self.cfg.window.as_nanos().max(1)) as u64
    }

    /// Aggregate the newest `last_n` windows (including the current,
    /// possibly partial, one) as of `elapsed` past the epoch.
    pub fn aggregate_at(&self, last_n: usize, elapsed: Duration) -> HistAgg {
        let now_wn = self.window_no(elapsed);
        let first = now_wn.saturating_sub(last_n.max(1) as u64 - 1);
        let mut agg = HistAgg::default();
        for wn in first..=now_wn {
            let slot = &self.slots[(wn % self.cfg.windows as u64) as usize];
            // Seqlock read: two matching even observations of `2 * wn`
            // bracket a consistent copy. A slot holding another window
            // (or mid-recycle) contributes nothing.
            for _ in 0..8 {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 != 2 * wn {
                    break;
                }
                let mut counts = [0u64; N_BUCKETS];
                for (dst, src) in counts.iter_mut().zip(slot.counts.iter()) {
                    *dst = src.load(Ordering::Relaxed);
                }
                let count = slot.count.load(Ordering::Relaxed);
                let sum = slot.sum_us.load(Ordering::Relaxed);
                if slot.seq.load(Ordering::Acquire) == s1 {
                    agg.merge(&HistAgg {
                        counts,
                        count,
                        sum_us: sum,
                    });
                    break;
                }
            }
        }
        agg
    }

    /// Aggregate the newest `last_n` windows as of now.
    pub fn aggregate(&self, last_n: usize) -> HistAgg {
        self.aggregate_at(last_n, self.epoch.elapsed())
    }

    /// The whole retained horizon (all windows).
    pub fn window_agg(&self) -> HistAgg {
        self.aggregate(self.cfg.windows)
    }

    /// The newest window only (the "fast" burn-rate window).
    pub fn fast_agg(&self) -> HistAgg {
        self.aggregate(1)
    }

    /// Lifetime totals (monotone; backs the Prometheus exposition).
    pub fn totals(&self) -> HistAgg {
        let mut counts = [0u64; N_BUCKETS];
        for (dst, src) in counts.iter_mut().zip(self.total_counts.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistAgg {
            counts,
            count: self.total_count.load(Ordering::Relaxed),
            sum_us: self.total_sum_us.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        for b in 0..N_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_upper_us(b)), b, "upper of {b}");
        }
    }
}
