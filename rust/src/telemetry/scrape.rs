//! Minimal Prometheus text-exposition listener.
//!
//! Hand-rolled HTTP/1.0 over `std::net` + `util::poll` — enough for
//! `curl` and a Prometheus scraper, no crates. Every request (any path)
//! gets the full exposition and a `Connection: close`. The accept loop
//! runs on one thread, nonblocking, and exits promptly on `shutdown()`
//! via a stop flag plus a self-connect nudge (the same pattern the
//! coordinator's accept loop uses).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::poll::wait_readable;

use super::Telemetry;

pub struct MetricsServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `bind` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and
    /// serve `telemetry`'s exposition until `shutdown()`.
    pub fn spawn(bind: &str, telemetry: Arc<Telemetry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(bind)
            .with_context(|| format!("binding metrics listener on {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("metrics-scrape".into())
            .spawn(move || accept_loop(listener, telemetry, stop2))?;
        Ok(MetricsServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop out of its poll wait.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(unix)]
fn listener_fd(l: &TcpListener) -> i32 {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

#[cfg(not(unix))]
fn listener_fd(_l: &TcpListener) -> i32 {
    -1
}

fn accept_loop(listener: TcpListener, telemetry: Arc<Telemetry>, stop: Arc<AtomicBool>) {
    let fd = listener_fd(&listener);
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let _ = handle_scrape(stream, &telemetry);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if fd >= 0 {
                    let _ = wait_readable(&[fd], Duration::from_millis(200));
                } else {
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
            Err(_) => break,
        }
    }
}

fn handle_scrape(mut stream: TcpStream, telemetry: &Telemetry) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read the request head (we only need it consumed; any path works).
    let mut buf = [0u8; 1024];
    let mut head = Vec::new();
    loop {
        let n = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break,
        };
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
            break;
        }
    }
    let body = telemetry.render_prometheus();
    let reply = format!(
        "HTTP/1.0 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(reply.as_bytes())?;
    stream.flush()?;
    Ok(())
}
