//! Live telemetry plane: unified metrics registry, SLO burn-rate
//! tracking, and tail-based trace sampling.
//!
//! One [`Telemetry`] handle per coordinator owns a [`Registry`] (stable
//! dotted metric names, per-tenant/per-class labels), a per-tenant SLO
//! tracker fed by the same windowed histograms, and a [`TailSampler`]
//! keeping exemplar + breaching traces. The serving hot path calls
//! [`Telemetry::observe`] once per finished request; everything else
//! (protocol stats frames, the Prometheus listener, `chameleon top`)
//! reads snapshots. `Telemetry::off()` short-circuits the whole plane
//! for A/B overhead measurement, mirroring `Tracer::off()`.
//!
//! Metric name catalog (see README §Live telemetry):
//! - `coordinator.requests.received`, `coordinator.replies`,
//!   `coordinator.replies.partial`, `coordinator.shed`,
//!   `coordinator.backpressure_frames`, `coordinator.rounds`,
//!   `coordinator.batches_ge2`, `coordinator.max_batch`,
//!   `coordinator.teardowns`, `coordinator.accept_drops`,
//!   `coordinator.nodelay_fallbacks`, `coordinator.shutdown_denied`,
//!   `coordinator.stats_denied`, `coordinator.deadline_shed`
//! - `coordinator.shed_reason{reason=queue_full|rate_limited|deadline_expired}`
//! - `coordinator.request_latency_us{tenant,class}` (windowed histogram)
//! - `slo.latency_events{tenant}` / `slo.availability_events{tenant}`
//!   (windowed 0/1 histograms the burn rates are computed from)
//! - `admission.queued{tenant}` (gauge)
//! - `cluster.*` (rounds, retries, failovers, hedges, ... gauges
//!   mirrored from `ClusterStats` each dispatch round)
//! - `retcache.*` (misses, cache_hits, spec_hits, cache_bytes, ...)
//! - `net.reconnects`, `net.poisonings`, `net.heal_failures`
//!   (process-global: they live in `Registry::global()` and are merged
//!   into every scrape)

pub mod hist;
pub mod registry;
pub mod sampler;
pub mod scrape;
pub mod slo;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::admission::QosClass;
use crate::util::json::{obj, Json};

pub use hist::{bucket_index, bucket_upper_us, HistAgg, HistogramConfig, WindowedHistogram};
pub use registry::{Counter, Gauge, Registry, Sample, SampleValue};
pub use sampler::{TailRecord, TailSampler, TailSnapshot, Verdict};
pub use scrape::MetricsServer;
pub use slo::{burn_rate, BurnPair, BurnReport, SloObjective};

/// How a served request ended, as the telemetry plane sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Complete,
    Partial,
    Shed,
}

/// Telemetry plane configuration.
#[derive(Clone, Copy, Debug)]
pub struct TelemetryConfig {
    pub hist: HistogramConfig,
    /// Latency/availability objective per class; `None` disables burn
    /// tracking for that class (latency histograms still record).
    pub slo_interactive: Option<SloObjective>,
    pub slo_batch: Option<SloObjective>,
    pub reservoir_cap: usize,
    pub flagged_cap: usize,
    pub sampler_seed: u64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            hist: HistogramConfig::default(),
            slo_interactive: None,
            slo_batch: None,
            reservoir_cap: 64,
            flagged_cap: 256,
            sampler_seed: 0x5eed,
        }
    }
}

/// Per-tenant handles: the latency histogram plus the 0/1 event
/// histograms burn rates are computed from. All lock-free to record.
pub struct TenantTelemetry {
    pub tenant: u32,
    pub class: QosClass,
    pub objective: Option<SloObjective>,
    pub latency: Arc<WindowedHistogram>,
    /// 0 = met the latency objective, 1 = breached it.
    pub latency_events: Arc<WindowedHistogram>,
    /// 0 = completed fully, 1 = partial or shed.
    pub availability_events: Arc<WindowedHistogram>,
}

impl TenantTelemetry {
    /// Burn report over (fast = newest window, slow = whole horizon).
    pub fn burn(&self) -> Option<BurnReport> {
        let o = self.objective?;
        let burn_of = |h: &WindowedHistogram, allowed: f64| BurnPair {
            fast: {
                let a = h.fast_agg();
                burn_rate(a.count_above(0), a.count, allowed)
            },
            slow: {
                let a = h.window_agg();
                burn_rate(a.count_above(0), a.count, allowed)
            },
        };
        let win = self.latency.window_agg();
        Some(BurnReport {
            tenant: self.tenant,
            class: self.class.name(),
            objective: o,
            latency: burn_of(&self.latency_events, 1.0 - o.target),
            availability: burn_of(&self.availability_events, 1.0 - o.availability),
            window_count: win.count,
            p99_us: win.quantile_us(0.99),
        })
    }
}

pub struct Telemetry {
    enabled: bool,
    start: Instant,
    cfg: TelemetryConfig,
    registry: Registry,
    sampler: TailSampler,
    tenants: Mutex<HashMap<u32, Arc<TenantTelemetry>>>,
}

impl Telemetry {
    pub fn new(cfg: TelemetryConfig) -> Arc<Telemetry> {
        Arc::new(Telemetry {
            enabled: true,
            start: Instant::now(),
            cfg,
            registry: Registry::new(cfg.hist),
            sampler: TailSampler::new(cfg.reservoir_cap, cfg.flagged_cap, cfg.sampler_seed),
            tenants: Mutex::new(HashMap::new()),
        })
    }

    /// A disabled plane: `observe` is a branch-and-return, nothing is
    /// registered or sampled. The baseline arm of the overhead A/B.
    pub fn off() -> Arc<Telemetry> {
        Arc::new(Telemetry {
            enabled: false,
            start: Instant::now(),
            cfg: TelemetryConfig::default(),
            registry: Registry::default(),
            sampler: TailSampler::new(1, 1, 0),
            tenants: Mutex::new(HashMap::new()),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn sampler(&self) -> &TailSampler {
        &self.sampler
    }

    pub fn uptime_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn objective_for(&self, class: QosClass) -> Option<SloObjective> {
        match class {
            QosClass::Interactive => self.cfg.slo_interactive,
            QosClass::Batch => self.cfg.slo_batch,
        }
    }

    /// Get-or-create the per-tenant handles. Callers on the hot path
    /// cache the returned `Arc` per tenant; the map lock is only taken
    /// on first sight of a tenant (and here, for snapshot readers).
    pub fn tenant(&self, tenant: u32) -> Arc<TenantTelemetry> {
        let mut g = self.tenants.lock().unwrap();
        if let Some(t) = g.get(&tenant) {
            return t.clone();
        }
        let class = QosClass::of_gpu(tenant);
        let tstr = tenant.to_string();
        let labels: &[(&str, &str)] = &[("tenant", tstr.as_str()), ("class", class.name())];
        let t = Arc::new(TenantTelemetry {
            tenant,
            class,
            objective: self.objective_for(class),
            latency: self
                .registry
                .histogram_with("coordinator.request_latency_us", labels),
            latency_events: self
                .registry
                .histogram_with("slo.latency_events", &[("tenant", tstr.as_str())]),
            availability_events: self
                .registry
                .histogram_with("slo.availability_events", &[("tenant", tstr.as_str())]),
        });
        g.insert(tenant, t.clone());
        t
    }

    /// Record one finished request: latency histogram, SLO event
    /// histograms, and a tail-sampler offer. `latency_us` is meaningful
    /// for `Complete`/`Partial`; sheds record availability only.
    pub fn observe(&self, tenant: u32, latency_us: u64, outcome: Outcome, trace_id: u64) {
        if !self.enabled {
            return;
        }
        let t = self.tenant(tenant);
        self.observe_with(&t, latency_us, outcome, trace_id);
    }

    /// Same as [`observe`](Self::observe) with a pre-fetched tenant
    /// handle (the dispatch loop caches these).
    pub fn observe_with(
        &self,
        t: &TenantTelemetry,
        latency_us: u64,
        outcome: Outcome,
        trace_id: u64,
    ) {
        if !self.enabled {
            return;
        }
        let breached = match outcome {
            Outcome::Shed => {
                t.availability_events.record(1);
                false
            }
            Outcome::Partial => {
                t.latency.record(latency_us);
                t.availability_events.record(1);
                self.record_latency_event(t, latency_us)
            }
            Outcome::Complete => {
                t.latency.record(latency_us);
                t.availability_events.record(0);
                self.record_latency_event(t, latency_us)
            }
        };
        let verdict = match outcome {
            Outcome::Shed => Verdict::Shed,
            Outcome::Partial => Verdict::Partial,
            Outcome::Complete if breached => Verdict::SloBreach,
            Outcome::Complete => Verdict::Ok,
        };
        self.sampler.offer(TailRecord {
            trace_id,
            tenant: t.tenant,
            total_us: latency_us,
            verdict,
        });
    }

    fn record_latency_event(&self, t: &TenantTelemetry, latency_us: u64) -> bool {
        match t.objective {
            Some(o) => {
                let breached = latency_us > o.latency_us;
                t.latency_events.record(breached as u64);
                breached
            }
            None => false,
        }
    }

    /// Burn reports for every tenant seen so far (tenants without an
    /// objective are skipped).
    pub fn burn_rates(&self) -> Vec<BurnReport> {
        let tenants: Vec<Arc<TenantTelemetry>> =
            self.tenants.lock().unwrap().values().cloned().collect();
        let mut out: Vec<BurnReport> = tenants.iter().filter_map(|t| t.burn()).collect();
        out.sort_by_key(|b| b.tenant);
        out
    }

    /// Per-tenant latency summaries (always available, SLO or not).
    pub fn tenant_summaries(&self) -> Vec<Json> {
        let mut tenants: Vec<Arc<TenantTelemetry>> =
            self.tenants.lock().unwrap().values().cloned().collect();
        tenants.sort_by_key(|t| t.tenant);
        tenants
            .iter()
            .map(|t| {
                let win = t.latency.window_agg();
                let tot = t.latency.totals();
                let mut fields = vec![
                    ("tenant", Json::Num(t.tenant as f64)),
                    ("class", Json::Str(t.class.name().to_string())),
                    ("count", Json::Num(tot.count as f64)),
                    ("window_count", Json::Num(win.count as f64)),
                    ("p50_us", Json::Num(win.quantile_us(0.50) as f64)),
                    ("p95_us", Json::Num(win.quantile_us(0.95) as f64)),
                    ("p99_us", Json::Num(win.quantile_us(0.99) as f64)),
                    ("mean_us", Json::Num(win.mean_us())),
                ];
                if let Some(b) = t.burn() {
                    fields.push(("slo", b.to_json()));
                }
                obj(fields)
            })
            .collect()
    }

    /// Prometheus exposition: this plane's registry, then the
    /// process-global registry (net counters), then derived burn-rate
    /// gauges so alert rules need no PromQL gymnastics.
    pub fn render_prometheus(&self) -> String {
        let mut out = self.registry.render_prometheus();
        out.push_str(&Registry::global().render_prometheus());
        for b in self.burn_rates() {
            let t = b.tenant.to_string();
            out.push_str(&format!(
                "# TYPE slo_latency_burn gauge\n\
                 slo_latency_burn{{tenant=\"{t}\",window=\"fast\"}} {:.6}\n\
                 slo_latency_burn{{tenant=\"{t}\",window=\"slow\"}} {:.6}\n\
                 # TYPE slo_availability_burn gauge\n\
                 slo_availability_burn{{tenant=\"{t}\",window=\"fast\"}} {:.6}\n\
                 slo_availability_burn{{tenant=\"{t}\",window=\"slow\"}} {:.6}\n",
                finite_prom(b.latency.fast),
                finite_prom(b.latency.slow),
                finite_prom(b.availability.fast),
                finite_prom(b.availability.slow),
            ));
        }
        out.push_str(&format!(
            "# TYPE telemetry_uptime_seconds gauge\ntelemetry_uptime_seconds {:.3}\n",
            self.uptime_s()
        ));
        out
    }

    /// The JSON body of a `StatsResponse` (minus server-specific
    /// sections the coordinator appends). Stable keys; see README.
    pub fn stats_json(&self) -> Json {
        obj(vec![
            ("uptime_s", Json::Num(self.uptime_s())),
            ("tenants", Json::Arr(self.tenant_summaries())),
            (
                "slo",
                Json::Arr(self.burn_rates().iter().map(|b| b.to_json()).collect()),
            ),
            ("metrics", self.registry.to_json()),
            ("global", Registry::global().to_json()),
            ("tail", self.sampler.snapshot().to_json(16)),
        ])
    }
}

fn finite_prom(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        1e9
    }
}

/// Render the `chameleon top` dashboard from a stats JSON document (as
/// returned over a `StatsResponse` frame).
pub fn render_dashboard(j: &Json) -> String {
    let num = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let mut out = String::new();
    let up = num(j, "uptime_s");
    out.push_str(&format!("chameleon top — coordinator up {up:.1}s\n"));
    if let Some(server) = j.get("server") {
        out.push_str(&format!(
            "requests: received {:>8}  replies {:>8}  partial {:>6}  shed {:>6}\n\
             rounds:   {:>8}  max batch {:>4}  teardowns {:>4}  accept drops {:>4}\n",
            num(server, "received") as u64,
            num(server, "replies") as u64,
            num(server, "partial") as u64,
            num(server, "shed") as u64,
            num(server, "rounds") as u64,
            num(server, "max_batch") as u64,
            num(server, "teardowns") as u64,
            num(server, "accept_drops") as u64,
        ));
    }
    if let Some(Json::Arr(tenants)) = j.get("tenants") {
        out.push_str(&format!(
            "\n{:>7} {:>12} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10}\n",
            "tenant", "class", "win_reqs", "p50_ms", "p95_ms", "p99_ms", "lat_burn", "avail_burn"
        ));
        for t in tenants {
            let (lat_burn, avail_burn) = match t.get("slo") {
                Some(s) => (
                    format!("{:.2}", num(&s.get("latency_burn").cloned().unwrap_or(Json::Null), "fast")),
                    format!(
                        "{:.2}",
                        num(&s.get("availability_burn").cloned().unwrap_or(Json::Null), "fast")
                    ),
                ),
                None => ("-".to_string(), "-".to_string()),
            };
            out.push_str(&format!(
                "{:>7} {:>12} {:>9} {:>9.2} {:>9.2} {:>9.2} {:>10} {:>10}\n",
                num(t, "tenant") as u64,
                t.get("class").and_then(|c| c.as_str()).unwrap_or("?"),
                num(t, "window_count") as u64,
                num(t, "p50_us") / 1e3,
                num(t, "p95_us") / 1e3,
                num(t, "p99_us") / 1e3,
                lat_burn,
                avail_burn,
            ));
        }
    }
    if let Some(g) = j.get("metrics").and_then(|m| m.get("gauges")) {
        if let Some(m) = g.as_obj() {
            let cluster: Vec<String> = m
                .iter()
                .filter(|(k, _)| k.starts_with("cluster."))
                .map(|(k, v)| {
                    format!("{} {}", &k["cluster.".len()..], v.as_f64().unwrap_or(0.0) as u64)
                })
                .collect();
            if !cluster.is_empty() {
                out.push_str(&format!("\ncluster: {}\n", cluster.join("  ")));
            }
        }
    }
    if let Some(tail) = j.get("tail") {
        out.push_str(&format!(
            "\ntail: sampled {} — {} flagged traces retained\n",
            num(tail, "seen") as u64,
            num(tail, "flagged_total") as u64,
        ));
        if let Some(Json::Arr(flagged)) = tail.get("flagged") {
            for f in flagged.iter().take(5) {
                out.push_str(&format!(
                    "  trace {:>16x} tenant {:>4} {:>9.2} ms  {}\n",
                    num(f, "trace_id") as u64,
                    num(f, "tenant") as u64,
                    num(f, "total_us") / 1e3,
                    f.get("verdict").and_then(|v| v.as_str()).unwrap_or("?"),
                ));
            }
        }
    }
    out
}
