//! Configuration system: the model zoo (paper Table 2), dataset zoo
//! (paper Table 3), and system topology.
//!
//! Each paper-scale config carries a *scaled* execution counterpart so the
//! whole stack runs for real on this testbed (PJRT CPU client, no
//! FPGAs/GPUs), while the `hwmodel` module projects paper-scale numbers.

use crate::util::json::{obj, Json};

/// A RALM model configuration (paper Table 2).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub dim: usize,
    pub n_layers: usize,
    pub enc_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
    /// Retrieval interval in tokens (1 = retrieve every step).
    pub interval: usize,
    /// Neighbors fetched per retrieval.
    pub k: usize,
    /// Which AOT decode artifact executes this model (scaled variants only;
    /// paper-scale models are projected via hwmodel).
    pub artifact: Option<&'static str>,
}

impl ModelConfig {
    pub const fn is_encdec(&self) -> bool {
        self.enc_layers > 0
    }

    /// Analytic parameter count, mirroring python `ModelConfig.param_count`.
    /// Encoder-decoder models carry a separate encoder embedding table
    /// (with it, EncDec-L lands exactly on Table 2's 1738M).
    pub fn param_count(&self) -> usize {
        let (d, v) = (self.dim, self.vocab);
        let ffn = 4 * d;
        let cross = if self.is_encdec() { 4 * d * d } else { 0 };
        let per_dec = 4 * d * d + 2 * d * ffn + cross;
        let per_enc = 4 * d * d + 2 * d * ffn;
        let enc_embed = if self.is_encdec() { v * d } else { 0 };
        v * d
            + enc_embed
            + self.max_seq * d
            + self.n_layers * per_dec
            + self.enc_layers * per_enc
    }

    /// FLOPs for one decode step (used by the GPU/TPU cost models).
    pub fn decode_flops(&self) -> f64 {
        let d = self.dim as f64;
        let ffn = 4.0 * d;
        let cross = if self.is_encdec() { 4.0 * d * d } else { 0.0 };
        let per_layer = 2.0 * (4.0 * d * d + cross + 2.0 * d * ffn);
        self.n_layers as f64 * per_layer + 2.0 * self.vocab as f64 * d
    }

    /// FLOPs for one encoder pass over the retrieved chunks (EncDec only).
    pub fn encode_flops(&self) -> f64 {
        if !self.is_encdec() {
            return 0.0;
        }
        let d = self.dim as f64;
        let s = (self.k * CHUNK_LEN) as f64;
        let ffn = 4.0 * d;
        let per_layer = 2.0 * s * (4.0 * d * d + 2.0 * d * ffn) + 2.0 * s * s * d;
        self.enc_layers as f64 * per_layer
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.into())),
            ("dim", Json::Num(self.dim as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("params", Json::Num(self.param_count() as f64)),
            ("interval", Json::Num(self.interval as f64)),
            ("k", Json::Num(self.k as f64)),
        ])
    }
}

/// Tokens per retrieved chunk for encoder-decoder models.
pub const CHUNK_LEN: usize = 8;

/// Paper Table 2: Dec-S (101M, interval 1, K=100).
pub const DEC_S: ModelConfig = ModelConfig {
    name: "dec_s",
    dim: 512,
    n_layers: 24,
    enc_layers: 0,
    n_heads: 8,
    vocab: 50_000,
    max_seq: 512,
    interval: 1,
    k: 100,
    artifact: None,
};

/// Paper Table 2: Dec-L (1259M, interval 1, K=100).
pub const DEC_L: ModelConfig = ModelConfig {
    name: "dec_l",
    dim: 1024,
    n_layers: 96,
    enc_layers: 0,
    n_heads: 16,
    vocab: 50_000,
    max_seq: 512,
    interval: 1,
    k: 100,
    artifact: None,
};

/// Paper Table 2: EncDec-S (158M, interval 8/64/512, K=10).
pub const ENCDEC_S: ModelConfig = ModelConfig {
    name: "encdec_s",
    dim: 512,
    n_layers: 24,
    enc_layers: 2,
    n_heads: 8,
    vocab: 50_000,
    max_seq: 512,
    interval: 8,
    k: 10,
    artifact: None,
};

/// Paper Table 2: EncDec-L (1738M, interval 8/64/512, K=10).
pub const ENCDEC_L: ModelConfig = ModelConfig {
    name: "encdec_l",
    dim: 1024,
    n_layers: 96,
    enc_layers: 2,
    n_heads: 16,
    vocab: 50_000,
    max_seq: 512,
    interval: 8,
    k: 10,
    artifact: None,
};

/// Scaled decoder that actually executes on the PJRT CPU client.
pub const DEC_TINY: ModelConfig = ModelConfig {
    name: "dec_tiny",
    dim: 128,
    n_layers: 4,
    enc_layers: 0,
    n_heads: 4,
    vocab: 2048,
    max_seq: 512,
    interval: 1,
    k: 10,
    artifact: Some("decode_dec_tiny_b1"),
};

/// Scaled encoder-decoder executing on the PJRT CPU client.
pub const ENCDEC_TINY: ModelConfig = ModelConfig {
    name: "encdec_tiny",
    dim: 128,
    n_layers: 4,
    enc_layers: 2,
    n_heads: 4,
    vocab: 2048,
    max_seq: 512,
    interval: 8,
    k: 4,
    artifact: Some("decode_encdec_tiny_b1"),
};

pub const PAPER_MODELS: [&ModelConfig; 4] = [&DEC_S, &DEC_L, &ENCDEC_S, &ENCDEC_L];

pub fn model_by_name(name: &str) -> Option<&'static ModelConfig> {
    [&DEC_S, &DEC_L, &ENCDEC_S, &ENCDEC_L, &DEC_TINY, &ENCDEC_TINY]
        .into_iter()
        .find(|m| m.name == name)
}

/// A vector dataset configuration (paper Table 3).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetConfig {
    pub name: &'static str,
    /// Paper-scale vector count (always 1e9 in Table 3).
    pub n_paper: usize,
    /// Scaled vector count actually generated on this testbed.
    pub n_scaled: usize,
    pub d: usize,
    pub m: usize,
    pub nlist_paper: usize,
    pub nlist_scaled: usize,
    pub nprobe: usize,
}

impl DatasetConfig {
    pub const fn dsub(&self) -> usize {
        self.d / self.m
    }

    /// Bytes of PQ codes + vector IDs at paper scale (Table 3 last row
    /// counts 8-byte IDs alongside m-byte codes).
    pub fn paper_bytes(&self) -> usize {
        self.n_paper * (self.m + 8)
    }

    /// Bytes of PQ codes scanned per query at a given scale.
    pub fn scan_bytes_per_query(&self, n: usize, nlist: usize) -> f64 {
        // nprobe lists out of nlist, balanced lists.
        n as f64 * self.nprobe as f64 / nlist as f64 * self.m as f64
    }

    /// Which chamvs_scan artifact serves this dataset's PQ width.
    pub fn scan_artifact(&self) -> String {
        format!("chamvs_scan_m{}", self.m)
    }

    pub fn ivf_artifact(&self, batch: usize) -> String {
        format!("ivf_scan_d{}_b{}", self.d, batch)
    }
}

/// SIFT1B: D=128, 16-byte PQ.
pub const SIFT: DatasetConfig = DatasetConfig {
    name: "SIFT",
    n_paper: 1_000_000_000,
    n_scaled: 200_000,
    d: 128,
    m: 16,
    nlist_paper: 32_768,
    nlist_scaled: 1024,
    nprobe: 32,
};

/// Deep1B: D=96 in the paper; padded to 128 here so PQ sub-spaces stay
/// 8-wide (the paper's own SYN datasets replicate SIFT the same way).
pub const DEEP: DatasetConfig = DatasetConfig {
    name: "Deep",
    n_paper: 1_000_000_000,
    n_scaled: 200_000,
    d: 96,
    m: 16,
    nlist_paper: 32_768,
    nlist_scaled: 1024,
    nprobe: 32,
};

/// SYN-512: D=512, 32-byte PQ (RALM-dimensioned).
pub const SYN512: DatasetConfig = DatasetConfig {
    name: "SYN-512",
    n_paper: 1_000_000_000,
    n_scaled: 100_000,
    d: 512,
    m: 32,
    nlist_paper: 32_768,
    nlist_scaled: 1024,
    nprobe: 32,
};

/// SYN-1024: D=1024, 64-byte PQ.
pub const SYN1024: DatasetConfig = DatasetConfig {
    name: "SYN-1024",
    n_paper: 1_000_000_000,
    n_scaled: 50_000,
    d: 1024,
    m: 64,
    nlist_paper: 32_768,
    nlist_scaled: 1024,
    nprobe: 32,
};

pub const DATASETS: [&DatasetConfig; 4] = [&SIFT, &DEEP, &SYN512, &SYN1024];

pub fn dataset_by_name(name: &str) -> Option<&'static DatasetConfig> {
    DATASETS.into_iter().find(|d| d.name.eq_ignore_ascii_case(name))
}

/// System topology: how many of each accelerator, and where artifacts live.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub artifacts_dir: String,
    pub n_memory_nodes: usize,
    pub n_gpus: usize,
    pub k: usize,
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            artifacts_dir: std::env::var("CHAMELEON_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".to_string()),
            n_memory_nodes: 1,
            n_gpus: 1,
            k: 100,
            seed: 0xC4A7,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_table2() {
        // Table 2: Dec-S 101M, Dec-L 1259M, EncDec-S 158M, EncDec-L 1738M.
        assert!((DEC_S.param_count() as f64 / 101e6 - 1.0).abs() < 0.02);
        assert!((DEC_L.param_count() as f64 / 1259e6 - 1.0).abs() < 0.02);
        assert!((ENCDEC_S.param_count() as f64 / 158e6 - 1.0).abs() < 0.06);
        assert!((ENCDEC_L.param_count() as f64 / 1738e6 - 1.0).abs() < 0.06);
    }

    #[test]
    fn dataset_lookup() {
        assert_eq!(dataset_by_name("sift").unwrap().m, 16);
        assert_eq!(dataset_by_name("SYN-512").unwrap().d, 512);
        assert!(dataset_by_name("nope").is_none());
    }

    #[test]
    fn table3_pq_bytes() {
        // Table 3: PQ + vec ID = 24 GB for SIFT/Deep, 40 GB SYN-512, 72 GB SYN-1024.
        assert_eq!(SIFT.paper_bytes(), 24_000_000_000);
        assert_eq!(SYN512.paper_bytes(), 40_000_000_000);
        assert_eq!(SYN1024.paper_bytes(), 72_000_000_000);
    }

    #[test]
    fn decode_flops_positive_and_ordered() {
        assert!(DEC_L.decode_flops() > DEC_S.decode_flops());
        assert!(ENCDEC_S.encode_flops() > 0.0);
        assert_eq!(DEC_S.encode_flops(), 0.0);
    }
}
