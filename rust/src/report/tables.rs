//! Fig 7, Fig 8, Table 4, Table 5 — the K-selection analysis and the
//! resource/energy tables (all analytic/model-driven, like the paper's).

use crate::config::{DatasetConfig, DATASETS};
use crate::hwmodel::energy::{chamvs_energy_per_query, cpu_energy_per_query};
use crate::hwmodel::fpga::FpgaModel;
use crate::hwmodel::{CpuModel, GpuModel};
use crate::kselect::binomial::{exceed_probability, hold_probability, required_depth};
use crate::kselect::hierarchical::agreement_rate;
use crate::kselect::HierarchicalConfig;

fn paper_codes(ds: &DatasetConfig) -> usize {
    (ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64) as usize
}

/// Fig 7: p(k) and P(k) for one of 16 L1 queues holding k of the top-100,
/// plus a Monte-Carlo agreement check of the truncated queue.
pub fn fig7_probability() -> String {
    let (big_k, q) = (100usize, 16usize);
    let mut out = String::new();
    out.push_str("Fig 7 — P[one of 16 L1 queues holds k of top-100]\n");
    out.push_str("k    p(k)        P(<=k)      bar\n");
    let mut cum = 0.0;
    for k in 0..=24 {
        let p = hold_probability(big_k, q, k);
        cum += p;
        let bar = "#".repeat((p * 250.0) as usize);
        out.push_str(&format!("{k:<4} {p:<11.6} {cum:<11.6} {bar}\n"));
    }
    let depth = required_depth(big_k, q, 0.99);
    out.push_str(&format!(
        "\n99%-identical truncation depth: {depth} (exceed prob/queue {:.2e})\n",
        exceed_probability(big_k, q, depth)
    ));
    let rate = agreement_rate(
        HierarchicalConfig::approximate(big_k, q, 0.99),
        16_384,
        300,
        42,
    );
    out.push_str(&format!(
        "Monte-Carlo agreement of truncated queue (300 queries): {:.1}%\n",
        rate * 100.0
    ));
    out
}

/// Fig 8: hardware resource savings of the approximate hierarchical queue
/// vs the exact module, sweeping the number of L1 queues.
pub fn fig8_resources() -> String {
    let k = 100;
    let mut out = String::new();
    out.push_str("Fig 8 — priority-queue resource units (K=100, 99% identical)\n");
    out.push_str("queues  exact_units  approx_units  savings  depth\n");
    for &q in &[2usize, 4, 8, 16, 32, 64] {
        let exact = HierarchicalConfig::exact(k, q).resource_units();
        let approx = HierarchicalConfig::approximate(k, q, 0.99);
        out.push_str(&format!(
            "{q:<7} {exact:<12} {:<13} {:<8.2} {}\n",
            approx.resource_units(),
            exact as f64 / approx.resource_units() as f64,
            approx.l1_depth,
        ));
    }
    out
}

/// Table 4: FPGA resource fractions per dataset.
pub fn table4_resources() -> String {
    let f = FpgaModel::default();
    let mut out = String::new();
    out.push_str("Table 4 — ChamVS accelerator resource fractions (U250)\n");
    out.push_str("Dataset    LUT     FF      BRAM    URAM    DSP\n");
    for ds in DATASETS {
        let lanes = 2 * f.n_decoding_units(ds.m);
        let kcfg = HierarchicalConfig::approximate(100, lanes, 0.99);
        let r = f.resources(ds.m, &kcfg).fraction_of_u250();
        out.push_str(&format!(
            "{:<10} {:<7.1} {:<7.1} {:<7.1} {:<7.1} {:<7.1}\n",
            ds.name,
            r[0] * 100.0,
            r[1] * 100.0,
            r[2] * 100.0,
            r[3] * 100.0,
            r[4] * 100.0,
        ));
    }
    out.push_str("(percent; paper band: LUT 23-28, FF 15-19, DSP 8-12)\n");
    out
}

/// Table 5: energy per query (mJ), CPU vs ChamVS, b in {1,4,16}.
pub fn table5_energy() -> String {
    let cpu = CpuModel::default();
    let fpga = FpgaModel::default();
    let gpu = GpuModel::default();
    let mut out = String::new();
    out.push_str("Table 5 — energy per query (mJ)\n");
    out.push_str("Dataset    CPU b=1   b=4     b=16    | ChamVS b=1  b=4    b=16   | ratio(b=1)\n");
    for ds in DATASETS {
        let codes = paper_codes(ds);
        let e_cpu: Vec<f64> = [1, 4, 16]
            .iter()
            .map(|&b| cpu_energy_per_query(&cpu, ds, codes, b) * 1e3)
            .collect();
        let e_chm: Vec<f64> = [1, 4, 16]
            .iter()
            .map(|&b| chamvs_energy_per_query(&fpga, &gpu, ds, codes, b) * 1e3)
            .collect();
        out.push_str(&format!(
            "{:<10} {:<9.1} {:<7.1} {:<7.1} | {:<11.1} {:<6.1} {:<6.1} | {:.1}x\n",
            ds.name,
            e_cpu[0],
            e_cpu[1],
            e_cpu[2],
            e_chm[0],
            e_chm[1],
            e_chm[2],
            e_cpu[0] / e_chm[0],
        ));
    }
    out.push_str("(paper: CPU 950.3/434.0/143.3 mJ on SIFT; ChamVS 53.6/28.2/21.5)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_renders_rows() {
        let s = fig7_probability();
        assert!(s.contains("P[one of 16"));
        assert!(s.lines().count() > 20);
        assert!(s.contains("Monte-Carlo"));
    }

    #[test]
    fn fig8_shows_savings() {
        let s = fig8_resources();
        assert!(s.contains("64"));
        // Savings column must grow with queue count.
        assert!(s.lines().count() >= 8);
    }

    #[test]
    fn table4_has_all_datasets() {
        let s = table4_resources();
        for name in ["SIFT", "Deep", "SYN-512", "SYN-1024"] {
            assert!(s.contains(name), "{name} missing");
        }
    }

    #[test]
    fn table5_ratio_in_band() {
        let s = table5_energy();
        assert!(s.contains("ratio"));
    }
}
