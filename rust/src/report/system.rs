//! Fig 11 (RALM inference latency), Fig 12 (throughput) and Fig 13
//! (accelerator ratio) — the end-to-end system experiments.

use crate::config::{ModelConfig, DEC_L, DEC_S, ENCDEC_L, ENCDEC_S, SYN1024, SYN512};
use crate::hwmodel::fpga::FpgaModel;
use crate::hwmodel::{CpuModel, GpuModel};

/// Modeled per-step latency of a RALM inference step for a system.
/// `chameleon=true` -> FPGA-GPU retrieval; false -> CPU retrieval baseline.
pub fn step_latency(
    model: &ModelConfig,
    batch: usize,
    retrieval_step: bool,
    chameleon: bool,
    gpu: &GpuModel,
    cpu: &CpuModel,
    fpga: &FpgaModel,
) -> f64 {
    let ds = if model.dim >= 1024 { &SYN1024 } else { &SYN512 };
    let mut t = gpu.decode_step_latency(model, batch);
    if retrieval_step {
        let codes =
            (ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64) as usize;
        t += if chameleon {
            gpu.index_scan_latency(ds.nlist_paper, ds.d, batch)
                + fpga.batch_latency(batch, codes, ds.m, ds.nprobe, model.k)
                + crate::hwmodel::loggp::LogGp::default().query_roundtrip(
                    1,
                    4 * ds.d + 4 * ds.nprobe,
                    12 * model.k,
                )
        } else {
            batch as f64
                * cpu.query_latency(1, codes, ds.m, ds.dsub(), ds.nlist_paper, ds.nprobe)
        };
        if model.is_encdec() {
            t += gpu.encode_latency(model, batch);
        }
    }
    t
}

/// Fig 11: latency over token-generation steps for the four models at
/// their retrieval intervals, Chameleon vs CPU-GPU baseline.
pub fn fig11_latency(n_tokens: usize) -> String {
    let (gpu, cpu, fpga) = (GpuModel::default(), CpuModel::default(), FpgaModel::default());
    let mut out = String::new();
    out.push_str("Fig 11 — RALM inference latency per step (b=1; ms)\n");
    out.push_str(
        "model     interval system     step(no-retr) step(retr) seq_total(s) speedup@retr\n",
    );
    for (model, interval) in [
        (&DEC_S, 1usize),
        (&DEC_L, 1),
        (&ENCDEC_S, 8),
        (&ENCDEC_L, 8),
    ] {
        let mut m = model.clone();
        m.interval = interval;
        let row = |chameleon: bool| -> (f64, f64, f64) {
            let plain = step_latency(&m, 1, false, chameleon, &gpu, &cpu, &fpga);
            let retr = step_latency(&m, 1, true, chameleon, &gpu, &cpu, &fpga);
            let total: f64 = (0..n_tokens)
                .map(|s| {
                    if s % interval == 0 {
                        retr
                    } else {
                        plain
                    }
                })
                .sum();
            (plain, retr, total)
        };
        let (bp, br, bt) = row(false);
        let (cp, cr, ct) = row(true);
        out.push_str(&format!(
            "{:<9} {:<8} {:<10} {:>12.3} {:>10.3} {:>12.3} {:>8}\n",
            m.name, interval, "CPU-GPU", bp * 1e3, br * 1e3, bt, "-"
        ));
        out.push_str(&format!(
            "{:<9} {:<8} {:<10} {:>12.3} {:>10.3} {:>12.3} {:>7.2}x\n",
            m.name,
            interval,
            "Chameleon",
            cp * 1e3,
            cr * 1e3,
            ct,
            br / cr,
        ));
    }
    out.push_str("(paper speedup at retrieval steps: 1.94-4.11x Dec-S, 1.71-3.02x Dec-L,\n");
    out.push_str(" 1.76-3.41x EncDec-S, 1.29-2.13x EncDec-L)\n");
    out
}

/// Fig 12: throughput across retrieval intervals.
pub fn fig12_throughput(n_tokens: usize) -> String {
    let (gpu, cpu, fpga) = (GpuModel::default(), CpuModel::default(), FpgaModel::default());
    let mut out = String::new();
    out.push_str("Fig 12 — RALM inference throughput (tokens/s)\n");
    out.push_str("model     interval batch  baseline   chameleon  speedup\n");
    let cases: [(&ModelConfig, &[usize], usize); 4] = [
        (&DEC_S, &[1], 64),
        (&DEC_L, &[1], 8),
        (&ENCDEC_S, &[8, 64, 512], 64),
        (&ENCDEC_L, &[8, 64, 512], 8),
    ];
    for (model, intervals, batch) in cases {
        for &interval in intervals {
            let mut m = model.clone();
            m.interval = interval;
            let tput = |chameleon: bool| -> f64 {
                let plain = step_latency(&m, batch, false, chameleon, &gpu, &cpu, &fpga);
                let retr = step_latency(&m, batch, true, chameleon, &gpu, &cpu, &fpga);
                let total: f64 = (0..n_tokens)
                    .map(|s| if s % interval == 0 { retr } else { plain })
                    .sum();
                (batch * n_tokens) as f64 / total
            };
            let base = tput(false);
            let cham = tput(true);
            out.push_str(&format!(
                "{:<9} {:<8} {:<6} {:>9.1} {:>10.1} {:>7.2}x\n",
                m.name,
                interval,
                batch,
                base,
                cham,
                cham / base,
            ));
        }
    }
    out.push_str("(paper: 3.18x Dec-S, 2.34x Dec-L at interval=1; gains shrink as interval grows)\n");
    out
}

/// Fig 13: GPUs needed to saturate one ChamVS engine per configuration.
pub fn fig13_ratio() -> String {
    let (gpu, fpga) = (GpuModel::default(), FpgaModel::default());
    let rows = crate::coordinator::ratio::fig13_sweep(&gpu, &fpga);
    let mut out = String::new();
    out.push_str("Fig 13 — GPUs to saturate one ChamVS engine\n");
    out.push_str("model     dataset   interval batch  tokens/s/GPU  ChamVS qps  GPUs/ChamVS\n");
    for r in &rows {
        out.push_str(&format!(
            "{:<9} {:<9} {:<8} {:<6} {:>12.1} {:>11.1} {:>11.1}\n",
            r.model,
            r.dataset,
            r.interval,
            r.batch,
            r.gpu_tokens_per_s,
            r.chamvs_qps,
            r.gpus_per_chamvs,
        ));
    }
    let min = rows.iter().map(|r| r.gpus_per_chamvs).fold(f64::MAX, f64::min);
    let max = rows.iter().map(|r| r.gpus_per_chamvs).fold(0.0, f64::max);
    out.push_str(&format!(
        "range: {min:.1} .. {max:.0} (paper: 0.2 .. 442) — disaggregation required\n"
    ));
    out
}

/// Retcache serve report: modeled throughput of the cached + speculative
/// serving path vs the seed synchronous path over Zipf-skewed repeated
/// query streams, sweeping cache capacity x workload skew, followed by
/// the cache-hit/miss + speculation-accuracy counter block.
pub fn retcache_report(n_scaled: usize, seed: u64) -> String {
    use crate::chamvs::dispatcher::Dispatcher;
    use crate::config::CHUNK_LEN;
    use crate::coordinator::retriever::Retriever;
    use crate::data::corpus::Corpus;
    use crate::retcache::{
        repeat_fraction, zipf_stream, CacheConfig, ServeModel, SpecConfig,
    };

    let ds = crate::config::dataset_by_name("SIFT").unwrap();
    let (data, index, nodes) = crate::report::search::build_stack(ds, n_scaled, 1, 100, seed);
    let dispatcher = Dispatcher::new(nodes, 100);
    let corpus = Corpus::generate(data.n, 2048, CHUNK_LEN, seed ^ 2);
    let mut retriever = Retriever::new(ds, index, dispatcher, corpus);
    let sm = ServeModel::new(&DEC_S);

    let mut out = String::new();
    out.push_str("Retcache — cached + speculative RALM serving (Dec-S over SIFT; modeled)\n");
    out.push_str(
        "capacity_B  zipf_a  repeat%  hit%   sync_tok/s  cached_tok/s  speedup\n",
    );
    for &cap in &[64usize << 10, 1 << 20] {
        for &alpha in &[0.6f64, 1.1] {
            let stream = zipf_stream(64, alpha, 256, seed ^ 9);
            let repeat = repeat_fraction(&stream);
            let queries: Vec<Vec<f32>> = stream
                .iter()
                .map(|&i| data.query(i % data.n_queries).to_vec())
                .collect();
            retriever.enable_cache(CacheConfig {
                capacity_bytes: cap,
                ..CacheConfig::default()
            });
            retriever.enable_speculation(SpecConfig::default());
            retriever.reset_retcache_stats();
            let r = sm
                .run(&mut retriever, &queries)
                .expect("retcache serve model");
            out.push_str(&format!(
                "{:<11} {:<7} {:>6.1}  {:>5.1}  {:>10.1} {:>13.1} {:>7.2}x\n",
                cap,
                alpha,
                repeat * 100.0,
                r.hit_rate() * 100.0,
                r.sync_tokens_per_s(),
                r.modeled_tokens_per_s(),
                r.speedup(),
            ));
        }
    }
    out.push('\n');
    // Counter block of the last cell (cache hit/miss + speculation
    // accuracy + saved latency).
    out.push_str(&retriever.cache_report());
    out
}

/// Parallel-dispatch report: measured host wall-clock of thread-pooled
/// ChamVS rounds across worker-thread counts on a 4-node index, next to
/// the per-query `measured_wall_s` (max across pool workers of their
/// nodes' scan sums — the honest parallel number at that width) and
/// `measured_cpu_s` (sum across nodes — total host work). Single-query
/// broadcast and batched per-node work queues.
pub fn dispatch_report(n_scaled: usize, n_queries: usize, seed: u64) -> String {
    use std::time::Instant;

    use crate::chamvs::dispatcher::{BatchQuery, Dispatcher};
    use crate::util::stats::Summary;

    let ds = crate::config::dataset_by_name("SIFT").unwrap();
    let (data, index, nodes) =
        crate::report::search::build_stack(ds, n_scaled, 4, 100, seed);
    let mut disp = Dispatcher::new(nodes, 100);
    let n_queries = n_queries.clamp(8, 64);
    let queries: Vec<Vec<f32>> = (0..n_queries)
        .map(|i| data.query(i % data.n_queries).to_vec())
        .collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    let batch: Vec<BatchQuery> = queries
        .iter()
        .zip(&lists)
        .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
        .collect();

    let mut out = String::new();
    out.push_str("Parallel dispatch — 4 memory nodes, SIFT (ms)\n");
    out.push_str(
        "threads mode     round_wall p50_node_wall p50_node_cpu\n",
    );
    for &threads in &[1usize, 2, 4] {
        disp.n_threads = threads;
        // Single-query broadcasts: one round per query.
        let t0 = Instant::now();
        let mut node_wall = Vec::new();
        let mut node_cpu = Vec::new();
        for (q, l) in queries.iter().zip(&lists) {
            let r = disp
                .search(q, &index.pq.centroids, l, ds.nprobe)
                .expect("dispatch");
            node_wall.push(r.measured_wall_s);
            node_cpu.push(r.measured_cpu_s);
        }
        let round_wall = t0.elapsed().as_secs_f64() / n_queries as f64;
        out.push_str(&format!(
            "{:<7} {:<8} {:>10.4} {:>13.4} {:>12.4}\n",
            threads,
            "single",
            round_wall * 1e3,
            Summary::of(&node_wall).p50 * 1e3,
            Summary::of(&node_cpu).p50 * 1e3,
        ));
        // One batched round: every query through per-node work queues.
        let t0 = Instant::now();
        let rs = disp
            .search_batch(&batch, &index.pq.centroids, ds.nprobe)
            .expect("batched dispatch");
        let round_wall = t0.elapsed().as_secs_f64() / rs.len() as f64;
        let node_wall: Vec<f64> = rs.iter().map(|r| r.measured_wall_s).collect();
        let node_cpu: Vec<f64> = rs.iter().map(|r| r.measured_cpu_s).collect();
        out.push_str(&format!(
            "{:<7} {:<8} {:>10.4} {:>13.4} {:>12.4}\n",
            threads,
            "batch",
            round_wall * 1e3,
            Summary::of(&node_wall).p50 * 1e3,
            Summary::of(&node_cpu).p50 * 1e3,
        ));
    }
    out.push_str(
        "(round_wall = measured per-query wall of the round; node_wall = max across\n\
         pool workers of their nodes' scan sums — the honest parallel number at the\n\
         configured width; node_cpu = sum across nodes)\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_report_renders_thread_sweep() {
        let s = dispatch_report(2000, 8, 5);
        assert!(s.contains("threads"), "{s}");
        assert!(s.contains("batch"), "{s}");
        assert!(s.contains("node_cpu"), "{s}");
    }

    #[test]
    fn retcache_report_shows_speedup_and_counters() {
        let s = retcache_report(2000, 3);
        assert!(s.contains("speedup"));
        assert!(s.contains("cache-hit"));
        assert!(s.contains("speculation issued"));
        // At least one skewed cell must clear the 1.3x acceptance bar.
        let best = s
            .lines()
            .filter_map(|l| {
                l.split_whitespace()
                    .last()
                    .and_then(|x| x.strip_suffix('x'))
                    .and_then(|x| x.parse::<f64>().ok())
            })
            .fold(0.0f64, f64::max);
        assert!(best >= 1.3, "best modeled speedup {best}\n{s}");
    }

    #[test]
    fn fig11_chameleon_faster_at_retrieval_steps() {
        // Paper Fig 11 retrieval-step speedups top out at 4.11x for the
        // smallest model; our single-core CPU retrieval baseline makes
        // the b=1 gap somewhat larger (see EXPERIMENTS.md). Assert the
        // shape: every model gains, and gains shrink as models grow.
        let (gpu, cpu, fpga) =
            (GpuModel::default(), CpuModel::default(), FpgaModel::default());
        let speedup = |model: &ModelConfig| {
            step_latency(model, 1, true, false, &gpu, &cpu, &fpga)
                / step_latency(model, 1, true, true, &gpu, &cpu, &fpga)
        };
        for model in [&DEC_S, &DEC_L, &ENCDEC_S, &ENCDEC_L] {
            let s = speedup(model);
            assert!(s > 1.1 && s < 25.0, "{}: speedup {s}", model.name);
        }
        assert!(speedup(&DEC_S) > speedup(&DEC_L), "small models gain more");
        assert!(speedup(&ENCDEC_S) > speedup(&ENCDEC_L));
    }

    #[test]
    fn fig12_interval1_speedup_band() {
        // Dec-S at interval 1, b=64: paper reports 3.18x; model must land
        // within a sensible band around it.
        let (gpu, cpu, fpga) =
            (GpuModel::default(), CpuModel::default(), FpgaModel::default());
        let mut m = DEC_S.clone();
        m.interval = 1;
        let plain_b = step_latency(&m, 64, false, false, &gpu, &cpu, &fpga);
        let retr_b = step_latency(&m, 64, true, false, &gpu, &cpu, &fpga);
        let plain_c = step_latency(&m, 64, false, true, &gpu, &cpu, &fpga);
        let retr_c = step_latency(&m, 64, true, true, &gpu, &cpu, &fpga);
        let speedup = (plain_b + retr_b) / (plain_c + retr_c);
        assert!(speedup > 1.5, "{speedup}");
    }

    #[test]
    fn no_retrieval_steps_identical_between_systems() {
        let (gpu, cpu, fpga) =
            (GpuModel::default(), CpuModel::default(), FpgaModel::default());
        let a = step_latency(&DEC_S, 1, false, false, &gpu, &cpu, &fpga);
        let b = step_latency(&DEC_S, 1, false, true, &gpu, &cpu, &fpga);
        assert_eq!(a, b);
    }

    #[test]
    fn reports_render() {
        assert!(fig11_latency(64).contains("Chameleon"));
        assert!(fig12_throughput(64).contains("speedup"));
        assert!(fig13_ratio().contains("disaggregation"));
    }
}
