//! Fig 9 (search latency distributions), Fig 10 (scalability) and the
//! recall setup check of Sec 6.1.

use crate::chamvs::backend::{BackendKind, SearchBackend};
use crate::chamvs::dispatcher::Dispatcher;
use crate::chamvs::node::{MemoryNode, ScanEngine};
use crate::config::{DatasetConfig, DATASETS};
use crate::data::recall::{ground_truth, mean_recall};
use crate::data::synthetic::SyntheticDataset;
use crate::hwmodel::loggp::LogGp;
use crate::ivf::index::IvfPqIndex;
use crate::ivf::shard::Shard;
use crate::util::rng::Rng;
use crate::util::stats::{Histogram, Summary};

/// Build a scaled dataset + index + single-node dispatcher for a dataset.
pub fn build_stack(
    ds: &'static DatasetConfig,
    n: usize,
    n_nodes: usize,
    k: usize,
    seed: u64,
) -> (SyntheticDataset, IvfPqIndex, Vec<MemoryNode>) {
    let data = SyntheticDataset::generate_sized(ds, n, 256, seed);
    // Fine-grained lists (nlist >> nprobe, like the paper's 32768 vs 32):
    // per-query scan size then varies with the probed lists' sizes, which
    // is exactly what spreads the Fig 9 violins.
    let nlist = (n / 16).min(ds.nlist_scaled).max(16);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let nodes = (0..n_nodes)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, k))
        .collect();
    (data, index, nodes)
}

/// Fig 9: per-backend latency distributions over the query set.
/// Distributions arise from per-query scan-size variation (IVF list sizes
/// differ), exactly the paper's source of violin spread.
pub fn fig9_search_latency(n_scaled: usize, n_queries: usize, seed: u64) -> String {
    let mut out = String::new();
    out.push_str("Fig 9 — vector search latency (paper-scale model; ms)\n");
    out.push_str(
        "dataset    batch backend    p50       p99       dist (modeled)\n",
    );
    for ds in DATASETS {
        let (data, index, nodes) = build_stack(ds, n_scaled, 1, 100, seed);
        let dispatcher = Dispatcher::new(nodes, 100);
        let mut backend = SearchBackend::new(BackendKind::Cpu, ds, dispatcher, true);
        // Collect per-query scan counts once (same across backends).
        let mut scan_counts = Vec::with_capacity(n_queries);
        let mut rng = Rng::new(seed ^ 7);
        for _ in 0..n_queries {
            let qi = rng.below(data.n_queries);
            let lists = index.probe(data.query(qi), ds.nprobe);
            scan_counts.push(index.scan_count(&lists));
        }
        // Scale each query's scanned-count to paper scale: normalize by
        // the *expected* probe mass at scaled size (nprobe/nlist differs
        // between the scaled and paper indexes), keeping the per-query
        // relative variation that produces the violin spread.
        let expected =
            data.n as f64 * ds.nprobe as f64 / index.nlist as f64;
        let paper_mean = ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64;
        for kind in BackendKind::ALL {
            backend.kind = kind;
            for &b in &[1usize, 4, 16] {
                let lats: Vec<f64> = scan_counts
                    .iter()
                    .map(|&c| {
                        let rel = c as f64 / expected;
                        let paper_scanned = (rel * paper_mean) as usize;
                        backend.batch_latency_model(b, paper_scanned) / b as f64
                    })
                    .collect();
                let s = Summary::of(&lats);
                let h = Histogram::of(&lats, 24);
                out.push_str(&format!(
                    "{:<10} {:<5} {:<10} {:>8.3} {:>8.3}  {}\n",
                    ds.name,
                    b,
                    kind.name(),
                    s.p50 * 1e3,
                    s.p99 * 1e3,
                    h.sparkline(),
                ));
            }
        }
        out.push('\n');
    }
    out
}

/// Fig 10: median/p99 latency scaling out memory nodes (LogGP sampling,
/// the paper's own extrapolation method on SYN-512).
pub fn fig10_scalability(n_scaled: usize, n_queries: usize, seed: u64) -> String {
    let ds = crate::config::dataset_by_name("SYN-512").unwrap();
    let (data, index, nodes) = build_stack(ds, n_scaled, 1, 100, seed);
    let fpga = nodes[0].fpga;
    let net = LogGp::default();
    // Per-query 1-node accelerator latency samples at paper scale
    // (probe-mass-normalized, as in fig9).
    let expected = data.n as f64 * ds.nprobe as f64 / index.nlist as f64;
    let paper_mean = ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64;
    let mut rng = Rng::new(seed ^ 3);
    let base: Vec<f64> = (0..n_queries)
        .map(|_| {
            let qi = rng.below(data.n_queries);
            let lists = index.probe(data.query(qi), ds.nprobe);
            let rel = index.scan_count(&lists) as f64 / expected;
            let paper_scanned = rel * paper_mean;
            fpga.query_latency(paper_scanned as usize, ds.m, ds.nprobe, 100).total()
        })
        .collect();

    let mut out = String::new();
    out.push_str("Fig 10 — scaling memory nodes, SYN-512 (ms)\n");
    out.push_str("nodes  batch  p50       p99\n");
    for &n_nodes in &[1usize, 2, 4, 8, 16] {
        for &b in &[1usize, 16, 64] {
            // A query on N nodes completes when the slowest node finishes
            // 1/N of the work: max of N samples scaled by 1/N (the paper's
            // sampling method), plus the LogGP round trip.
            let mut samples = Vec::with_capacity(n_queries);
            let mut r2 = Rng::new(seed ^ (n_nodes as u64) << 8 ^ b as u64);
            for _ in 0..n_queries {
                let mut worst: f64 = 0.0;
                for _ in 0..n_nodes {
                    worst = worst.max(base[r2.below(base.len())]);
                }
                let accel = worst / n_nodes as f64 * b as f64;
                let netw = net.query_roundtrip(n_nodes, 4 * ds.d + 4 * ds.nprobe, 1200);
                samples.push(accel + netw);
            }
            let s = Summary::of(&samples);
            out.push_str(&format!(
                "{n_nodes:<6} {b:<6} {:>8.3} {:>8.3}\n",
                s.p50 * 1e3 / b as f64,
                s.p99 * 1e3 / b as f64,
            ));
        }
    }
    out.push_str("(paper: +7.9% median at b=64, +54.5% at b=1 going 1->many nodes)\n");
    out
}

/// Sec 6.1 recall check: R@K of the scaled IVF-PQ setup.
pub fn recall_report(n_scaled: usize, n_queries: usize, seed: u64) -> String {
    let ds = crate::config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, n_scaled, n_queries, seed);
    let nlist = (n_scaled as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let mut out = String::new();
    out.push_str("Recall — scaled SIFT-like dataset (Sec 6.1 setup)\n");
    out.push_str(&format!("n={n_scaled} nlist={nlist} m={}\n", ds.m));
    out.push_str("nprobe  R@1     R@10    R@100\n");
    let gt100 = ground_truth(&data.data, data.n, data.d, &data.queries, n_queries, 100);
    for &nprobe in &[1usize, 4, 16, 32, 64] {
        let mut results = Vec::new();
        for q in 0..n_queries {
            let (ids, _) = index.search(data.query(q), nprobe, 100);
            results.push(ids);
        }
        let r1 = mean_recall(
            &results.iter().map(|r| r[..1].to_vec()).collect::<Vec<_>>(),
            &gt100.iter().map(|g| g[..1].to_vec()).collect::<Vec<_>>(),
        );
        let r10 = mean_recall(
            &results.iter().map(|r| r[..10.min(r.len())].to_vec()).collect::<Vec<_>>(),
            &gt100.iter().map(|g| g[..10].to_vec()).collect::<Vec<_>>(),
        );
        let r100 = mean_recall(&results, &gt100);
        out.push_str(&format!(
            "{nprobe:<7} {r1:<7.3} {r10:<7.3} {r100:<7.3}\n"
        ));
    }
    out.push_str("(paper: R@100 = 93-94% at nprobe=32 on billion-scale sets)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_report_shapes() {
        let s = fig9_search_latency(2000, 16, 1);
        // 4 datasets x 4 backends x 3 batches rows.
        let data_rows = s
            .lines()
            .filter(|l| BackendKind::ALL.iter().any(|k| l.contains(k.name())))
            .count();
        assert!(data_rows >= 48, "{data_rows} rows");
    }

    #[test]
    fn fig10_tail_grows_with_nodes_at_b1() {
        let s = fig10_scalability(2000, 32, 2);
        assert!(s.contains("nodes"));
        assert!(s.lines().count() > 10);
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let s = recall_report(2000, 8, 3);
        let rows: Vec<f64> = s
            .lines()
            .filter(|l| l.chars().next().map(char::is_numeric).unwrap_or(false))
            .filter_map(|l| {
                l.split_whitespace().nth(3).and_then(|x| x.parse().ok())
            })
            .collect();
        assert!(rows.len() >= 4);
        assert!(rows.last().unwrap() >= rows.first().unwrap());
    }
}
