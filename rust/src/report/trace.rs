//! `chameleon report trace`: aggregate a span dump into per-stage
//! percentiles, critical-path attribution and hedge/cache/speculation
//! win rates — the offline half of the end-to-end query tracing pipeline
//! (the online half is `chameleon loadgen --trace-out` or any server
//! spawned with
//! [`crate::coordinator::server::CoordinatorServer::spawn_traced`]).

use anyhow::{Context, Result};

use crate::chamvs::dispatcher::Dispatcher;
use crate::chamvs::node::{MemoryNode, ScanEngine};
use crate::config;
use crate::coordinator::retriever::Retriever;
use crate::data::corpus::Corpus;
use crate::data::synthetic::SyntheticDataset;
use crate::hwmodel::capacity::{CapacityPlanner, StageTimes};
use crate::ivf::index::IvfPqIndex;
use crate::ivf::shard::Shard;
use crate::retcache::{CacheConfig, KeyPolicy, SpecConfig};
use crate::telemetry::burn_rate;
use crate::trace::{analyze, events_from_json, SpanEvent, SpanKind, Tracer};
use crate::util::json::{obj, Json};

/// Aggregate a trace dump file (or, with no path, a small in-process
/// traced run) and render the report plus a fitted capacity plan. With
/// `slo = Some((latency_ms, target))` the report appends the SLO burn
/// implied by the `Total` spans in the dump.
pub fn trace_report(
    path: Option<&str>,
    n: usize,
    queries: usize,
    seed: u64,
    slo: Option<(f64, f64)>,
) -> Result<String> {
    let (events, observed_nodes) = load_events(path, n, queries, seed)?;
    let a = analyze(&events);
    let mut out = a.render();
    // Fan-out for the planner fit: from the per-node span tags when the
    // dump carries scans, else the demo's node count.
    let nodes = observed_nodes.unwrap_or_else(|| a.per_node.len().max(1));
    if a.totals.is_some() && a.stage_mean_s(SpanKind::NodeScan) > 0.0 {
        let st = StageTimes::from_analysis(&a, nodes);
        let planner = CapacityPlanner::new(st, 4 * 128, 12 * 10);
        out.push_str(&planner.render(planner.saturation_qps(nodes) * 0.5, 0.05));
    }
    if let Some((slo_ms, target)) = slo {
        let s = slo_from_totals(&events, slo_ms, target);
        out.push_str(&format!(
            "slo: {:.1} ms @ {:.4} — {}/{} breaches, burn {:.2}\n",
            slo_ms,
            target,
            s.breaches,
            s.total,
            if s.burn.is_finite() { s.burn } else { 1e9 },
        ));
    }
    Ok(out)
}

/// Machine-readable variant of [`trace_report`]: the trace analysis JSON
/// plus the fitted stage times under `stage_fit` (same inner keys as the
/// `stages` object in `BENCH_serve.json`: `lut_s`, `scan_s`, `merge_s`,
/// `reply_s`, `cache_probe_s`, `spec_verify_s`) and, given an SLO, the
/// burn implied by the `Total` spans.
pub fn trace_report_json(
    path: Option<&str>,
    n: usize,
    queries: usize,
    seed: u64,
    slo: Option<(f64, f64)>,
) -> Result<String> {
    let (events, observed_nodes) = load_events(path, n, queries, seed)?;
    let a = analyze(&events);
    let nodes = observed_nodes.unwrap_or_else(|| a.per_node.len().max(1));
    let Json::Obj(mut doc) = a.to_json() else {
        anyhow::bail!("trace analysis did not serialize to an object");
    };
    let st = StageTimes::from_analysis(&a, nodes);
    doc.insert(
        "stage_fit".to_string(),
        obj(vec![
            ("lut_s", Json::Num(st.lut_s)),
            ("scan_s", Json::Num(st.scan_s)),
            ("merge_s", Json::Num(st.merge_s)),
            ("reply_s", Json::Num(st.reply_s)),
            ("cache_probe_s", Json::Num(st.cache_probe_s)),
            ("spec_verify_s", Json::Num(st.spec_verify_s)),
        ]),
    );
    doc.insert("nodes".to_string(), Json::Num(nodes as f64));
    if let Some((slo_ms, target)) = slo {
        let s = slo_from_totals(&events, slo_ms, target);
        doc.insert(
            "slo".to_string(),
            obj(vec![
                ("slo_ms", Json::Num(slo_ms)),
                ("target", Json::Num(target)),
                ("total_spans", Json::Num(s.total as f64)),
                ("breaches", Json::Num(s.breaches as f64)),
                (
                    "burn",
                    Json::Num(if s.burn.is_finite() { s.burn } else { 1e9 }),
                ),
            ]),
        );
    }
    Ok(Json::Obj(doc).dump())
}

fn load_events(
    path: Option<&str>,
    n: usize,
    queries: usize,
    seed: u64,
) -> Result<(Vec<SpanEvent>, Option<usize>)> {
    match path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading trace dump '{p}'"))?;
            let j = Json::parse(&text).with_context(|| format!("parsing '{p}'"))?;
            Ok((events_from_json(&j)?, None))
        }
        None => Ok((demo_events(n, queries, seed)?, Some(2))),
    }
}

struct SloFromTotals {
    total: u64,
    breaches: u64,
    burn: f64,
}

/// Offline SLO accounting over the dump's end-to-end `Total` spans — the
/// same `burn_rate` formula the live telemetry plane uses, applied to a
/// recorded trace instead of the sliding windows.
fn slo_from_totals(events: &[SpanEvent], slo_ms: f64, target: f64) -> SloFromTotals {
    let slo_s = slo_ms * 1e-3;
    let mut total = 0u64;
    let mut breaches = 0u64;
    for e in events.iter().filter(|e| e.kind == SpanKind::Total) {
        total += 1;
        if e.dur_s > slo_s {
            breaches += 1;
        }
    }
    SloFromTotals {
        total,
        breaches,
        burn: burn_rate(breaches, total, 1.0 - target),
    }
}

/// Produce a span stream by running a traced closed loop over an
/// in-process two-node retrieval stack with the retcache enabled — every
/// core span kind except the server-owned queue wait shows up.
fn demo_events(
    n: usize,
    queries: usize,
    seed: u64,
) -> Result<Vec<crate::trace::SpanEvent>> {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, n, queries.max(1), seed);
    let nlist = (n as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let dispatcher = Dispatcher::new(nodes, 10);
    let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, seed ^ 2);
    let mut retriever = Retriever::new(ds, index, dispatcher, corpus);
    retriever.enable_cache(CacheConfig { key: KeyPolicy::Exact, ..CacheConfig::default() });
    retriever.enable_speculation(SpecConfig::default());
    let tracer = Tracer::new(16 * 1024);
    retriever.set_tracer(tracer.clone());
    for i in 0..queries.max(1) {
        let trace_id = (i + 1) as u64;
        let t0 = std::time::Instant::now();
        // Repeat every query once so cache hits and speculation verifies
        // both fire.
        let q = data.query((i / 2) % data.n_queries);
        retriever.retrieve_cached_from_traced(0, q, trace_id)?;
        tracer.record(trace_id, SpanKind::QueueWait, 0, 0.0);
        tracer.record(trace_id, SpanKind::Total, 0, t0.elapsed().as_secs_f64());
    }
    retriever.cancel_speculation();
    Ok(tracer.snapshot())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_report_carries_core_stages_and_plan() {
        let text = trace_report(None, 4000, 8, 42, None).unwrap();
        for stage in ["lut_build", "node_scan", "merge", "cache_probe", "total"] {
            assert!(text.contains(stage), "missing {stage} in:\n{text}");
        }
        assert!(text.contains("planner:"), "{text}");
    }

    #[test]
    fn dump_roundtrip_report() {
        use crate::trace::events_to_json;
        let evs = demo_events(4000, 6, 7).unwrap();
        let dir = std::env::temp_dir().join("chameleon_trace_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        std::fs::write(&path, events_to_json(&evs).dump()).unwrap();
        let text =
            trace_report(Some(path.to_str().unwrap()), 0, 0, 0, Some((0.0, 0.99))).unwrap();
        assert!(text.contains("node_scan"), "{text}");
        assert!(text.contains("burn"), "{text}");
        assert!(trace_report(Some("/nonexistent/trace.json"), 0, 0, 0, None).is_err());
        let j = trace_report_json(Some(path.to_str().unwrap()), 0, 0, 0, Some((0.0, 0.99)))
            .unwrap();
        let doc = Json::parse(&j).unwrap();
        assert!(doc.get("stage_fit").is_some(), "{j}");
        let slo = doc.get("slo").unwrap();
        // A 0 ms SLO makes every Total span a breach.
        assert_eq!(
            slo.get("breaches").unwrap().as_f64(),
            slo.get("total_spans").unwrap().as_f64(),
        );
    }
}
