//! `chameleon report trace`: aggregate a span dump into per-stage
//! percentiles, critical-path attribution and hedge/cache/speculation
//! win rates — the offline half of the end-to-end query tracing pipeline
//! (the online half is `chameleon loadgen --trace-out` or any server
//! spawned with
//! [`crate::coordinator::server::CoordinatorServer::spawn_traced`]).

use anyhow::{Context, Result};

use crate::chamvs::dispatcher::Dispatcher;
use crate::chamvs::node::{MemoryNode, ScanEngine};
use crate::config;
use crate::coordinator::retriever::Retriever;
use crate::data::corpus::Corpus;
use crate::data::synthetic::SyntheticDataset;
use crate::hwmodel::capacity::{CapacityPlanner, StageTimes};
use crate::ivf::index::IvfPqIndex;
use crate::ivf::shard::Shard;
use crate::retcache::{CacheConfig, KeyPolicy, SpecConfig};
use crate::trace::{analyze, events_from_json, SpanKind, Tracer};
use crate::util::json::Json;

/// Aggregate a trace dump file (or, with no path, a small in-process
/// traced run) and render the report plus a fitted capacity plan.
pub fn trace_report(
    path: Option<&str>,
    n: usize,
    queries: usize,
    seed: u64,
) -> Result<String> {
    let (events, observed_nodes) = match path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading trace dump '{p}'"))?;
            let j = Json::parse(&text).with_context(|| format!("parsing '{p}'"))?;
            (events_from_json(&j)?, None)
        }
        None => (demo_events(n, queries, seed)?, Some(2)),
    };
    let a = analyze(&events);
    let mut out = a.render();
    // Fan-out for the planner fit: from the per-node span tags when the
    // dump carries scans, else the demo's node count.
    let nodes = observed_nodes.unwrap_or_else(|| a.per_node.len().max(1));
    if a.totals.is_some() && a.stage_mean_s(SpanKind::NodeScan) > 0.0 {
        let st = StageTimes::from_analysis(&a, nodes);
        let planner = CapacityPlanner::new(st, 4 * 128, 12 * 10);
        out.push_str(&planner.render(planner.saturation_qps(nodes) * 0.5, 0.05));
    }
    Ok(out)
}

/// Produce a span stream by running a traced closed loop over an
/// in-process two-node retrieval stack with the retcache enabled — every
/// core span kind except the server-owned queue wait shows up.
fn demo_events(
    n: usize,
    queries: usize,
    seed: u64,
) -> Result<Vec<crate::trace::SpanEvent>> {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, n, queries.max(1), seed);
    let nlist = (n as f64).sqrt() as usize;
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let dispatcher = Dispatcher::new(nodes, 10);
    let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, seed ^ 2);
    let mut retriever = Retriever::new(ds, index, dispatcher, corpus);
    retriever.enable_cache(CacheConfig { key: KeyPolicy::Exact, ..CacheConfig::default() });
    retriever.enable_speculation(SpecConfig::default());
    let tracer = Tracer::new(16 * 1024);
    retriever.set_tracer(tracer.clone());
    for i in 0..queries.max(1) {
        let trace_id = (i + 1) as u64;
        let t0 = std::time::Instant::now();
        // Repeat every query once so cache hits and speculation verifies
        // both fire.
        let q = data.query((i / 2) % data.n_queries);
        retriever.retrieve_cached_from_traced(0, q, trace_id)?;
        tracer.record(trace_id, SpanKind::QueueWait, 0, 0.0);
        tracer.record(trace_id, SpanKind::Total, 0, t0.elapsed().as_secs_f64());
    }
    retriever.cancel_speculation();
    Ok(tracer.snapshot())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_report_carries_core_stages_and_plan() {
        let text = trace_report(None, 4000, 8, 42).unwrap();
        for stage in ["lut_build", "node_scan", "merge", "cache_probe", "total"] {
            assert!(text.contains(stage), "missing {stage} in:\n{text}");
        }
        assert!(text.contains("planner:"), "{text}");
    }

    #[test]
    fn dump_roundtrip_report() {
        use crate::trace::events_to_json;
        let evs = demo_events(4000, 6, 7).unwrap();
        let dir = std::env::temp_dir().join("chameleon_trace_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        std::fs::write(&path, events_to_json(&evs).dump()).unwrap();
        let text = trace_report(Some(path.to_str().unwrap()), 0, 0, 0).unwrap();
        assert!(text.contains("node_scan"), "{text}");
        assert!(trace_report(Some("/nonexistent/trace.json"), 0, 0, 0).is_err());
    }
}
