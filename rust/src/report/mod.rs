//! Report generators: one function per paper table/figure, printing the
//! same rows/series the paper reports (DESIGN.md Sec 5 experiment index).
//! Each is callable from `chameleon report <id>` and from the benches.

pub mod search;
pub mod system;
pub mod tables;
pub mod trace;

pub use search::{fig10_scalability, fig9_search_latency, recall_report};
pub use system::{
    dispatch_report, fig11_latency, fig12_throughput, fig13_ratio, retcache_report,
};
pub use tables::{fig7_probability, fig8_resources, table4_resources, table5_energy};
pub use trace::{trace_report, trace_report_json};

/// Render a markdown-ish table row.
pub fn row(cells: &[String]) -> String {
    format!("| {} |", cells.join(" | "))
}
