//! CPU affinity + NUMA placement for scan workers — raw `sched_setaffinity`
//! / `sched_getcpu` FFI on 64-bit Linux (no libc crate, matching the
//! `util::poll` idiom), portable no-op fallback elsewhere.
//!
//! The point (ROADMAP item 2, paper Sec 2.3): an ADC scan is memory-bound
//! on its shard's flat arena, so a worker bouncing between sockets pays
//! remote-DRAM latency on every code line. `worker_cpus` plans one CPU per
//! worker, round-robining across NUMA nodes (parsed from
//! `/sys/devices/system/node/node*/cpulist`) so co-resident workers spread
//! over sockets instead of piling onto one; `cluster::engine` and
//! `chamvs::Dispatcher` pin their scan threads to the plan when pinning is
//! enabled (`--pin-workers` / `CHAM_PIN=1`), and the engine surfaces the
//! observed per-node CPU in `ClusterStats`.

#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
mod sys {
    /// 16 x 64 bits = 1024 CPUs, the kernel's default CONFIG_NR_CPUS cap.
    pub const MASK_WORDS: usize = 16;

    extern "C" {
        /// pid 0 = the calling thread.
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        pub fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
        pub fn sched_getcpu() -> i32;
    }
}

/// Whether pinning is real on this platform.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn supported() -> bool {
    true
}

/// The CPU the calling thread is executing on right now.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn current_cpu() -> Option<usize> {
    let cpu = unsafe { sys::sched_getcpu() };
    (cpu >= 0).then_some(cpu as usize)
}

/// CPUs the calling thread is currently allowed to run on, ascending.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn allowed_cpus() -> Vec<usize> {
    let mut mask = [0u64; sys::MASK_WORDS];
    let rc = unsafe {
        sys::sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr())
    };
    if rc != 0 {
        return Vec::new();
    }
    let mut cpus = Vec::new();
    for (w, &word) in mask.iter().enumerate() {
        for b in 0..64 {
            if word >> b & 1 == 1 {
                cpus.push(w * 64 + b);
            }
        }
    }
    cpus
}

/// Pin the calling thread to a set of CPUs. Returns whether the kernel
/// accepted the mask (false on empty input, out-of-range CPUs, or a
/// sandbox that denies sched_setaffinity — callers treat that as "not
/// pinned" and carry on).
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn pin_to_cpus(cpus: &[usize]) -> bool {
    let mut mask = [0u64; sys::MASK_WORDS];
    let mut any = false;
    for &c in cpus {
        if c < sys::MASK_WORDS * 64 {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    let rc = unsafe {
        sys::sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr())
    };
    rc == 0
}

#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn supported() -> bool {
    false
}

#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn current_cpu() -> Option<usize> {
    None
}

#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn allowed_cpus() -> Vec<usize> {
    Vec::new()
}

#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn pin_to_cpus(_cpus: &[usize]) -> bool {
    false
}

/// Pin the calling thread to one CPU.
pub fn pin_to_cpu(cpu: usize) -> bool {
    pin_to_cpus(&[cpu])
}

/// NUMA topology visible to this process: one CPU list per node,
/// intersected with the allowed mask, empty nodes dropped. Falls back to
/// a single pseudo-node holding every allowed CPU when sysfs is absent
/// (non-NUMA kernels, containers masking /sys).
pub fn numa_nodes() -> Vec<Vec<usize>> {
    let allowed = allowed_cpus();
    if allowed.is_empty() {
        return Vec::new();
    }
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir("/sys/devices/system/node") {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy().into_owned();
            let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok())
            else {
                continue;
            };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            let cpus: Vec<usize> = parse_cpulist(list.trim())
                .into_iter()
                .filter(|c| allowed.binary_search(c).is_ok())
                .collect();
            if !cpus.is_empty() {
                nodes.push((idx, cpus));
            }
        }
    }
    if nodes.is_empty() {
        return vec![allowed];
    }
    nodes.sort_by_key(|(idx, _)| *idx);
    nodes.into_iter().map(|(_, cpus)| cpus).collect()
}

/// Parse a sysfs cpulist like `0-15,32-47` into ascending CPU numbers.
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.parse::<usize>(), hi.parse::<usize>()) {
                cpus.extend(lo..=hi);
            }
        } else if let Ok(c) = part.parse::<usize>() {
            cpus.push(c);
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// CPU assignment plan for `n` workers: round-robin across NUMA nodes
/// first (worker 0 → node 0's first CPU, worker 1 → node 1's first CPU,
/// ...), so a worker pool spreads its memory-bound scans over sockets;
/// wraps when `n` exceeds the CPU count. Empty when affinity is
/// unsupported — callers skip pinning entirely.
pub fn worker_cpus(n: usize) -> Vec<usize> {
    let order = interleaved();
    if order.is_empty() {
        return Vec::new();
    }
    (0..n).map(|i| order[i % order.len()]).collect()
}

/// The CPU the `i`-th worker of a pool should pin to (same plan as
/// `worker_cpus`, usable incrementally as workers join).
pub fn worker_cpu(i: usize) -> Option<usize> {
    let order = interleaved();
    if order.is_empty() {
        None
    } else {
        Some(order[i % order.len()])
    }
}

/// All allowed CPUs, interleaved round-robin across NUMA nodes.
fn interleaved() -> Vec<usize> {
    let nodes = numa_nodes();
    let mut order = Vec::new();
    let mut depth = 0;
    loop {
        let mut any = false;
        for node in &nodes {
            if let Some(&c) = node.get(depth) {
                order.push(c);
                any = true;
            }
        }
        if !any {
            break;
        }
        depth += 1;
    }
    order
}

/// Whether pinning was requested via environment (`CHAM_PIN=1`); the
/// CLI's `--pin-workers` flag sets this so every layer below sees it.
pub fn env_pin_requested() -> bool {
    std::env::var_os("CHAM_PIN").is_some_and(|v| !v.is_empty() && v != "0")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-2,8,16-17"), vec![0, 1, 2, 8, 16, 17]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("junk,3"), vec![3]);
    }

    #[cfg(all(target_os = "linux", target_pointer_width = "64"))]
    #[test]
    fn pin_query_round_trip() {
        let before = allowed_cpus();
        assert!(!before.is_empty(), "a running thread must be allowed somewhere");
        let here = current_cpu().expect("sched_getcpu works on linux");
        assert!(before.contains(&here), "current cpu {here} not in {before:?}");

        // Some sandboxes deny sched_setaffinity; re-applying the current
        // mask probes that without changing anything.
        if !pin_to_cpus(&before) {
            eprintln!("sched_setaffinity denied here; skipping pin round-trip");
            return;
        }
        let target = before[0];
        assert!(pin_to_cpu(target));
        assert_eq!(allowed_cpus(), vec![target]);
        assert_eq!(current_cpu(), Some(target));
        // Restore so the test thread doesn't skew parallel tests.
        assert!(pin_to_cpus(&before));
        assert_eq!(allowed_cpus(), before);
    }

    #[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
    #[test]
    fn unsupported_platform_is_a_graceful_noop() {
        assert!(!supported());
        assert_eq!(current_cpu(), None);
        assert!(allowed_cpus().is_empty());
        assert!(!pin_to_cpu(0));
        assert!(numa_nodes().is_empty());
        assert!(worker_cpus(4).is_empty());
        assert_eq!(worker_cpu(0), None);
    }

    #[test]
    fn numa_plan_covers_allowed_cpus() {
        let allowed = allowed_cpus();
        let nodes = numa_nodes();
        if allowed.is_empty() {
            assert!(nodes.is_empty());
            return;
        }
        let mut union: Vec<usize> = nodes.iter().flatten().copied().collect();
        union.sort_unstable();
        assert_eq!(union, allowed, "numa nodes must partition the allowed set");

        let plan = worker_cpus(allowed.len() + 3);
        assert_eq!(plan.len(), allowed.len() + 3);
        assert!(plan.iter().all(|c| allowed.contains(c)));
        for (i, &c) in plan.iter().enumerate() {
            assert_eq!(worker_cpu(i), Some(c), "incremental plan agrees");
        }
    }
}
