//! Minimal property-testing harness (offline substrate for proptest).
//!
//! `check` runs a property over many generated cases; on failure it
//! re-raises with the failing seed so the case can be replayed
//! deterministically (`PROP_SEED=<n> cargo test ...`).

use super::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Run `prop` over `cases` generated inputs. The generator receives a
/// per-case RNG; the property panics (via assert!) to signal failure.
pub fn check<G, T, P>(name: &str, gen: G, prop: P)
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T),
{
    let base_seed =
        std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE_u64);
    let cases = default_cases();
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&input)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {case} (replay with PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Generate a vector of f32 distances with duplicates and extremes mixed in
/// — the adversarial shape for K-selection code.
pub fn gen_distances(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len);
    (0..n)
        .map(|_| match rng.below(10) {
            0 => 0.0,
            1 => f32::MAX,
            2 => rng.f32(), // dense cluster near 0
            _ => rng.normal().abs() * 100.0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("sum-nonneg", |r| r.normal_vec(10), |xs| {
            let s: f32 = xs.iter().map(|x| x * x).sum();
            assert!(s >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn check_reports_failures() {
        check("always-fails", |r| r.below(10), |_| panic!("boom"));
    }

    #[test]
    fn gen_distances_nonempty() {
        let mut r = Rng::new(1);
        for _ in 0..50 {
            let d = gen_distances(&mut r, 100);
            assert!(!d.is_empty() && d.len() <= 100);
        }
    }
}
