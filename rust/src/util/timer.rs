//! Wall-clock timing helpers and a micro-bench harness (offline substrate
//! for criterion). Used by `benches/*` and the `report` module.

use std::time::Instant;

use super::stats::Summary;

/// Time a closure once, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Repeat a closure and return per-iteration latency samples (seconds).
///
/// Runs `warmup` unrecorded iterations first; a black-box consume of the
/// result keeps the optimizer honest.
pub fn sample<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples
}

/// A named benchmark group printing criterion-style one-liners.
pub struct Bench {
    group: String,
    pub results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        println!("\n== bench group: {group} ==");
        Bench { group: group.to_string(), results: Vec::new() }
    }

    /// Run one case with the default warmup/iteration policy.
    pub fn case<T>(&mut self, name: &str, f: impl FnMut() -> T) -> Summary {
        self.case_n(name, 3, 20, f)
    }

    pub fn case_n<T>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: impl FnMut() -> T,
    ) -> Summary {
        let s = Summary::of(&sample(warmup, iters, f));
        println!("{}", s.render_ms(&format!("{}/{}", self.group, name)));
        self.results.push((name.to_string(), s.clone()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_something() {
        let (v, secs) = time(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(secs >= 0.0);
    }

    #[test]
    fn sample_count() {
        let s = sample(2, 10, || 1 + 1);
        assert_eq!(s.len(), 10);
        assert!(s.iter().all(|&x| x >= 0.0));
    }
}
