//! Minimal readiness and resource-limit shims over raw syscalls.
//!
//! The build is fully offline and vendors no `libc` crate, so the two
//! POSIX facilities the nonblocking coordinator needs — `poll(2)`
//! readiness over a set of sockets, and a raised `RLIMIT_NOFILE` soft
//! limit for high-connection benches — are declared directly as C FFI
//! on 64-bit Unix. Elsewhere the API degrades to a conservative
//! busy-poll fallback: sleep briefly and report everything ready, which
//! is correct (the sockets are nonblocking, so spurious readiness just
//! costs a `WouldBlock`) but burns a little CPU.

use std::net::TcpStream;
use std::time::Duration;

/// `poll(2)` event bits (Linux/BSD share these values).
pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;

/// The raw fd of a socket, or -1 where raw fds don't exist. `poll(2)`
/// ignores negative fds (their `revents` comes back 0), so a -1 entry
/// simply never reports ready.
#[cfg(unix)]
pub fn raw_fd(s: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
pub fn raw_fd(_s: &TcpStream) -> i32 {
    -1
}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    #[repr(C)]
    pub struct RLimit {
        pub cur: u64,
        pub max: u64,
    }

    pub const RLIMIT_NOFILE: i32 = 7;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
        pub fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        pub fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
}

/// Wait up to `timeout` for any of `fds` to become readable (or hit
/// error/hangup, which a subsequent read surfaces). Returns one flag per
/// fd: "a read will make progress". An empty set just sleeps out the
/// timeout, so an event loop with no connections parks here instead of
/// spinning.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn wait_readable(fds: &[i32], timeout: Duration) -> Vec<bool> {
    let mut pfds: Vec<sys::PollFd> = fds
        .iter()
        .map(|&fd| sys::PollFd { fd, events: POLLIN, revents: 0 })
        .collect();
    let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
    let rc = unsafe { sys::poll(pfds.as_mut_ptr(), pfds.len() as u64, ms) };
    if rc <= 0 {
        // Timeout (or EINTR): nothing ready this round.
        return vec![false; fds.len()];
    }
    pfds.iter()
        .map(|p| p.revents & (POLLIN | POLLERR | POLLHUP) != 0)
        .collect()
}

#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn wait_readable(fds: &[i32], timeout: Duration) -> Vec<bool> {
    std::thread::sleep(timeout.min(Duration::from_millis(1)));
    vec![true; fds.len()]
}

/// Wait up to `timeout` for `fd` to accept more written bytes. Used by
/// the reply path when a nonblocking send hits a full socket buffer.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn wait_writable(fd: i32, timeout: Duration) -> bool {
    let mut pfd = sys::PollFd { fd, events: POLLOUT, revents: 0 };
    let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
    let rc = unsafe { sys::poll(&mut pfd, 1, ms) };
    rc > 0 && pfd.revents & (POLLOUT | POLLERR | POLLHUP) != 0
}

#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn wait_writable(_fd: i32, timeout: Duration) -> bool {
    std::thread::sleep(timeout.min(Duration::from_millis(1)));
    true
}

/// Best-effort raise of the open-file soft limit toward `target` (the
/// 512-connection sweep needs > 1024 fds in one process). Returns the
/// soft limit actually in effect afterwards; callers treat it as a
/// ceiling, not a guarantee. With `target` at or below the current soft
/// limit this is a pure query.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn raise_nofile(target: u64) -> u64 {
    let mut lim = sys::RLimit { cur: 0, max: 0 };
    if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut lim) } != 0 {
        return target;
    }
    if lim.cur >= target {
        return lim.cur;
    }
    lim.cur = target.min(lim.max);
    unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &lim) };
    if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut lim) } != 0 {
        return target;
    }
    lim.cur
}

#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn raise_nofile(target: u64) -> u64 {
    target
}

#[cfg(test)]
#[cfg(all(unix, target_pointer_width = "64"))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    #[test]
    fn readiness_tracks_actual_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        let fd = raw_fd(&rx);

        // Nothing written yet: a short poll times out quiet.
        let r = wait_readable(&[fd], Duration::from_millis(20));
        assert_eq!(r, vec![false]);

        tx.write_all(b"ping").unwrap();
        tx.flush().unwrap();
        let r = wait_readable(&[fd], Duration::from_millis(500));
        assert_eq!(r, vec![true]);

        // An idle socket's send buffer has room.
        assert!(wait_writable(fd, Duration::from_millis(100)));

        // Peer hangup also reports ready (the read then sees EOF).
        drop(tx);
        let r = wait_readable(&[fd], Duration::from_millis(500));
        assert_eq!(r, vec![true]);
    }

    #[test]
    fn negative_fds_never_report_ready() {
        let r = wait_readable(&[-1, -1], Duration::from_millis(5));
        assert_eq!(r, vec![false, false]);
    }

    #[test]
    fn raise_nofile_is_monotone() {
        let cur = raise_nofile(0);
        assert!(cur > 0, "soft NOFILE limit reported as 0");
        // Re-raising to the current value is a no-op query.
        assert_eq!(raise_nofile(cur), cur);
        // Raising toward a higher target never lowers the limit.
        assert!(raise_nofile(cur + 16) >= cur);
    }
}
