//! Minimal JSON parser + writer (offline substrate for serde_json).
//!
//! Parses the `artifacts/manifest.json` produced by `python/compile/aot.py`
//! and serializes metrics / report output. Supports the full JSON grammar
//! except exotic number forms; numbers are stored as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not emitted by aot.py).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || b".eE+-".contains(&c))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","shape":[2,3],"scale":0.02,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""A""#).unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn parses_real_manifest_fragment() {
        let src = r#"{"artifacts":{"x":{"file":"x.hlo.txt","inputs":[{"name":"q","shape":[16,8],"dtype":"f32","kind":"arg"}]}}}"#;
        let v = Json::parse(src).unwrap();
        let x = v.get("artifacts").unwrap().get("x").unwrap();
        assert_eq!(x.get("file").unwrap().as_str(), Some("x.hlo.txt"));
    }
}
