//! Latency/throughput statistics: percentiles, summaries, and a tiny
//! fixed-width histogram used by the benches and reports (offline
//! substrate for criterion's statistics).

/// Summary statistics over a sample of latency values (seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub std: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            min: s[0],
            max: s[n - 1],
            p50: percentile_sorted(&s, 0.50),
            p90: percentile_sorted(&s, 0.90),
            p99: percentile_sorted(&s, 0.99),
            std: var.sqrt(),
        }
    }

    /// One-line human-readable rendering (times in ms).
    pub fn render_ms(&self, label: &str) -> String {
        format!(
            "{label:<32} n={:<6} p50={:>9.3}ms p90={:>9.3}ms p99={:>9.3}ms mean={:>9.3}ms",
            self.n,
            self.p50 * 1e3,
            self.p90 * 1e3,
            self.p99 * 1e3,
            self.mean * 1e3,
        )
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, q)
}

/// A fixed-bin histogram for rendering latency distributions in reports
/// (the textual stand-in for the paper's violin plots in Fig 9).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<usize>,
}

impl Histogram {
    pub fn of(samples: &[f64], n_bins: usize) -> Histogram {
        let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut bins = vec![0usize; n_bins];
        let width = ((hi - lo) / n_bins as f64).max(1e-12);
        for &x in samples {
            let b = (((x - lo) / width) as usize).min(n_bins - 1);
            bins[b] += 1;
        }
        Histogram { lo, hi, bins }
    }

    /// ASCII sparkline of the distribution shape.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = *self.bins.iter().max().unwrap_or(&1) as f64;
        self.bins
            .iter()
            .map(|&c| {
                let idx = ((c as f64 / max) * 7.0).round() as usize;
                GLYPHS[idx.min(7)]
            })
            .collect()
    }
}

/// Throughput helper: items per second given total wall time.
pub fn throughput(items: usize, secs: f64) -> f64 {
    items as f64 / secs.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sequence() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&s, 0.5) - 50.5).abs() < 1e-9);
        assert!((percentile(&s, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&s, 1.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&s, 0.99) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[0.5]);
        assert_eq!(s.p50, 0.5);
        assert_eq!(s.p99, 0.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn histogram_counts_everything() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64 / 100.0).collect();
        let h = Histogram::of(&xs, 20);
        assert_eq!(h.bins.iter().sum::<usize>(), 1000);
        assert_eq!(h.sparkline().chars().count(), 20);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(100, 2.0) - 50.0).abs() < 1e-12);
    }
}
