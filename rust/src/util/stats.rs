//! Latency/throughput statistics: percentiles, summaries, and a tiny
//! fixed-width histogram used by the benches and reports (offline
//! substrate for criterion's statistics).

/// Summary statistics over a sample of latency values (seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub std: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. NaN samples are
    /// ordered last (total order) instead of panicking the sort.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            min: s[0],
            max: s[n - 1],
            p50: percentile_sorted(&s, 0.50),
            p90: percentile_sorted(&s, 0.90),
            p95: percentile_sorted(&s, 0.95),
            p99: percentile_sorted(&s, 0.99),
            std: var.sqrt(),
        }
    }

    /// One-line human-readable rendering (times in ms).
    pub fn render_ms(&self, label: &str) -> String {
        format!(
            "{label:<32} n={:<6} p50={:>9.3}ms p95={:>9.3}ms p99={:>9.3}ms mean={:>9.3}ms",
            self.n,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.p99 * 1e3,
            self.mean * 1e3,
        )
    }
}

/// Rank-interpolated percentile of an ascending-sorted slice, q in [0,1].
///
/// Uses the Hyndman–Fan type-7 estimator (the R/NumPy default): the
/// target rank is `q * (n - 1)` and the value is linearly interpolated
/// between the two bracketing order statistics. Small-n behavior is
/// defined, not special-cased:
///   - n = 1: every percentile is the single sample;
///   - n = 2: p50 is the midpoint, p95 sits at rank 0.95 (i.e.
///     `0.05*lo + 0.95*hi`);
///   - n = 3: p50 is the middle sample exactly.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    percentile_sorted(&s, q)
}

/// Fixed-capacity uniform reservoir (Vitter's algorithm R).
///
/// Keeps at most `cap` of the observations pushed so far; below capacity
/// the sample is exact (summaries match the full-sample `Summary`
/// bit-for-bit), beyond it each observation survives with probability
/// `cap / seen`. Replacement choices come from a deterministic [`Rng`]
/// stream so runs are reproducible. This bounds `util::metrics` memory
/// under sustained open-loop load.
///
/// [`Rng`]: crate::util::rng::Rng
#[derive(Clone, Debug)]
pub struct Reservoir {
    buf: Vec<f64>,
    seen: u64,
    cap: usize,
    rng: crate::util::rng::Rng,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be > 0");
        Reservoir {
            buf: Vec::new(),
            seen: 0,
            cap,
            rng: crate::util::rng::Rng::new(seed ^ 0x5eed_5a3_917),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            // Algorithm R: replace a random slot with prob cap/seen.
            let j = self.rng.below(self.seen as usize);
            if j < self.cap {
                self.buf[j] = x;
            }
        }
    }

    /// Total observations pushed (not the held sample size).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Held sample size: `min(seen, cap)`.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The held sample (insertion order below capacity).
    pub fn samples(&self) -> &[f64] {
        &self.buf
    }

    /// Summary over the held sample, with `n` reporting the true
    /// observation count (`seen`), not the reservoir size.
    pub fn summary(&self) -> Option<Summary> {
        if self.buf.is_empty() {
            return None;
        }
        let mut s = Summary::of(&self.buf);
        s.n = self.seen as usize;
        Some(s)
    }
}

/// A fixed-bin histogram for rendering latency distributions in reports
/// (the textual stand-in for the paper's violin plots in Fig 9).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<usize>,
}

impl Histogram {
    pub fn of(samples: &[f64], n_bins: usize) -> Histogram {
        let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut bins = vec![0usize; n_bins];
        let width = ((hi - lo) / n_bins as f64).max(1e-12);
        for &x in samples {
            let b = (((x - lo) / width) as usize).min(n_bins - 1);
            bins[b] += 1;
        }
        Histogram { lo, hi, bins }
    }

    /// ASCII sparkline of the distribution shape.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = *self.bins.iter().max().unwrap_or(&1) as f64;
        self.bins
            .iter()
            .map(|&c| {
                let idx = ((c as f64 / max) * 7.0).round() as usize;
                GLYPHS[idx.min(7)]
            })
            .collect()
    }
}

/// Throughput helper: items per second given total wall time.
pub fn throughput(items: usize, secs: f64) -> f64 {
    items as f64 / secs.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sequence() {
        // Hand-computed type-7 references for 1..=100: rank = q*99, so
        // p50 = 50.5, p95 = 95.05, p99 = 99.01.
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&s, 0.5) - 50.5).abs() < 1e-9);
        assert!((percentile(&s, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&s, 1.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&s, 0.95) - 95.05).abs() < 1e-9);
        assert!((percentile(&s, 0.99) - 99.01).abs() < 1e-9);
        let sum = Summary::of(&s);
        assert!((sum.p50 - 50.5).abs() < 1e-9);
        assert!((sum.p95 - 95.05).abs() < 1e-9);
        assert!((sum.p99 - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentiles_below_four_samples_are_defined() {
        // n = 1: everything is the sample.
        let one = Summary::of(&[3.0]);
        assert_eq!((one.p50, one.p95, one.p99), (3.0, 3.0, 3.0));
        // n = 2: rank q*(n-1) = q, interpolated between the two samples.
        let two = Summary::of(&[10.0, 20.0]);
        assert!((two.p50 - 15.0).abs() < 1e-9);
        assert!((two.p95 - 19.5).abs() < 1e-9);
        assert!((two.p99 - 19.9).abs() < 1e-9);
        // n = 3: rank q*2 -> p50 is exactly the middle sample.
        let three = Summary::of(&[1.0, 2.0, 4.0]);
        assert!((three.p50 - 2.0).abs() < 1e-9);
        assert!((three.p95 - (2.0 * 0.1 + 4.0 * 0.9)).abs() < 1e-9);
        // Order independence.
        let shuffled = Summary::of(&[4.0, 1.0, 2.0]);
        assert_eq!(three, shuffled);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // total_cmp orders NaN last; min/p50 of the finite mass survive.
        let s = Summary::of(&[1.0, f64::NAN, 2.0]);
        assert_eq!(s.min, 1.0);
        assert!((s.p50 - 2.0).abs() < 1e-9);
        assert!(s.max.is_nan());
    }

    #[test]
    fn reservoir_exact_below_capacity() {
        let mut r = Reservoir::new(128, 7);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.len(), 100);
        assert_eq!(r.seen(), 100);
        // Below capacity the reservoir holds every sample, so the summary
        // equals the full-sample summary exactly.
        assert_eq!(r.summary().unwrap(), Summary::of(&xs));
    }

    #[test]
    fn reservoir_bounds_memory_and_stays_representative() {
        let cap = 256;
        let mut r = Reservoir::new(cap, 3);
        for i in 0..100_000 {
            r.push((i % 1000) as f64);
        }
        assert_eq!(r.len(), cap);
        assert_eq!(r.seen(), 100_000);
        let s = r.summary().unwrap();
        assert_eq!(s.n, 100_000);
        // Uniform 0..1000 input: the sampled median must land near 500.
        assert!((s.p50 - 500.0).abs() < 120.0, "p50 {}", s.p50);
    }

    #[test]
    fn reservoir_is_deterministic() {
        let mut a = Reservoir::new(64, 9);
        let mut b = Reservoir::new(64, 9);
        for i in 0..10_000 {
            a.push(i as f64);
            b.push(i as f64);
        }
        assert_eq!(a.samples(), b.samples());
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[0.5]);
        assert_eq!(s.p50, 0.5);
        assert_eq!(s.p99, 0.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn histogram_counts_everything() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64 / 100.0).collect();
        let h = Histogram::of(&xs, 20);
        assert_eq!(h.bins.iter().sum::<usize>(), 1000);
        assert_eq!(h.sparkline().chars().count(), 20);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(100, 2.0) - 50.0).abs() < 1e-12);
    }
}
