//! Lightweight metrics: counters + latency histograms with JSON export —
//! the observability layer of the coordinator (the paper's prototype logs
//! equivalent per-stage timings for its evaluation).
//!
//! Latency series are held in fixed-capacity reservoirs
//! ([`Reservoir`]), so memory stays bounded under sustained open-loop
//! load; below capacity the sample is exact and summaries match a
//! full-sample [`Summary`] bit-for-bit.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use super::json::{obj, Json};
use super::stats::{Reservoir, Summary};

/// Samples kept per latency series (exact below this, uniform beyond).
pub const RESERVOIR_CAP: usize = 4096;

/// A process-wide metrics registry (cheap enough for the request path).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    samples: Mutex<BTreeMap<String, Reservoir>>,
}

/// Per-series reservoir seed: deterministic per name so runs reproduce.
fn series_seed(name: &str) -> u64 {
    name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    })
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Record one latency sample (seconds).
    pub fn observe(&self, name: &str, secs: f64) {
        self.samples
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Reservoir::new(RESERVOIR_CAP, series_seed(name)))
            .push(secs);
    }

    /// Time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// Summary of a series; `n` is the true observation count even when
    /// the reservoir has downsampled.
    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.samples.lock().unwrap().get(name).and_then(|r| r.summary())
    }

    /// Samples currently held for a series (<= RESERVOIR_CAP).
    pub fn held(&self, name: &str) -> usize {
        self.samples.lock().unwrap().get(name).map_or(0, |r| r.len())
    }

    /// Export everything as JSON (counters + per-histogram percentiles).
    pub fn to_json(&self) -> Json {
        let counters = self.counters.lock().unwrap();
        let samples = self.samples.lock().unwrap();
        let mut c = BTreeMap::new();
        for (k, v) in counters.iter() {
            c.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut h = BTreeMap::new();
        for (k, r) in samples.iter() {
            let Some(s) = r.summary() else { continue };
            h.insert(
                k.clone(),
                obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("p50", Json::Num(s.p50)),
                    ("p90", Json::Num(s.p90)),
                    ("p95", Json::Num(s.p95)),
                    ("p99", Json::Num(s.p99)),
                    ("mean", Json::Num(s.mean)),
                ]),
            );
        }
        obj(vec![("counters", Json::Obj(c)), ("latency", Json::Obj(h))])
    }

    /// Human-readable dump.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, r) in self.samples.lock().unwrap().iter() {
            if let Some(s) = r.summary() {
                out.push_str(&s.render_ms(k));
                out.push('\n');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("queries", 1);
        m.incr("queries", 2);
        assert_eq!(m.counter("queries"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn histograms_summarize() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64 / 1000.0);
        }
        let s = m.summary("lat").unwrap();
        assert_eq!(s.n, 100);
        assert!((s.p50 - 0.0505).abs() < 1e-3);
    }

    #[test]
    fn small_series_match_full_sample_summary() {
        // Below reservoir capacity nothing is dropped: the summary must
        // equal the old unbounded full-sample behavior exactly.
        let m = Metrics::new();
        let xs: Vec<f64> = (1..=500).map(|i| i as f64 / 250.0).collect();
        for &x in &xs {
            m.observe("lat", x);
        }
        assert_eq!(m.summary("lat").unwrap(), Summary::of(&xs));
        assert_eq!(m.held("lat"), xs.len());
    }

    #[test]
    fn sustained_series_stay_bounded() {
        let m = Metrics::new();
        for i in 0..10 * RESERVOIR_CAP {
            m.observe("lat", (i % 100) as f64);
        }
        assert_eq!(m.held("lat"), RESERVOIR_CAP);
        let s = m.summary("lat").unwrap();
        assert_eq!(s.n, 10 * RESERVOIR_CAP);
        assert!((s.p50 - 49.5).abs() < 10.0, "p50 {}", s.p50);
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let v = m.time("work", || 7u32);
        assert_eq!(v, 7);
        assert_eq!(m.summary("work").unwrap().n, 1);
    }

    #[test]
    fn json_export_roundtrips() {
        let m = Metrics::new();
        m.incr("a", 5);
        m.observe("b", 0.25);
        let j = m.to_json();
        let parsed = crate::util::json::Json::parse(&j.dump()).unwrap();
        assert_eq!(
            parsed.get("counters").unwrap().get("a").unwrap().as_f64(),
            Some(5.0)
        );
        assert!(parsed.get("latency").unwrap().get("b").is_some());
    }
}
