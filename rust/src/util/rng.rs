//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 seeds a xoshiro256**-style generator; every experiment in
//! the repo threads explicit seeds so runs are reproducible bit-for-bit.

/// A small, fast, deterministic RNG (xoshiro256**).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-shard / per-thread use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine here:
        // bias is < 2^-40 for the n used in this crate.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// A vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Reservoir sampling keeps memory at O(k) for large n.
        let mut res: Vec<usize> = (0..k).collect();
        for i in k..n {
            let j = self.below(i + 1);
            if j < k {
                res[j] = i;
            }
        }
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs = r.normal_vec(100_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(1000, 100);
        assert_eq!(s.len(), 100);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
        assert!(sorted.iter().all(|&i| i < 1000));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
