//! Shared utilities: deterministic RNG, minimal JSON, statistics, timing,
//! a tiny CLI parser and a property-testing helper.
//!
//! All of these are substrates we would normally pull from crates.io
//! (rand/serde_json/criterion/clap/proptest); the build is fully offline,
//! so they are implemented from scratch here and unit-tested like any
//! other module.

pub mod affinity;
pub mod cli;
pub mod json;
pub mod metrics;
pub mod poll;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
