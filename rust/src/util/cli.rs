//! Tiny command-line parser (offline substrate for clap).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand if it
    /// does not start with '-').
    pub fn parse_from(tokens: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = tokens.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value`, `--key value`, or boolean `--flag`.
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.options.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(&std::env::args().skip(1).collect::<Vec<_>>())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse_from(&toks("serve --batch 8 --verbose --out=x.json db"));
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("batch"), Some("8"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["db"]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse_from(&toks("x --n 42 --f 1.5"));
        assert_eq!(a.get_usize("n", 0), 42);
        assert!((a.get_f64("f", 0.0) - 1.5).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse_from(&toks("run --fast"));
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse_from(&toks("--help"));
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }
}
