//! Wire protocol between the coordinator and memory nodes.
//!
//! Frames are length-prefixed little-endian binary:
//!   u32 magic | u32 kind | u64 payload_len | payload
//! Payload encodings are fixed-layout (no self-describing overhead —
//! the hot path moves f32/u32 arrays).
//!
//! Decoding is defensive: element counts are validated against the
//! remaining payload before any allocation, so a truncated or garbage
//! frame yields an error instead of a panic or a huge `Vec` reservation.

use std::io::{Read, Write};

use anyhow::{bail, Result};
use byteorder::{LittleEndian as LE, ReadBytesExt, WriteBytesExt};

pub const MAGIC: u32 = 0xC4A3_1E0F;

/// Frame kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    ScanRequest = 1,
    ScanResponse = 2,
    Shutdown = 3,
    /// GPU -> coordinator: retrieve neighbors + tokens for a query vector
    /// (paper workflow step 3).
    RetrieveRequest = 4,
    /// Coordinator -> GPU: neighbor tokens + distances (step 9).
    RetrieveResponse = 5,
    /// Memory node -> coordinator, once per connection at accept time:
    /// the node's identity and PQ geometry (the client side needs `m` to
    /// validate query dims without an out-of-band contract).
    Hello = 6,
    /// Coordinator -> node: a whole dispatch batch in one frame, so one
    /// network round trip carries every query of a coordinator round.
    BatchScanRequest = 7,
    /// Node -> coordinator: per-query local top-Ks for one batch frame.
    BatchScanResponse = 8,
    /// Admin -> coordinator: a live cluster-membership transition
    /// (join/drain/remove a memory node); applied between dispatch
    /// rounds, never mid-batch.
    ClusterUpdate = 9,
    /// Coordinator -> admin: the transition's outcome + new epoch.
    ClusterAck = 10,
    /// -> memory node: retire gracefully — finish in-flight work, stop
    /// accepting new connections, exit once the current one closes.
    Drain = 11,
    /// Coordinator -> GPU: the request was shed by admission control
    /// (tenant queue full or rate limit); the payload names the shed
    /// request and a retry hint. Sent *instead of* a `RetrieveResponse`,
    /// out of band with respect to the connection's FIFO reply stream —
    /// match on `query_id`, not on arrival order.
    Backpressure = 12,
    /// Memory node -> coordinator: a well-framed request failed to decode
    /// or execute. Sent instead of a response so one malformed request
    /// doesn't tear down a connection carrying other tenants' traffic;
    /// only unframeable bytes (bad magic/kind/length) close the stream.
    NodeError = 13,
    /// -> coordinator: ask for a live telemetry snapshot (counters,
    /// per-tenant latency/burn, tail-sampled traces). Optionally gated
    /// to the admin connection like [`Shutdown`](Kind::Shutdown). Peers
    /// predating the stats plane close the connection on this kind —
    /// the caller uses a dedicated connection so serving traffic never
    /// shares a stream with a stats probe.
    StatsRequest = 14,
    /// Coordinator -> caller: the snapshot, as a versioned JSON document
    /// (stats are a cold path; JSON keeps the schema evolvable without a
    /// wire change, and the revision field pins compatibility).
    StatsResponse = 15,
}

impl Kind {
    fn from_u32(x: u32) -> Result<Kind> {
        Ok(match x {
            1 => Kind::ScanRequest,
            2 => Kind::ScanResponse,
            3 => Kind::Shutdown,
            4 => Kind::RetrieveRequest,
            5 => Kind::RetrieveResponse,
            6 => Kind::Hello,
            7 => Kind::BatchScanRequest,
            8 => Kind::BatchScanResponse,
            9 => Kind::ClusterUpdate,
            10 => Kind::ClusterAck,
            11 => Kind::Drain,
            12 => Kind::Backpressure,
            13 => Kind::NodeError,
            14 => Kind::StatsRequest,
            15 => Kind::StatsResponse,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// A raw frame.
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: Kind,
    pub payload: Vec<u8>,
}

/// Bytes in the fixed frame header (`magic | kind | payload_len`).
pub const FRAME_HEADER_BYTES: usize = 16;

/// Largest accepted payload (defensive cap shared by every decode path).
pub const MAX_PAYLOAD_BYTES: usize = 1 << 30;

/// Bytes of the per-frame payload checksum trailer (FNV-1a 64 over the
/// payload), appended when both peers negotiated checksums via [`Hello`]
/// capability flags. The trailer is *inside* `payload_len`, so a
/// non-negotiating peer never sees it — checksummed frames only flow
/// between peers that both advertised [`HELLO_CAP_CHECKSUMS`].
pub const CHECKSUM_TRAILER_BYTES: usize = 8;

/// FNV-1a 64 over a byte slice: the frame payload checksum. Not
/// cryptographic — it exists to catch injected bit flips and truncation
/// before corrupt distances get merged, not to resist an adversary.
pub fn payload_checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl Frame {
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_u32::<LE>(MAGIC)?;
        w.write_u32::<LE>(self.kind as u32)?;
        w.write_u64::<LE>(self.payload.len() as u64)?;
        w.write_all(&self.payload)?;
        w.flush()?;
        Ok(())
    }

    /// [`write_to`](Self::write_to) with the negotiated checksum trailer
    /// appended (and counted in `payload_len`).
    pub fn write_to_checksummed(&self, w: &mut impl Write) -> Result<()> {
        let len = self.payload.len() + CHECKSUM_TRAILER_BYTES;
        w.write_u32::<LE>(MAGIC)?;
        w.write_u32::<LE>(self.kind as u32)?;
        w.write_u64::<LE>(len as u64)?;
        w.write_all(&self.payload)?;
        w.write_u64::<LE>(payload_checksum(&self.payload))?;
        w.flush()?;
        Ok(())
    }

    /// The full wire image (header + payload) as one buffer — the shape a
    /// nonblocking writer needs so a partial `write` can resume at a byte
    /// offset instead of mid-`write_to`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(FRAME_HEADER_BYTES + self.payload.len());
        buf.write_u32::<LE>(MAGIC).unwrap();
        buf.write_u32::<LE>(self.kind as u32).unwrap();
        buf.write_u64::<LE>(self.payload.len() as u64).unwrap();
        buf.extend_from_slice(&self.payload);
        buf
    }

    /// Verify and strip a checksum trailer in place. Call on frames read
    /// from a connection that negotiated checksums.
    pub fn verify_strip_checksum(&mut self) -> Result<()> {
        anyhow::ensure!(
            self.payload.len() >= CHECKSUM_TRAILER_BYTES,
            "{:?} frame too short for checksum trailer ({} bytes)",
            self.kind,
            self.payload.len()
        );
        let body_len = self.payload.len() - CHECKSUM_TRAILER_BYTES;
        let want = (&self.payload[body_len..]).read_u64::<LE>()?;
        let got = payload_checksum(&self.payload[..body_len]);
        anyhow::ensure!(
            got == want,
            "{:?} frame payload checksum mismatch (corruption on the wire)",
            self.kind
        );
        self.payload.truncate(body_len);
        Ok(())
    }

    /// Blocking frame read. NOT resumable: a read timeout mid-frame loses
    /// the bytes already consumed, so on a stream with a read timeout use
    /// [`FrameReader`] instead (the serving loops all do). Kept for
    /// clients that block without timeouts (request/response round trips).
    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let magic = r.read_u32::<LE>()?;
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let kind = Kind::from_u32(r.read_u32::<LE>()?)?;
        let len = r.read_u64::<LE>()? as usize;
        if len > MAX_PAYLOAD_BYTES {
            bail!("frame too large: {len}");
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        Ok(Frame { kind, payload })
    }
}

/// Outcome of one [`FrameReader::poll`] pump.
#[derive(Debug)]
pub enum ReadProgress {
    /// A complete frame was decoded.
    Frame(Frame),
    /// The source has no more bytes right now (`WouldBlock`/timeout);
    /// any partial header/payload bytes stay buffered for the next poll.
    Idle,
    /// Clean EOF exactly on a frame boundary.
    Closed,
}

/// Incremental frame decoder: a resumable state machine that buffers
/// partial header/payload bytes across reads, so a `WouldBlock` or read
/// timeout *mid-frame* suspends the parse instead of desyncing it (the
/// slow-client bug: `Frame::read_from` restarted parsing mid-stream after
/// a timeout had already consumed part of the header).
///
/// One `FrameReader` per connection; feed it the connection's stream —
/// blocking with a read timeout, or nonblocking under a readiness loop —
/// and pump [`poll`](Self::poll) until `Idle`.
#[derive(Debug, Default)]
pub struct FrameReader {
    header: [u8; FRAME_HEADER_BYTES],
    /// Header bytes buffered so far (< FRAME_HEADER_BYTES while partial).
    have: usize,
    /// Decoded header + payload buffer being filled (`Some` once the
    /// header is complete and validated).
    body: Option<(Kind, Vec<u8>)>,
    filled: usize,
    /// When set (checksums negotiated via Hello), every completed frame
    /// must carry a valid [`CHECKSUM_TRAILER_BYTES`] trailer, which is
    /// verified and stripped before the frame is handed up.
    checksums: bool,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Enable (or disable) checksum-trailer verification on every
    /// subsequent frame. Flip this the moment checksum negotiation
    /// completes — at a frame boundary, never mid-frame.
    pub fn set_checksums(&mut self, on: bool) {
        self.checksums = on;
    }

    /// Whether any bytes of the next frame have been consumed — the
    /// "timeout is only idleness at a frame boundary" predicate.
    pub fn mid_frame(&self) -> bool {
        self.have > 0 || self.body.is_some()
    }

    /// Pump the reader: consume available bytes from `r` and return the
    /// first complete frame, `Idle` on `WouldBlock`/timeout (state kept),
    /// or `Closed` on EOF at a frame boundary. EOF mid-frame and protocol
    /// garbage (bad magic/kind/length) are errors.
    pub fn poll(&mut self, r: &mut impl Read) -> Result<ReadProgress> {
        // Phase 1: fill the 16-byte header.
        while self.body.is_none() {
            match r.read(&mut self.header[self.have..]) {
                Ok(0) => {
                    if self.have == 0 {
                        return Ok(ReadProgress::Closed);
                    }
                    bail!("eof mid-frame ({} header bytes buffered)", self.have);
                }
                Ok(n) => {
                    self.have += n;
                    if self.have == FRAME_HEADER_BYTES {
                        self.body = Some(self.decode_header()?);
                    }
                }
                Err(e) if would_block(&e) => return Ok(ReadProgress::Idle),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        // Phase 2: fill the payload.
        let (_, payload) = self.body.as_mut().unwrap();
        while self.filled < payload.len() {
            match r.read(&mut payload[self.filled..]) {
                Ok(0) => bail!(
                    "eof mid-frame ({}/{} payload bytes)",
                    self.filled,
                    payload.len()
                ),
                Ok(n) => self.filled += n,
                Err(e) if would_block(&e) => return Ok(ReadProgress::Idle),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        let (kind, payload) = self.body.take().unwrap();
        self.have = 0;
        self.filled = 0;
        let mut frame = Frame { kind, payload };
        if self.checksums {
            frame.verify_strip_checksum()?;
        }
        Ok(ReadProgress::Frame(frame))
    }

    /// Validate the buffered header and allocate the payload buffer.
    fn decode_header(&self) -> Result<(Kind, Vec<u8>)> {
        let mut h = &self.header[..];
        let magic = h.read_u32::<LE>()?;
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let kind = Kind::from_u32(h.read_u32::<LE>()?)?;
        let len = h.read_u64::<LE>()? as usize;
        if len > MAX_PAYLOAD_BYTES {
            bail!("frame too large: {len}");
        }
        Ok((kind, vec![0u8; len]))
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

// ---------------------------------------------------------------- readers
//
// Checked array readers: the claimed element count must fit in the bytes
// actually present, bounding both the read and the allocation by the
// frame's (already size-capped) payload.

fn read_f32s(r: &mut &[u8], n: usize) -> Result<Vec<f32>> {
    anyhow::ensure!(r.len() >= 4 * n, "truncated frame: {n} f32s > {} bytes", r.len());
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(r.read_f32::<LE>()?);
    }
    Ok(v)
}

fn read_u32s(r: &mut &[u8], n: usize) -> Result<Vec<u32>> {
    anyhow::ensure!(r.len() >= 4 * n, "truncated frame: {n} u32s > {} bytes", r.len());
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(r.read_u32::<LE>()?);
    }
    Ok(v)
}

fn read_u64s(r: &mut &[u8], n: usize) -> Result<Vec<u64>> {
    anyhow::ensure!(r.len() >= 8 * n, "truncated frame: {n} u64s > {} bytes", r.len());
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(r.read_u64::<LE>()?);
    }
    Ok(v)
}

/// A length-prefixed UTF-8 string; the claimed length must fit in the
/// remaining payload before anything is allocated.
fn read_string(r: &mut &[u8]) -> Result<String> {
    let n = r.read_u32::<LE>()? as usize;
    anyhow::ensure!(n <= r.len(), "truncated frame: {n}-byte string > {} bytes", r.len());
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|e| anyhow::anyhow!("invalid utf-8 in frame string: {e}"))
}

/// An item count whose items occupy at least `min_item_bytes` each.
fn read_count(r: &mut &[u8], min_item_bytes: usize) -> Result<usize> {
    let n = r.read_u32::<LE>()? as usize;
    anyhow::ensure!(
        n.saturating_mul(min_item_bytes) <= r.len(),
        "truncated frame: {n} items > {} bytes",
        r.len()
    );
    Ok(n)
}

// ------------------------------------------------------------------ hello

/// Capability bit in [`Hello::flags`]: the sender can verify and emit
/// per-frame payload checksum trailers. Checksums turn on for a
/// connection only after BOTH directions advertised the bit (the node in
/// its accept-time Hello, the client in the Hello it sends back); either
/// side omitting it keeps the legacy plain framing, so old peers interop.
pub const HELLO_CAP_CHECKSUMS: u32 = 1 << 0;

/// Bytes of the optional capability-flags tail on [`Hello`].
pub const HELLO_FLAGS_TAIL_BYTES: usize = 4;

/// Node handshake, sent by a memory node once per accepted connection.
/// `shard`/`n_shards` declare which carve of the database this node
/// holds, so a coordinator can place replicated nodes into its cluster
/// map without an out-of-band assignment contract. A client that wants
/// to negotiate capabilities answers with a Hello of its own (old
/// clients never do, and old nodes treat an unexpected frame as an
/// error reply — negotiation stays opt-in at both ends).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub node_id: u32,
    /// PQ code width of the node's shard.
    pub m: u32,
    /// IVF list count of the node's shard.
    pub nlist: u32,
    /// Which shard (of `n_shards`) this node holds a replica of.
    pub shard: u32,
    /// Shard count the node's carve was taken at.
    pub n_shards: u32,
    /// Capability flags (optional tail on the wire; 0 from old peers).
    pub flags: u32,
}

impl Hello {
    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(20 + HELLO_FLAGS_TAIL_BYTES);
        p.write_u32::<LE>(self.node_id).unwrap();
        p.write_u32::<LE>(self.m).unwrap();
        p.write_u32::<LE>(self.nlist).unwrap();
        p.write_u32::<LE>(self.shard).unwrap();
        p.write_u32::<LE>(self.n_shards).unwrap();
        p.write_u32::<LE>(self.flags).unwrap();
        Frame { kind: Kind::Hello, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<Hello> {
        if f.kind != Kind::Hello {
            bail!("not a hello");
        }
        let mut r = &f.payload[..];
        let mut h = Hello {
            node_id: r.read_u32::<LE>()?,
            m: r.read_u32::<LE>()?,
            nlist: r.read_u32::<LE>()?,
            shard: r.read_u32::<LE>()?,
            n_shards: r.read_u32::<LE>()?,
            flags: 0,
        };
        match r.len() {
            0 => {} // pre-capability peer: no flags
            HELLO_FLAGS_TAIL_BYTES => h.flags = r.read_u32::<LE>()?,
            // A longer tail is a future peer advertising more than we
            // understand: read our flags word, ignore the rest.
            n if n > HELLO_FLAGS_TAIL_BYTES => h.flags = r.read_u32::<LE>()?,
            n => bail!("hello with partial flags tail ({n} bytes)"),
        }
        Ok(h)
    }

    /// Whether this peer advertised checksummed framing.
    pub fn wants_checksums(&self) -> bool {
        self.flags & HELLO_CAP_CHECKSUMS != 0
    }
}

// ---------------------------------------------------------------- cluster

/// A cluster-membership transition kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterOp {
    /// Add a memory node (the coordinator connects to `addr`).
    Join = 1,
    /// Retire a node: excluded from new selection, finishes in flight.
    Drain = 2,
    /// Drop a node from the map (its connection closes).
    Remove = 3,
}

impl ClusterOp {
    fn from_u32(x: u32) -> Result<ClusterOp> {
        Ok(match x {
            1 => ClusterOp::Join,
            2 => ClusterOp::Drain,
            3 => ClusterOp::Remove,
            other => bail!("unknown cluster op {other}"),
        })
    }
}

/// Admin request for a live membership transition. For `Join`, `addr` is
/// the node's `host:port` and `shard` is validated against the node's own
/// Hello; for `Drain`/`Remove`, only `node_id` is meaningful.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterUpdate {
    pub op: ClusterOp,
    pub node_id: u32,
    pub shard: u32,
    pub addr: String,
}

impl ClusterUpdate {
    pub fn encode(&self) -> Frame {
        let bytes = self.addr.as_bytes();
        let mut p = Vec::with_capacity(16 + bytes.len());
        p.write_u32::<LE>(self.op as u32).unwrap();
        p.write_u32::<LE>(self.node_id).unwrap();
        p.write_u32::<LE>(self.shard).unwrap();
        p.write_u32::<LE>(bytes.len() as u32).unwrap();
        p.extend_from_slice(bytes);
        Frame { kind: Kind::ClusterUpdate, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<ClusterUpdate> {
        if f.kind != Kind::ClusterUpdate {
            bail!("not a cluster update");
        }
        let mut r = &f.payload[..];
        let op = ClusterOp::from_u32(r.read_u32::<LE>()?)?;
        let node_id = r.read_u32::<LE>()?;
        let shard = r.read_u32::<LE>()?;
        let addr = read_string(&mut r)?;
        Ok(ClusterUpdate { op, node_id, shard, addr })
    }
}

/// Coordinator reply to a [`ClusterUpdate`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterAck {
    /// Cluster-map epoch after the transition (unchanged on failure).
    pub epoch: u64,
    pub ok: bool,
    /// Human-readable outcome (error text on failure).
    pub message: String,
}

impl ClusterAck {
    pub fn encode(&self) -> Frame {
        let bytes = self.message.as_bytes();
        let mut p = Vec::with_capacity(16 + bytes.len());
        p.write_u64::<LE>(self.epoch).unwrap();
        p.write_u32::<LE>(u32::from(self.ok)).unwrap();
        p.write_u32::<LE>(bytes.len() as u32).unwrap();
        p.extend_from_slice(bytes);
        Frame { kind: Kind::ClusterAck, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<ClusterAck> {
        if f.kind != Kind::ClusterAck {
            bail!("not a cluster ack");
        }
        let mut r = &f.payload[..];
        let epoch = r.read_u64::<LE>()?;
        let ok = r.read_u32::<LE>()? != 0;
        let message = read_string(&mut r)?;
        Ok(ClusterAck { epoch, ok, message })
    }
}

// ------------------------------------------------------------------- scan

/// A scan request: query vector + probed list ids (paper step 4/5).
#[derive(Clone, Debug, PartialEq)]
pub struct ScanRequest {
    pub query_id: u64,
    pub query: Vec<f32>,
    pub lists: Vec<u32>,
    pub k: u32,
}

impl ScanRequest {
    /// Serialized body size (the batch frame preallocates from this).
    fn body_len(&self) -> usize {
        20 + 4 * self.query.len() + 4 * self.lists.len()
    }

    fn write_body(&self, p: &mut Vec<u8>) {
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.k).unwrap();
        p.write_u32::<LE>(self.query.len() as u32).unwrap();
        p.write_u32::<LE>(self.lists.len() as u32).unwrap();
        for &x in &self.query {
            p.write_f32::<LE>(x).unwrap();
        }
        for &l in &self.lists {
            p.write_u32::<LE>(l).unwrap();
        }
    }

    fn read_body(r: &mut &[u8]) -> Result<ScanRequest> {
        let query_id = r.read_u64::<LE>()?;
        let k = r.read_u32::<LE>()?;
        let qn = r.read_u32::<LE>()? as usize;
        let ln = r.read_u32::<LE>()? as usize;
        let query = read_f32s(r, qn)?;
        let lists = read_u32s(r, ln)?;
        Ok(ScanRequest { query_id, query, lists, k })
    }

    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(self.body_len());
        self.write_body(&mut p);
        Frame { kind: Kind::ScanRequest, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<ScanRequest> {
        if f.kind != Kind::ScanRequest {
            bail!("not a scan request");
        }
        Self::read_body(&mut &f.payload[..])
    }
}

/// A scan response: the node's local top-K (paper step 7), plus the
/// node-side latency accounting — `measured_s` is the host wall actually
/// spent, so the networked dispatch path reports honest measured numbers
/// instead of zeros.
#[derive(Clone, Debug, PartialEq)]
pub struct ScanResponse {
    pub query_id: u64,
    pub node_id: u32,
    pub dists: Vec<f32>,
    pub ids: Vec<u64>,
    /// Node-side modeled accelerator seconds (for latency accounting).
    pub modeled_s: f64,
    /// Node-side host wall-clock seconds actually spent on this scan.
    pub measured_s: f64,
    /// PQ codes scanned on the node.
    pub n_scanned: u64,
    /// Node-side ADC lookup-table build seconds attributed to this
    /// query. Optional on the wire (timing tail): decodes to 0.0 from a
    /// node that predates the per-stage breakdown.
    pub lut_s: f64,
    /// Node-side scan+select wall seconds (the per-stage twin of
    /// `measured_s`; 0.0 from a node that omits the timing tail).
    pub scan_s: f64,
}

/// Bytes of the optional per-stage timing tail (`lut_s`, `scan_s`).
///
/// Compatibility contract, both directions: decoders ignore trailing
/// payload bytes they don't understand, so an old coordinator skips the
/// tail a new node appends; a new decoder reads the tail when exactly
/// present, falls back to zeros when absent, and only errors on a
/// partial (torn) tail.
pub const SCAN_TIMING_TAIL_BYTES: usize = 16;

impl ScanResponse {
    /// Serialized *legacy* body size — the timing tail rides after all
    /// bodies, never inside them.
    fn body_len(&self) -> usize {
        40 + 12 * self.ids.len()
    }

    fn write_body(&self, p: &mut Vec<u8>) {
        assert_eq!(self.dists.len(), self.ids.len());
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.node_id).unwrap();
        p.write_f64::<LE>(self.modeled_s).unwrap();
        p.write_f64::<LE>(self.measured_s).unwrap();
        p.write_u64::<LE>(self.n_scanned).unwrap();
        p.write_u32::<LE>(self.ids.len() as u32).unwrap();
        for &d in &self.dists {
            p.write_f32::<LE>(d).unwrap();
        }
        for &i in &self.ids {
            p.write_u64::<LE>(i).unwrap();
        }
    }

    fn write_tail(&self, p: &mut Vec<u8>) {
        p.write_f64::<LE>(self.lut_s).unwrap();
        p.write_f64::<LE>(self.scan_s).unwrap();
    }

    fn read_tail(&mut self, r: &mut &[u8]) -> Result<()> {
        self.lut_s = r.read_f64::<LE>()?;
        self.scan_s = r.read_f64::<LE>()?;
        Ok(())
    }

    fn read_body(r: &mut &[u8]) -> Result<ScanResponse> {
        let query_id = r.read_u64::<LE>()?;
        let node_id = r.read_u32::<LE>()?;
        let modeled_s = r.read_f64::<LE>()?;
        let measured_s = r.read_f64::<LE>()?;
        let n_scanned = r.read_u64::<LE>()?;
        let n = read_count(r, 12)?;
        let dists = read_f32s(r, n)?;
        let ids = read_u64s(r, n)?;
        Ok(ScanResponse {
            query_id,
            node_id,
            dists,
            ids,
            modeled_s,
            measured_s,
            n_scanned,
            lut_s: 0.0,
            scan_s: 0.0,
        })
    }

    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(self.body_len() + SCAN_TIMING_TAIL_BYTES);
        self.write_body(&mut p);
        self.write_tail(&mut p);
        Frame { kind: Kind::ScanResponse, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<ScanResponse> {
        if f.kind != Kind::ScanResponse {
            bail!("not a scan response");
        }
        let mut r = &f.payload[..];
        let mut resp = Self::read_body(&mut r)?;
        match r.len() {
            0 => {} // timing-less peer: stage fields stay zero
            SCAN_TIMING_TAIL_BYTES => resp.read_tail(&mut r)?,
            n => bail!("scan response with partial timing tail ({n} bytes)"),
        }
        Ok(resp)
    }
}

// ------------------------------------------------------------ batch scan

/// One coordinator dispatch round as a single frame: every query of the
/// batch, each with its own request id (replies are matched by id).
#[derive(Clone, Debug, PartialEq)]
pub struct BatchScanRequest {
    pub items: Vec<ScanRequest>,
}

impl BatchScanRequest {
    pub fn encode(&self) -> Frame {
        let total: usize = self.items.iter().map(ScanRequest::body_len).sum();
        let mut p = Vec::with_capacity(4 + total);
        p.write_u32::<LE>(self.items.len() as u32).unwrap();
        for it in &self.items {
            it.write_body(&mut p);
        }
        Frame { kind: Kind::BatchScanRequest, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<BatchScanRequest> {
        if f.kind != Kind::BatchScanRequest {
            bail!("not a batch scan request");
        }
        let mut r = &f.payload[..];
        let n = read_count(&mut r, 20)?;
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            items.push(ScanRequest::read_body(&mut r)?);
        }
        Ok(BatchScanRequest { items })
    }
}

/// Per-query local top-Ks for one [`BatchScanRequest`], in request order.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchScanResponse {
    pub node_id: u32,
    pub items: Vec<ScanResponse>,
}

impl BatchScanResponse {
    pub fn encode(&self) -> Frame {
        let total: usize = self.items.iter().map(ScanResponse::body_len).sum();
        let mut p =
            Vec::with_capacity(8 + total + self.items.len() * SCAN_TIMING_TAIL_BYTES);
        p.write_u32::<LE>(self.node_id).unwrap();
        p.write_u32::<LE>(self.items.len() as u32).unwrap();
        for it in &self.items {
            it.write_body(&mut p);
        }
        // Per-item timing tails after ALL bodies: unambiguous (the frame
        // length bounds the payload) and invisible to old decoders,
        // which stop after the last body.
        for it in &self.items {
            it.write_tail(&mut p);
        }
        Frame { kind: Kind::BatchScanResponse, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<BatchScanResponse> {
        if f.kind != Kind::BatchScanResponse {
            bail!("not a batch scan response");
        }
        let mut r = &f.payload[..];
        let node_id = r.read_u32::<LE>()?;
        let n = read_count(&mut r, 40)?;
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            items.push(ScanResponse::read_body(&mut r)?);
        }
        match r.len() {
            0 => {} // timing-less peer
            rem if rem == n * SCAN_TIMING_TAIL_BYTES => {
                for it in &mut items {
                    it.read_tail(&mut r)?;
                }
            }
            rem => bail!(
                "batch scan response with partial timing tail ({rem} bytes for {n} items)"
            ),
        }
        Ok(BatchScanResponse { node_id, items })
    }
}

// --------------------------------------------------------------- retrieve

/// GPU-side retrieval request: the raw query vector plus the list ids the
/// colocated index scan selected (the coordinator "records the
/// association between queries and GPU IDs", Sec 3 step 3/4). `query_id`
/// is the per-connection request id replies are routed by — the
/// concurrent coordinator answers a connection's requests in FIFO order,
/// and pipelined clients re-match responses on it.
#[derive(Clone, Debug, PartialEq)]
pub struct RetrieveRequest {
    pub query_id: u64,
    pub gpu_id: u32,
    pub query: Vec<f32>,
    pub lists: Vec<u32>,
    pub k: u32,
    /// True for EncDec models: respond with chunk tokens, not next-tokens.
    pub want_chunks: bool,
    /// End-to-end latency budget in microseconds, measured from the
    /// coordinator's decode of this frame; 0 = no deadline. Queue wait,
    /// retries, hedges and reconnects all draw from this one budget:
    /// expired in queue -> shed with `Backpressure`, expired mid-scan ->
    /// partial result. Optional tail on the wire (0 from old clients).
    pub deadline_us: u64,
}

/// Bytes of the optional deadline tail on [`RetrieveRequest`].
pub const RETRIEVE_DEADLINE_TAIL_BYTES: usize = 8;

impl RetrieveRequest {
    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(
            28 + 4 * self.query.len()
                + 4 * self.lists.len()
                + RETRIEVE_DEADLINE_TAIL_BYTES,
        );
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.gpu_id).unwrap();
        p.write_u32::<LE>(self.k).unwrap();
        p.write_u32::<LE>(u32::from(self.want_chunks)).unwrap();
        p.write_u32::<LE>(self.query.len() as u32).unwrap();
        p.write_u32::<LE>(self.lists.len() as u32).unwrap();
        for &x in &self.query {
            p.write_f32::<LE>(x).unwrap();
        }
        for &l in &self.lists {
            p.write_u32::<LE>(l).unwrap();
        }
        p.write_u64::<LE>(self.deadline_us).unwrap();
        Frame { kind: Kind::RetrieveRequest, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<RetrieveRequest> {
        if f.kind != Kind::RetrieveRequest {
            bail!("not a retrieve request");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let gpu_id = r.read_u32::<LE>()?;
        let k = r.read_u32::<LE>()?;
        let want_chunks = r.read_u32::<LE>()? != 0;
        let qn = r.read_u32::<LE>()? as usize;
        let ln = r.read_u32::<LE>()? as usize;
        let query = read_f32s(&mut r, qn)?;
        let lists = read_u32s(&mut r, ln)?;
        let deadline_us = match r.len() {
            0 => 0, // pre-deadline client
            RETRIEVE_DEADLINE_TAIL_BYTES => r.read_u64::<LE>()?,
            n => bail!("retrieve request with partial deadline tail ({n} bytes)"),
        };
        Ok(RetrieveRequest {
            query_id,
            gpu_id,
            query,
            lists,
            k,
            want_chunks,
            deadline_us,
        })
    }
}

/// Coordinator reply: retrieved token payload + distances, plus shard
/// coverage (how many shards contributed to the merged top-k) so clients
/// can tell a complete answer from a degraded partial one.
#[derive(Clone, Debug, PartialEq)]
pub struct RetrieveResponse {
    pub query_id: u64,
    /// Next-tokens of the K neighbors (decoder-only) or concatenated
    /// chunk tokens (EncDec, K*chunk_len long).
    pub tokens: Vec<u32>,
    pub dists: Vec<f32>,
    /// Shards whose scans made it into the merge (coverage tail;
    /// 0 from a pre-coverage coordinator — treat as complete).
    pub shards_answered: u32,
    /// Total shards the query fanned out to (0 from an old coordinator).
    pub n_shards: u32,
}

/// Bytes of the optional coverage tail on [`RetrieveResponse`].
pub const RETRIEVE_COVERAGE_TAIL_BYTES: usize = 8;

impl RetrieveResponse {
    /// A response covering every shard (the only shape an old
    /// coordinator can produce, and the common case on a new one).
    pub fn complete(query_id: u64, tokens: Vec<u32>, dists: Vec<f32>) -> Self {
        RetrieveResponse { query_id, tokens, dists, shards_answered: 0, n_shards: 0 }
    }

    /// Fraction of shards that answered; 1.0 when the coverage tail is
    /// absent (old coordinator) or every shard answered.
    pub fn coverage(&self) -> f64 {
        if self.n_shards == 0 {
            return 1.0;
        }
        self.shards_answered as f64 / self.n_shards as f64
    }

    /// Whether this is a degraded partial result (some shard unanswered).
    pub fn is_partial(&self) -> bool {
        self.n_shards != 0 && self.shards_answered < self.n_shards
    }

    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(
            16 + 4 * self.tokens.len()
                + 4 * self.dists.len()
                + RETRIEVE_COVERAGE_TAIL_BYTES,
        );
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.tokens.len() as u32).unwrap();
        p.write_u32::<LE>(self.dists.len() as u32).unwrap();
        for &t in &self.tokens {
            p.write_u32::<LE>(t).unwrap();
        }
        for &d in &self.dists {
            p.write_f32::<LE>(d).unwrap();
        }
        p.write_u32::<LE>(self.shards_answered).unwrap();
        p.write_u32::<LE>(self.n_shards).unwrap();
        Frame { kind: Kind::RetrieveResponse, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<RetrieveResponse> {
        if f.kind != Kind::RetrieveResponse {
            bail!("not a retrieve response");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let tn = r.read_u32::<LE>()? as usize;
        let dn = r.read_u32::<LE>()? as usize;
        let tokens = read_u32s(&mut r, tn)?;
        let dists = read_f32s(&mut r, dn)?;
        let (shards_answered, n_shards) = match r.len() {
            0 => (0, 0), // pre-coverage coordinator
            RETRIEVE_COVERAGE_TAIL_BYTES => {
                (r.read_u32::<LE>()?, r.read_u32::<LE>()?)
            }
            n => bail!("retrieve response with partial coverage tail ({n} bytes)"),
        };
        Ok(RetrieveResponse { query_id, tokens, dists, shards_answered, n_shards })
    }
}

/// Coordinator reply when admission control sheds a request instead of
/// queueing it: names the shed `query_id`, the tenant it was charged to,
/// why it was shed, and a retry hint. Pipelined clients must match on
/// `query_id` — a backpressure reply is written immediately at admission
/// time, ahead of responses for earlier requests still in the batcher.
#[derive(Clone, Debug, PartialEq)]
pub struct Backpressure {
    pub query_id: u64,
    /// Tenant the request was charged to (the request's `gpu_id`).
    pub tenant: u32,
    /// Shed reason code: 1 = tenant queue full, 2 = rate limited.
    pub reason: u32,
    /// Tenant queue depth at shed time (sizing hint for the client).
    pub queue_depth: u32,
    /// Suggested client backoff before retrying, in microseconds.
    pub retry_after_us: u64,
}

impl Backpressure {
    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(28);
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.tenant).unwrap();
        p.write_u32::<LE>(self.reason).unwrap();
        p.write_u32::<LE>(self.queue_depth).unwrap();
        p.write_u64::<LE>(self.retry_after_us).unwrap();
        Frame { kind: Kind::Backpressure, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<Backpressure> {
        if f.kind != Kind::Backpressure {
            bail!("not a backpressure frame");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let tenant = r.read_u32::<LE>()?;
        let reason = r.read_u32::<LE>()?;
        let queue_depth = r.read_u32::<LE>()?;
        let retry_after_us = r.read_u64::<LE>()?;
        Ok(Backpressure { query_id, tenant, reason, queue_depth, retry_after_us })
    }
}

// ------------------------------------------------------------- node error

/// Error reply for a well-framed request that failed to decode or
/// execute. The connection stays alive: the sender answers the one bad
/// request and keeps serving the rest, tearing down only on unframeable
/// bytes. `query_id` is 0 when the bad request's id could not be parsed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeError {
    pub query_id: u64,
    pub message: String,
}

impl NodeError {
    pub fn encode(&self) -> Frame {
        let bytes = self.message.as_bytes();
        let mut p = Vec::with_capacity(12 + bytes.len());
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(bytes.len() as u32).unwrap();
        p.extend_from_slice(bytes);
        Frame { kind: Kind::NodeError, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<NodeError> {
        if f.kind != Kind::NodeError {
            bail!("not a node error frame");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let message = read_string(&mut r)?;
        Ok(NodeError { query_id, message })
    }
}

// ------------------------------------------------------------ stats plane

/// Wire revision of the [`StatsResponse`] JSON schema. Bumped when keys
/// documented in README §Live telemetry change incompatibly; readers
/// must tolerate unknown keys at the same revision.
pub const STATS_REVISION: u32 = 1;

/// Ask a coordinator for a live telemetry snapshot.
///
/// `prefix` restricts the registry dump to metric names with that dotted
/// prefix (empty = everything); `flags` is reserved (0). An **empty
/// payload decodes to the defaults**, so a minimal peer can probe with a
/// bare kind-14 frame — and, like `Hello`, the decoder reads the fields
/// it knows and ignores a longer tail, pinning old-peer interop in both
/// directions.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatsRequest {
    pub prefix: String,
    pub flags: u32,
}

impl StatsRequest {
    pub fn encode(&self) -> Frame {
        let bytes = self.prefix.as_bytes();
        let mut p = Vec::with_capacity(8 + bytes.len());
        p.write_u32::<LE>(self.flags).unwrap();
        p.write_u32::<LE>(bytes.len() as u32).unwrap();
        p.extend_from_slice(bytes);
        Frame { kind: Kind::StatsRequest, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<StatsRequest> {
        if f.kind != Kind::StatsRequest {
            bail!("not a stats request");
        }
        if f.payload.is_empty() {
            return Ok(StatsRequest::default());
        }
        let mut r = &f.payload[..];
        let flags = r.read_u32::<LE>()?;
        let prefix = read_string(&mut r)?;
        // Trailing bytes are a future tail from a newer peer: ignore.
        Ok(StatsRequest { prefix, flags })
    }
}

/// The telemetry snapshot: a [`STATS_REVISION`]-versioned JSON document
/// (see README §Live telemetry for the key catalog). Trailing payload
/// bytes beyond the string are ignored, mirroring [`StatsRequest`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatsResponse {
    pub revision: u32,
    pub json: String,
}

impl StatsResponse {
    pub fn encode(&self) -> Frame {
        let bytes = self.json.as_bytes();
        let mut p = Vec::with_capacity(8 + bytes.len());
        p.write_u32::<LE>(self.revision).unwrap();
        p.write_u32::<LE>(bytes.len() as u32).unwrap();
        p.extend_from_slice(bytes);
        Frame { kind: Kind::StatsResponse, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<StatsResponse> {
        if f.kind != Kind::StatsResponse {
            bail!("not a stats response");
        }
        let mut r = &f.payload[..];
        let revision = r.read_u32::<LE>()?;
        let json = read_string(&mut r)?;
        Ok(StatsResponse { revision, json })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_scan_request() -> ScanRequest {
        ScanRequest {
            query_id: 42,
            query: vec![1.0, -2.5, 3.25],
            lists: vec![7, 9, 11],
            k: 10,
        }
    }

    fn sample_scan_response(qid: u64) -> ScanResponse {
        ScanResponse {
            query_id: qid,
            node_id: 3,
            dists: vec![0.5, 1.5],
            ids: vec![100, 200],
            modeled_s: 1.25e-3,
            measured_s: 0.75e-3,
            n_scanned: 1234,
            lut_s: 0.25e-3,
            scan_s: 0.5e-3,
        }
    }

    /// Frame-layer round trip through write_to/read_from.
    fn roundtrip(f: Frame) -> Frame {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        Frame::read_from(&mut &buf[..]).unwrap()
    }

    #[test]
    fn every_kind_roundtrips_through_the_frame_layer() {
        let frames = vec![
            sample_scan_request().encode(),
            sample_scan_response(1).encode(),
            Frame { kind: Kind::Shutdown, payload: vec![] },
            RetrieveRequest {
                query_id: 5,
                gpu_id: 2,
                query: vec![0.5, -1.0],
                lists: vec![3, 1],
                k: 10,
                want_chunks: true,
                deadline_us: 5_000,
            }
            .encode(),
            RetrieveResponse::complete(5, vec![10, 20], vec![0.1, 0.2]).encode(),
            Hello { node_id: 2, m: 16, nlist: 77, shard: 1, n_shards: 4, flags: 0 }
                .encode(),
            NodeError { query_id: 9, message: "bad request".to_string() }.encode(),
            ClusterUpdate {
                op: ClusterOp::Join,
                node_id: 9,
                shard: 1,
                addr: "127.0.0.1:4242".to_string(),
            }
            .encode(),
            ClusterAck { epoch: 17, ok: true, message: "joined".to_string() }
                .encode(),
            Frame { kind: Kind::Drain, payload: vec![] },
            BatchScanRequest {
                items: vec![sample_scan_request(), ScanRequest {
                    query_id: 43,
                    query: vec![0.0; 4],
                    lists: vec![],
                    k: 5,
                }],
            }
            .encode(),
            BatchScanResponse {
                node_id: 1,
                items: vec![sample_scan_response(42), sample_scan_response(43)],
            }
            .encode(),
        ];
        for f in frames {
            let back = roundtrip(f.clone());
            assert_eq!(back.kind, f.kind);
            assert_eq!(back.payload, f.payload);
        }
    }

    #[test]
    fn retrieve_request_roundtrip() {
        let req = RetrieveRequest {
            query_id: 5,
            gpu_id: 2,
            query: vec![0.5, -1.0],
            lists: vec![3, 1],
            k: 10,
            want_chunks: true,
            deadline_us: 12_500,
        };
        let back = roundtrip(req.encode());
        assert_eq!(RetrieveRequest::decode(&back).unwrap(), req);
    }

    #[test]
    fn retrieve_request_deadline_tail_compat() {
        // Old client -> new coordinator: a payload stopping at the legacy
        // body decodes with no deadline.
        let req = RetrieveRequest {
            query_id: 7,
            gpu_id: 1,
            query: vec![1.0, 2.0],
            lists: vec![4],
            k: 3,
            want_chunks: false,
            deadline_us: 9999,
        };
        let f = req.encode();
        let legacy_len = f.payload.len() - RETRIEVE_DEADLINE_TAIL_BYTES;
        let legacy = Frame { kind: f.kind, payload: f.payload[..legacy_len].to_vec() };
        let d = RetrieveRequest::decode(&legacy).unwrap();
        assert_eq!(d.deadline_us, 0);
        assert_eq!(d.query, req.query);
        // A torn tail is an error, not a silent zero.
        for cut in legacy_len + 1..f.payload.len() {
            let t = Frame { kind: f.kind, payload: f.payload[..cut].to_vec() };
            assert!(RetrieveRequest::decode(&t).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn retrieve_response_roundtrip() {
        let resp = RetrieveResponse {
            query_id: 5,
            tokens: vec![10, 20, 30],
            dists: vec![0.1, 0.2, 0.3],
            shards_answered: 3,
            n_shards: 4,
        };
        let back = roundtrip(resp.encode());
        let d = RetrieveResponse::decode(&back).unwrap();
        assert_eq!(d, resp);
        assert!(d.is_partial());
        assert!((d.coverage() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn retrieve_response_coverage_tail_compat() {
        // Old coordinator -> new client: no coverage tail reads as a
        // complete answer (coverage 1.0, not partial).
        let resp = RetrieveResponse::complete(5, vec![10], vec![0.5]);
        let f = resp.encode();
        let legacy_len = f.payload.len() - RETRIEVE_COVERAGE_TAIL_BYTES;
        let legacy = Frame { kind: f.kind, payload: f.payload[..legacy_len].to_vec() };
        let d = RetrieveResponse::decode(&legacy).unwrap();
        assert_eq!(d.coverage(), 1.0);
        assert!(!d.is_partial());
        for cut in legacy_len + 1..f.payload.len() {
            let t = Frame { kind: f.kind, payload: f.payload[..cut].to_vec() };
            assert!(RetrieveResponse::decode(&t).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn node_error_roundtrip() {
        let e = NodeError { query_id: 3, message: "scan failed: dim".to_string() };
        let back = roundtrip(e.encode());
        assert_eq!(NodeError::decode(&back).unwrap(), e);
    }

    #[test]
    fn request_roundtrip() {
        let req = sample_scan_request();
        let back = roundtrip(req.encode());
        assert_eq!(ScanRequest::decode(&back).unwrap(), req);
    }

    #[test]
    fn response_roundtrip() {
        let resp = sample_scan_response(1);
        let back = roundtrip(resp.encode());
        assert_eq!(ScanResponse::decode(&back).unwrap(), resp);
    }

    #[test]
    fn hello_roundtrip() {
        let h = Hello {
            node_id: 7,
            m: 32,
            nlist: 141,
            shard: 3,
            n_shards: 8,
            flags: HELLO_CAP_CHECKSUMS,
        };
        let back = roundtrip(h.encode());
        let d = Hello::decode(&back).unwrap();
        assert_eq!(d, h);
        assert!(d.wants_checksums());
    }

    #[test]
    fn hello_flags_tail_compat() {
        // Old node -> new client: a 20-byte Hello decodes with flags 0.
        let h = Hello { node_id: 1, m: 8, nlist: 32, shard: 0, n_shards: 2, flags: 7 };
        let f = h.encode();
        let legacy = Frame {
            kind: f.kind,
            payload: f.payload[..f.payload.len() - HELLO_FLAGS_TAIL_BYTES].to_vec(),
        };
        let d = Hello::decode(&legacy).unwrap();
        assert_eq!(d.flags, 0);
        assert!(!d.wants_checksums());
        // Future peer with a longer tail: our flags word still reads.
        let mut longer = f.payload.clone();
        longer.extend_from_slice(&[0u8; 12]);
        let d = Hello::decode(&Frame { kind: f.kind, payload: longer }).unwrap();
        assert_eq!(d.flags, 7);
    }

    #[test]
    fn checksummed_frame_roundtrip_and_detection() {
        let f = sample_scan_request().encode();
        let mut wire = Vec::new();
        f.write_to_checksummed(&mut wire).unwrap();

        // A checksum-aware reader verifies, strips, and hands up the
        // original payload.
        let mut fr = FrameReader::new();
        fr.set_checksums(true);
        match fr.poll(&mut &wire[..]).unwrap() {
            ReadProgress::Frame(got) => {
                assert_eq!(got.payload, f.payload);
                assert_eq!(ScanRequest::decode(&got).unwrap(), sample_scan_request());
            }
            other => panic!("expected frame, got {other:?}"),
        }

        // Flip any payload byte: the reader must error, never deliver.
        for i in FRAME_HEADER_BYTES..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 0x10;
            let mut fr = FrameReader::new();
            fr.set_checksums(true);
            assert!(fr.poll(&mut &bad[..]).is_err(), "flip at byte {i} undetected");
        }
    }

    #[test]
    fn checksum_strip_requires_trailer() {
        // Plain frames fed to a checksumming reader must error (too
        // short / mismatch), not silently pass.
        let mut short = Frame { kind: Kind::Shutdown, payload: vec![] };
        assert!(short.verify_strip_checksum().is_err());
        let mut plain = sample_scan_request().encode();
        assert!(plain.verify_strip_checksum().is_err());
    }

    #[test]
    fn cluster_update_roundtrip() {
        for op in [ClusterOp::Join, ClusterOp::Drain, ClusterOp::Remove] {
            let u = ClusterUpdate {
                op,
                node_id: 3,
                shard: 2,
                addr: if op == ClusterOp::Join {
                    "10.0.0.7:9000".to_string()
                } else {
                    String::new()
                },
            };
            let back = roundtrip(u.encode());
            assert_eq!(ClusterUpdate::decode(&back).unwrap(), u);
        }
    }

    #[test]
    fn cluster_ack_roundtrip() {
        for (ok, msg) in [(true, "epoch advanced"), (false, "unknown node 9")] {
            let a = ClusterAck { epoch: 42, ok, message: msg.to_string() };
            let back = roundtrip(a.encode());
            assert_eq!(ClusterAck::decode(&back).unwrap(), a);
        }
    }

    #[test]
    fn cluster_update_rejects_bad_strings() {
        // Claimed string length beyond the payload must error up front.
        let mut p = Vec::new();
        p.write_u32::<LE>(1).unwrap(); // op: Join
        p.write_u32::<LE>(0).unwrap(); // node_id
        p.write_u32::<LE>(0).unwrap(); // shard
        p.write_u32::<LE>(u32::MAX).unwrap(); // addr len: absurd
        let f = Frame { kind: Kind::ClusterUpdate, payload: p };
        assert!(ClusterUpdate::decode(&f).is_err());

        // Non-UTF-8 bytes under a valid length must error, not panic.
        let mut p = Vec::new();
        p.write_u32::<LE>(1).unwrap();
        p.write_u32::<LE>(0).unwrap();
        p.write_u32::<LE>(0).unwrap();
        p.write_u32::<LE>(2).unwrap();
        p.extend_from_slice(&[0xff, 0xfe]);
        let f = Frame { kind: Kind::ClusterUpdate, payload: p };
        assert!(ClusterUpdate::decode(&f).is_err());
    }

    #[test]
    fn batch_scan_roundtrip() {
        let req = BatchScanRequest {
            items: (0..3)
                .map(|i| ScanRequest {
                    query_id: i,
                    query: vec![i as f32; 4],
                    lists: vec![i as u32],
                    k: 10,
                })
                .collect(),
        };
        let back = roundtrip(req.encode());
        assert_eq!(BatchScanRequest::decode(&back).unwrap(), req);

        let resp = BatchScanResponse {
            node_id: 2,
            items: (0..3).map(|i| sample_scan_response(i)).collect(),
        };
        let back = roundtrip(resp.encode());
        assert_eq!(BatchScanResponse::decode(&back).unwrap(), resp);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = vec![0u8; 16];
        assert!(Frame::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_wrong_kind() {
        let req = ScanRequest { query_id: 0, query: vec![], lists: vec![], k: 1 };
        let f = req.encode();
        assert!(ScanResponse::decode(&f).is_err());
    }

    #[test]
    fn shutdown_frame_roundtrip() {
        let back = roundtrip(Frame { kind: Kind::Shutdown, payload: vec![] });
        assert_eq!(back.kind, Kind::Shutdown);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut buf = Vec::new();
        sample_scan_request().encode().write_to(&mut buf).unwrap();
        // Every strict prefix must fail at the frame layer, not panic.
        for cut in [0, 3, 8, 15, 16, buf.len() - 1] {
            assert!(Frame::read_from(&mut &buf[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn truncated_payload_decode_errors() {
        let resp = sample_scan_response(9);
        let full = resp.encode();
        let legacy_len = resp.body_len();
        assert_eq!(full.payload.len(), legacy_len + SCAN_TIMING_TAIL_BYTES);
        for cut in 0..full.payload.len() {
            let f = Frame { kind: full.kind, payload: full.payload[..cut].to_vec() };
            if cut == legacy_len {
                // A cut at exactly the legacy body is a valid frame from
                // a timing-less peer: stage fields fall back to zeros.
                let d = ScanResponse::decode(&f).unwrap();
                assert_eq!((d.lut_s, d.scan_s), (0.0, 0.0));
            } else {
                assert!(ScanResponse::decode(&f).is_err(), "cut={cut}");
            }
        }
    }

    #[test]
    fn timing_tail_roundtrips() {
        // New node -> new coordinator: the per-stage fields survive both
        // the single and the batched frame shape.
        let resp = sample_scan_response(4);
        let d = ScanResponse::decode(&roundtrip(resp.encode())).unwrap();
        assert_eq!(d, resp);
        assert_eq!((d.lut_s, d.scan_s), (0.25e-3, 0.5e-3));

        let batch = BatchScanResponse {
            node_id: 2,
            items: (0..3)
                .map(|i| {
                    let mut r = sample_scan_response(i);
                    r.lut_s = i as f64 * 1e-4;
                    r.scan_s = i as f64 * 2e-4;
                    r
                })
                .collect(),
        };
        let d = BatchScanResponse::decode(&roundtrip(batch.encode())).unwrap();
        assert_eq!(d, batch);
    }

    #[test]
    fn timingless_peer_decodes_to_zeros() {
        // Old node -> new coordinator: a payload that stops at the last
        // legacy body must decode (never error), stage fields zeroed.
        let mut want = sample_scan_response(7);
        let mut p = Vec::new();
        want.write_body(&mut p);
        let d = ScanResponse::decode(&Frame { kind: Kind::ScanResponse, payload: p })
            .unwrap();
        want.lut_s = 0.0;
        want.scan_s = 0.0;
        assert_eq!(d, want);

        let items: Vec<ScanResponse> =
            (0..3).map(sample_scan_response).collect();
        let mut p = Vec::new();
        p.write_u32::<LE>(5).unwrap();
        p.write_u32::<LE>(items.len() as u32).unwrap();
        for it in &items {
            it.write_body(&mut p);
        }
        let d =
            BatchScanResponse::decode(&Frame { kind: Kind::BatchScanResponse, payload: p })
                .unwrap();
        assert_eq!(d.node_id, 5);
        for (got, sent) in d.items.iter().zip(&items) {
            assert_eq!((got.lut_s, got.scan_s), (0.0, 0.0));
            assert_eq!(got.ids, sent.ids);
            assert_eq!(got.measured_s, sent.measured_s);
        }
    }

    #[test]
    fn new_frames_keep_the_legacy_body_prefix() {
        // New node -> old coordinator: an old decoder reads the legacy
        // body and ignores trailing bytes, so the tail must ride strictly
        // after an unchanged body encoding.
        let resp = sample_scan_response(3);
        let mut legacy = Vec::new();
        resp.write_body(&mut legacy);
        let f = resp.encode();
        assert_eq!(&f.payload[..legacy.len()], &legacy[..]);

        let batch = BatchScanResponse { node_id: 1, items: vec![sample_scan_response(8)] };
        let mut legacy = Vec::new();
        legacy.write_u32::<LE>(batch.node_id).unwrap();
        legacy.write_u32::<LE>(1).unwrap();
        batch.items[0].write_body(&mut legacy);
        let f = batch.encode();
        assert_eq!(&f.payload[..legacy.len()], &legacy[..]);
        assert_eq!(f.payload.len(), legacy.len() + SCAN_TIMING_TAIL_BYTES);
    }

    #[test]
    fn partial_batch_timing_tail_errors() {
        let batch = BatchScanResponse {
            node_id: 1,
            items: (0..2).map(sample_scan_response).collect(),
        };
        let full = batch.encode();
        let tail = batch.items.len() * SCAN_TIMING_TAIL_BYTES;
        let body_end = full.payload.len() - tail;
        for cut in body_end + 1..full.payload.len() {
            let f = Frame { kind: full.kind, payload: full.payload[..cut].to_vec() };
            assert!(BatchScanResponse::decode(&f).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn garbage_counts_error_without_allocating() {
        // A frame claiming u32::MAX queries must be rejected up front (a
        // naive Vec::with_capacity would try to reserve gigabytes).
        let mut p = Vec::new();
        p.write_u64::<LE>(1).unwrap(); // query_id
        p.write_u32::<LE>(10).unwrap(); // k
        p.write_u32::<LE>(u32::MAX).unwrap(); // qn: absurd
        p.write_u32::<LE>(0).unwrap(); // ln
        let f = Frame { kind: Kind::ScanRequest, payload: p };
        assert!(ScanRequest::decode(&f).is_err());

        let mut p = Vec::new();
        p.write_u32::<LE>(0).unwrap(); // node_id
        p.write_u32::<LE>(u32::MAX).unwrap(); // item count: absurd
        let f = Frame { kind: Kind::BatchScanResponse, payload: p };
        assert!(BatchScanResponse::decode(&f).is_err());
    }

    #[test]
    fn garbage_bytes_decode_errors() {
        // Arbitrary bytes under a valid kind: decode must return Err (any
        // error is fine) rather than panicking.
        let junk: Vec<u8> = (0..64u32).map(|i| (i * 37 + 11) as u8).collect();
        for kind in [
            Kind::ScanRequest,
            Kind::ScanResponse,
            Kind::RetrieveRequest,
            Kind::RetrieveResponse,
            Kind::BatchScanRequest,
            Kind::BatchScanResponse,
            Kind::ClusterUpdate,
            Kind::ClusterAck,
        ] {
            let f = Frame { kind, payload: junk.clone() };
            let failed = match kind {
                Kind::ScanRequest => ScanRequest::decode(&f).is_err(),
                Kind::ScanResponse => ScanResponse::decode(&f).is_err(),
                Kind::RetrieveRequest => RetrieveRequest::decode(&f).is_err(),
                Kind::RetrieveResponse => RetrieveResponse::decode(&f).is_err(),
                Kind::BatchScanRequest => BatchScanRequest::decode(&f).is_err(),
                Kind::BatchScanResponse => BatchScanResponse::decode(&f).is_err(),
                Kind::ClusterUpdate => ClusterUpdate::decode(&f).is_err(),
                Kind::ClusterAck => ClusterAck::decode(&f).is_err(),
                _ => unreachable!(),
            };
            assert!(failed, "{kind:?} accepted garbage");
        }
    }

    #[test]
    fn backpressure_roundtrip() {
        let b = Backpressure {
            query_id: 77,
            tenant: 1002,
            reason: 1,
            queue_depth: 16,
            retry_after_us: 2500,
        };
        let back = roundtrip(b.encode());
        assert_eq!(Backpressure::decode(&back).unwrap(), b);
    }

    #[test]
    fn backpressure_rejects_truncation_and_wrong_kind() {
        let f = Backpressure {
            query_id: 1,
            tenant: 2,
            reason: 2,
            queue_depth: 3,
            retry_after_us: 4,
        }
        .encode();
        for cut in 0..f.payload.len() {
            let t = Frame { kind: f.kind, payload: f.payload[..cut].to_vec() };
            assert!(Backpressure::decode(&t).is_err(), "cut={cut}");
        }
        let wrong = Frame { kind: Kind::Shutdown, payload: f.payload };
        assert!(Backpressure::decode(&wrong).is_err());
    }

    /// A reader that serves the wire bytes in fixed-size slivers and
    /// interposes a `WouldBlock` between every sliver — the worst-case
    /// dribbling peer a nonblocking frame reader has to survive.
    struct Dribble {
        bytes: Vec<u8>,
        pos: usize,
        chunk: usize,
        ready: bool,
    }

    impl std::io::Read for Dribble {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if !self.ready {
                self.ready = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.ready = false;
            let n = self.chunk.min(buf.len()).min(self.bytes.len() - self.pos);
            buf[..n].copy_from_slice(&self.bytes[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn frame_reader_resumes_across_would_block_at_every_chunk_size() {
        let frames = vec![
            sample_scan_request().encode(),
            Frame { kind: Kind::Shutdown, payload: vec![] },
            sample_scan_response(3).encode(),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            f.write_to(&mut wire).unwrap();
        }
        // Every sliver size — including 1 byte at a time, which splits
        // both the header and the payload mid-field.
        for chunk in [1usize, 2, 3, 7, 16, 17, 64] {
            let mut src =
                Dribble { bytes: wire.clone(), pos: 0, chunk, ready: false };
            let mut fr = FrameReader::new();
            let mut got = Vec::new();
            loop {
                match fr.poll(&mut src).unwrap() {
                    ReadProgress::Frame(f) => got.push(f),
                    ReadProgress::Idle => continue,
                    ReadProgress::Closed => break,
                }
            }
            assert_eq!(got.len(), frames.len(), "chunk={chunk}");
            for (g, want) in got.iter().zip(&frames) {
                assert_eq!(g.kind, want.kind, "chunk={chunk}");
                assert_eq!(g.payload, want.payload, "chunk={chunk}");
            }
            assert!(!fr.mid_frame());
        }
    }

    #[test]
    fn frame_reader_tracks_mid_frame_state() {
        let mut wire = Vec::new();
        sample_scan_request().encode().write_to(&mut wire).unwrap();

        // Partial header: the reader buffers 7 bytes, reports Idle on the
        // WouldBlock, and remembers it is mid-frame.
        let mut fr = FrameReader::new();
        let mut src =
            Dribble { bytes: wire[..7].to_vec(), pos: 0, chunk: 7, ready: true };
        assert!(matches!(fr.poll(&mut src).unwrap(), ReadProgress::Idle));
        assert!(fr.mid_frame());

        // Partial payload: header complete, body buffered, still mid-frame.
        let mut fr = FrameReader::new();
        let cut = FRAME_HEADER_BYTES + 3;
        let mut src =
            Dribble { bytes: wire[..cut].to_vec(), pos: 0, chunk: cut, ready: true };
        assert!(matches!(fr.poll(&mut src).unwrap(), ReadProgress::Idle));
        assert!(fr.mid_frame());

        // Feeding the rest completes the original frame exactly.
        let mut rest = &wire[cut..];
        match fr.poll(&mut rest).unwrap() {
            ReadProgress::Frame(f) => {
                assert_eq!(ScanRequest::decode(&f).unwrap(), sample_scan_request());
            }
            other => panic!("expected frame, got {other:?}"),
        }
        assert!(!fr.mid_frame());
    }

    #[test]
    fn frame_reader_closed_only_at_frame_boundary() {
        // Clean EOF between frames is a graceful close...
        let mut wire = Vec::new();
        sample_scan_request().encode().write_to(&mut wire).unwrap();
        let mut fr = FrameReader::new();
        let mut r = &wire[..];
        assert!(matches!(fr.poll(&mut r).unwrap(), ReadProgress::Frame(_)));
        assert!(matches!(fr.poll(&mut r).unwrap(), ReadProgress::Closed));

        // ...but EOF mid-header and mid-payload are hard errors.
        for cut in [1, 8, 15, FRAME_HEADER_BYTES + 2] {
            let mut fr = FrameReader::new();
            let mut r = &wire[..cut];
            let err = loop {
                match fr.poll(&mut r) {
                    Ok(ReadProgress::Idle) => continue,
                    Ok(other) => panic!("cut={cut}: expected error, got {other:?}"),
                    Err(e) => break e,
                }
            };
            assert!(err.to_string().contains("eof mid-frame"), "cut={cut}: {err}");
        }
    }

    /// Serves a byte stream in pre-chosen chunk sizes with a `WouldBlock`
    /// between chunks, then clean EOF — the property-test source.
    struct Chunked {
        bytes: Vec<u8>,
        pos: usize,
        sizes: Vec<usize>,
        next: usize,
        ready: bool,
    }

    impl std::io::Read for Chunked {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if !self.ready {
                self.ready = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.ready = false;
            if self.pos >= self.bytes.len() {
                return Ok(0);
            }
            let want = self.sizes[self.next % self.sizes.len()].max(1);
            self.next += 1;
            let n = want.min(buf.len()).min(self.bytes.len() - self.pos);
            buf[..n].copy_from_slice(&self.bytes[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    /// One generated fuzz case: a stream of random frames, a re-chunking
    /// schedule, and an optional injected mutilation.
    struct FuzzCase {
        frames: Vec<Frame>,
        wire: Vec<u8>,
        sizes: Vec<usize>,
        /// None = pristine; Some((i, 0)) = truncate at byte i;
        /// Some((i, mask != 0)) = flip `mask` into byte i.
        mutation: Option<(usize, u8)>,
        checksums: bool,
    }

    fn gen_fuzz_case(rng: &mut crate::util::rng::Rng) -> FuzzCase {
        let kinds = [
            Kind::ScanRequest,
            Kind::ScanResponse,
            Kind::Shutdown,
            Kind::RetrieveRequest,
            Kind::Backpressure,
            Kind::NodeError,
        ];
        let checksums = rng.below(2) == 0;
        let n = 1 + rng.below(4);
        let frames: Vec<Frame> = (0..n)
            .map(|_| {
                let len = rng.below(160);
                let payload: Vec<u8> =
                    (0..len).map(|_| rng.next_u64() as u8).collect();
                Frame { kind: kinds[rng.below(kinds.len())], payload }
            })
            .collect();
        let mut wire = Vec::new();
        for f in &frames {
            if checksums {
                f.write_to_checksummed(&mut wire).unwrap();
            } else {
                f.write_to(&mut wire).unwrap();
            }
        }
        let sizes: Vec<usize> =
            (0..1 + rng.below(8)).map(|_| 1 + rng.below(64)).collect();
        let mutation = match rng.below(3) {
            0 => None,
            1 => Some((rng.below(wire.len()), 0)), // truncation
            _ => Some((rng.below(wire.len()), 1 << rng.below(8) as u8)),
        };
        FuzzCase { frames, wire, sizes, mutation, checksums }
    }

    /// Satellite property: arbitrary re-chunking with injected bit flips
    /// and truncations never panics the reader and never lets it resync
    /// mid-frame — the outcome is always clean frames followed by either
    /// a clean close or one error, and (under checksums) every delivered
    /// frame's payload is byte-identical to what was sent.
    #[test]
    fn frame_reader_fuzz_never_panics_or_resyncs() {
        crate::util::prop::check("frame-reader-fuzz", gen_fuzz_case, |case| {
            let mut bytes = case.wire.clone();
            let mut truncated = false;
            match case.mutation {
                Some((at, 0)) => {
                    bytes.truncate(at);
                    truncated = true;
                }
                Some((at, mask)) => bytes[at] ^= mask,
                None => {}
            }
            let mut src = Chunked {
                bytes,
                pos: 0,
                sizes: case.sizes.clone(),
                next: 0,
                ready: false,
            };
            let mut fr = FrameReader::new();
            fr.set_checksums(case.checksums);
            let mut got: Vec<Frame> = Vec::new();
            let mut errored = false;
            let mut closed = false;
            // Bounded pump: the source alternates WouldBlock/data, so
            // 4x the wire length comfortably covers every schedule.
            for _ in 0..8 * case.wire.len() + 64 {
                match fr.poll(&mut src) {
                    Ok(ReadProgress::Frame(f)) => got.push(f),
                    Ok(ReadProgress::Idle) => continue,
                    Ok(ReadProgress::Closed) => {
                        closed = true;
                        break;
                    }
                    Err(_) => {
                        errored = true;
                        break;
                    }
                }
            }
            assert!(
                errored || closed,
                "reader neither closed nor errored (stuck mid-frame)"
            );
            assert!(got.len() <= case.frames.len(), "more frames out than in");
            match case.mutation {
                None => {
                    // Pristine stream: everything delivered, clean close.
                    assert!(closed, "pristine stream must close cleanly");
                    assert_eq!(got.len(), case.frames.len());
                    for (g, w) in got.iter().zip(&case.frames) {
                        assert_eq!(g.kind, w.kind);
                        assert_eq!(g.payload, w.payload);
                    }
                }
                Some((_, 0)) => {
                    // Truncation: delivered frames are an exact prefix;
                    // EOF mid-frame is an error, at a boundary a close.
                    assert!(truncated);
                    for (g, w) in got.iter().zip(&case.frames) {
                        assert_eq!(g.kind, w.kind);
                        assert_eq!(g.payload, w.payload);
                    }
                    if closed {
                        assert!(!fr.mid_frame(), "closed while mid-frame");
                    }
                }
                Some(_) => {
                    // Bit flip: under checksums no corrupted payload may
                    // ever be delivered — each delivered frame's payload
                    // is byte-identical to the one sent in its slot.
                    if case.checksums {
                        for (g, w) in got.iter().zip(&case.frames) {
                            assert_eq!(
                                g.payload, w.payload,
                                "corrupted payload delivered despite checksums"
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn frame_reader_rejects_garbage_header_immediately() {
        // Bad magic fails as soon as the 16 header bytes are in — the
        // reader never waits for a bogus multi-gigabyte "payload".
        let mut fr = FrameReader::new();
        let garbage = [0xabu8; FRAME_HEADER_BYTES];
        assert!(fr.poll(&mut &garbage[..]).is_err());

        // Oversized length claim with a valid magic also fails up front.
        let mut h = Vec::new();
        h.write_u32::<LE>(MAGIC).unwrap();
        h.write_u32::<LE>(Kind::Shutdown as u32).unwrap();
        h.write_u64::<LE>((MAX_PAYLOAD_BYTES as u64) + 1).unwrap();
        let mut fr = FrameReader::new();
        assert!(fr.poll(&mut &h[..]).is_err());
    }

    // ------------------------------------------------------ stats plane

    #[test]
    fn stats_kinds_pin_wire_numbers() {
        // 14/15 are wire contract: old peers key their close-on-unknown
        // behavior off these exact numbers.
        assert_eq!(Kind::StatsRequest as u32, 14);
        assert_eq!(Kind::StatsResponse as u32, 15);
        assert_eq!(Kind::from_u32(14).unwrap(), Kind::StatsRequest);
        assert_eq!(Kind::from_u32(15).unwrap(), Kind::StatsResponse);
    }

    #[test]
    fn stats_request_roundtrip() {
        let req = StatsRequest { prefix: "coordinator.".to_string(), flags: 0 };
        let back = roundtrip(req.encode());
        assert_eq!(StatsRequest::decode(&back).unwrap(), req);
    }

    #[test]
    fn stats_response_roundtrip() {
        let resp = StatsResponse {
            revision: STATS_REVISION,
            json: r#"{"uptime_s":1.5,"tenants":[]}"#.to_string(),
        };
        let back = roundtrip(resp.encode());
        assert_eq!(StatsResponse::decode(&back).unwrap(), resp);
    }

    #[test]
    fn stats_request_empty_payload_is_default() {
        // A minimal (or older) peer probing with a bare kind-14 frame
        // gets the "dump everything" defaults.
        let f = Frame { kind: Kind::StatsRequest, payload: Vec::new() };
        assert_eq!(StatsRequest::decode(&f).unwrap(), StatsRequest::default());
    }

    #[test]
    fn stats_frames_ignore_future_tails() {
        // A newer peer may append fields; today's decoder reads what it
        // knows and ignores the rest (the Hello idiom).
        let mut f = StatsRequest { prefix: "net.".to_string(), flags: 7 }.encode();
        f.payload.extend_from_slice(&[0xde, 0xad, 0xbe, 0xef]);
        let got = StatsRequest::decode(&f).unwrap();
        assert_eq!(got.prefix, "net.");
        assert_eq!(got.flags, 7);

        let mut f = StatsResponse { revision: 9, json: "{}".to_string() }.encode();
        f.payload.extend_from_slice(&[1, 2, 3]);
        let got = StatsResponse::decode(&f).unwrap();
        assert_eq!(got.revision, 9);
        assert_eq!(got.json, "{}");
    }

    #[test]
    fn stats_frames_reject_truncation_garbage_and_wrong_kind() {
        let req = StatsRequest { prefix: "abc".to_string(), flags: 1 }.encode();
        // Every non-empty strict prefix of the payload must error (the
        // empty payload is the documented minimal-probe form).
        for cut in 1..req.payload.len() {
            let t = Frame { kind: req.kind, payload: req.payload[..cut].to_vec() };
            assert!(StatsRequest::decode(&t).is_err(), "request cut={cut}");
        }
        let resp = StatsResponse { revision: 1, json: "{\"k\":1}".to_string() }.encode();
        for cut in 0..resp.payload.len() {
            let t = Frame { kind: resp.kind, payload: resp.payload[..cut].to_vec() };
            assert!(StatsResponse::decode(&t).is_err(), "response cut={cut}");
        }

        // A string length claiming more bytes than the payload holds
        // must fail before allocating.
        let mut p = Vec::new();
        p.write_u32::<LE>(STATS_REVISION).unwrap();
        p.write_u32::<LE>(u32::MAX).unwrap();
        p.extend_from_slice(b"tiny");
        let f = Frame { kind: Kind::StatsResponse, payload: p };
        assert!(StatsResponse::decode(&f).is_err());

        // Non-UTF8 string bytes are garbage, not a panic.
        let mut p = Vec::new();
        p.write_u32::<LE>(STATS_REVISION).unwrap();
        p.write_u32::<LE>(2).unwrap();
        p.extend_from_slice(&[0xff, 0xfe]);
        let f = Frame { kind: Kind::StatsResponse, payload: p };
        assert!(StatsResponse::decode(&f).is_err());

        let wrong = Frame { kind: Kind::Shutdown, payload: req.payload };
        assert!(StatsRequest::decode(&wrong).is_err());
    }

    #[test]
    fn pre_stats_peer_interop_is_pinned() {
        // A peer built before the stats plane rejects kind 14/15 at the
        // framing layer (unknown kind => connection error), which is the
        // documented old-peer behavior: stats probes use a dedicated
        // connection precisely so this close is harmless. Pin the
        // guardrail by checking the next unassigned kind still errors —
        // the same code path an old peer takes for 14.
        assert!(Kind::from_u32(16).is_err());
        assert!(Kind::from_u32(0).is_err());

        // And a new coordinator never confuses a stats frame with the
        // frames an old peer does know.
        let f = StatsRequest::default().encode();
        assert!(Backpressure::decode(&f).is_err());
        assert!(NodeError::decode(&f).is_err());
    }
}
