//! Wire protocol between the coordinator and memory nodes.
//!
//! Frames are length-prefixed little-endian binary:
//!   u32 magic | u32 kind | u64 payload_len | payload
//! Payload encodings are fixed-layout (no self-describing overhead —
//! the hot path moves f32/u32 arrays).

use std::io::{Read, Write};

use anyhow::{bail, Result};
use byteorder::{LittleEndian as LE, ReadBytesExt, WriteBytesExt};

pub const MAGIC: u32 = 0xC4A3_1E0F;

/// Frame kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    ScanRequest = 1,
    ScanResponse = 2,
    Shutdown = 3,
    /// GPU -> coordinator: retrieve neighbors + tokens for a query vector
    /// (paper workflow step 3).
    RetrieveRequest = 4,
    /// Coordinator -> GPU: neighbor tokens + distances (step 9).
    RetrieveResponse = 5,
}

impl Kind {
    fn from_u32(x: u32) -> Result<Kind> {
        Ok(match x {
            1 => Kind::ScanRequest,
            2 => Kind::ScanResponse,
            3 => Kind::Shutdown,
            4 => Kind::RetrieveRequest,
            5 => Kind::RetrieveResponse,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// A raw frame.
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: Kind,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_u32::<LE>(MAGIC)?;
        w.write_u32::<LE>(self.kind as u32)?;
        w.write_u64::<LE>(self.payload.len() as u64)?;
        w.write_all(&self.payload)?;
        w.flush()?;
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let magic = r.read_u32::<LE>()?;
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let kind = Kind::from_u32(r.read_u32::<LE>()?)?;
        let len = r.read_u64::<LE>()? as usize;
        if len > 1 << 30 {
            bail!("frame too large: {len}");
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        Ok(Frame { kind, payload })
    }
}

/// A scan request: query vector + probed list ids (paper step 4/5).
#[derive(Clone, Debug, PartialEq)]
pub struct ScanRequest {
    pub query_id: u64,
    pub query: Vec<f32>,
    pub lists: Vec<u32>,
    pub k: u32,
}

impl ScanRequest {
    pub fn encode(&self) -> Frame {
        let mut p = Vec::with_capacity(24 + 4 * self.query.len() + 4 * self.lists.len());
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.k).unwrap();
        p.write_u32::<LE>(self.query.len() as u32).unwrap();
        p.write_u32::<LE>(self.lists.len() as u32).unwrap();
        for &x in &self.query {
            p.write_f32::<LE>(x).unwrap();
        }
        for &l in &self.lists {
            p.write_u32::<LE>(l).unwrap();
        }
        Frame { kind: Kind::ScanRequest, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<ScanRequest> {
        if f.kind != Kind::ScanRequest {
            bail!("not a scan request");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let k = r.read_u32::<LE>()?;
        let qn = r.read_u32::<LE>()? as usize;
        let ln = r.read_u32::<LE>()? as usize;
        let mut query = Vec::with_capacity(qn);
        for _ in 0..qn {
            query.push(r.read_f32::<LE>()?);
        }
        let mut lists = Vec::with_capacity(ln);
        for _ in 0..ln {
            lists.push(r.read_u32::<LE>()?);
        }
        Ok(ScanRequest { query_id, query, lists, k })
    }
}

/// A scan response: the node's local top-K (paper step 7).
#[derive(Clone, Debug, PartialEq)]
pub struct ScanResponse {
    pub query_id: u64,
    pub node_id: u32,
    pub dists: Vec<f32>,
    pub ids: Vec<u64>,
    /// Node-side modeled accelerator seconds (for latency accounting).
    pub modeled_s: f64,
}

impl ScanResponse {
    pub fn encode(&self) -> Frame {
        assert_eq!(self.dists.len(), self.ids.len());
        let mut p = Vec::with_capacity(28 + 12 * self.ids.len());
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.node_id).unwrap();
        p.write_f64::<LE>(self.modeled_s).unwrap();
        p.write_u32::<LE>(self.ids.len() as u32).unwrap();
        for &d in &self.dists {
            p.write_f32::<LE>(d).unwrap();
        }
        for &i in &self.ids {
            p.write_u64::<LE>(i).unwrap();
        }
        Frame { kind: Kind::ScanResponse, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<ScanResponse> {
        if f.kind != Kind::ScanResponse {
            bail!("not a scan response");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let node_id = r.read_u32::<LE>()?;
        let modeled_s = r.read_f64::<LE>()?;
        let n = r.read_u32::<LE>()? as usize;
        let mut dists = Vec::with_capacity(n);
        for _ in 0..n {
            dists.push(r.read_f32::<LE>()?);
        }
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(r.read_u64::<LE>()?);
        }
        Ok(ScanResponse { query_id, node_id, dists, ids, modeled_s })
    }
}

/// GPU-side retrieval request: the raw query vector plus the list ids the
/// colocated index scan selected (the coordinator "records the
/// association between queries and GPU IDs", Sec 3 step 3/4).
#[derive(Clone, Debug, PartialEq)]
pub struct RetrieveRequest {
    pub query_id: u64,
    pub gpu_id: u32,
    pub query: Vec<f32>,
    pub lists: Vec<u32>,
    pub k: u32,
    /// True for EncDec models: respond with chunk tokens, not next-tokens.
    pub want_chunks: bool,
}

impl RetrieveRequest {
    pub fn encode(&self) -> Frame {
        let mut p = Vec::new();
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.gpu_id).unwrap();
        p.write_u32::<LE>(self.k).unwrap();
        p.write_u32::<LE>(u32::from(self.want_chunks)).unwrap();
        p.write_u32::<LE>(self.query.len() as u32).unwrap();
        p.write_u32::<LE>(self.lists.len() as u32).unwrap();
        for &x in &self.query {
            p.write_f32::<LE>(x).unwrap();
        }
        for &l in &self.lists {
            p.write_u32::<LE>(l).unwrap();
        }
        Frame { kind: Kind::RetrieveRequest, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<RetrieveRequest> {
        if f.kind != Kind::RetrieveRequest {
            bail!("not a retrieve request");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let gpu_id = r.read_u32::<LE>()?;
        let k = r.read_u32::<LE>()?;
        let want_chunks = r.read_u32::<LE>()? != 0;
        let qn = r.read_u32::<LE>()? as usize;
        let ln = r.read_u32::<LE>()? as usize;
        let mut query = Vec::with_capacity(qn);
        for _ in 0..qn {
            query.push(r.read_f32::<LE>()?);
        }
        let mut lists = Vec::with_capacity(ln);
        for _ in 0..ln {
            lists.push(r.read_u32::<LE>()?);
        }
        Ok(RetrieveRequest { query_id, gpu_id, query, lists, k, want_chunks })
    }
}

/// Coordinator reply: retrieved token payload + distances.
#[derive(Clone, Debug, PartialEq)]
pub struct RetrieveResponse {
    pub query_id: u64,
    /// Next-tokens of the K neighbors (decoder-only) or concatenated
    /// chunk tokens (EncDec, K*chunk_len long).
    pub tokens: Vec<u32>,
    pub dists: Vec<f32>,
}

impl RetrieveResponse {
    pub fn encode(&self) -> Frame {
        let mut p = Vec::new();
        p.write_u64::<LE>(self.query_id).unwrap();
        p.write_u32::<LE>(self.tokens.len() as u32).unwrap();
        p.write_u32::<LE>(self.dists.len() as u32).unwrap();
        for &t in &self.tokens {
            p.write_u32::<LE>(t).unwrap();
        }
        for &d in &self.dists {
            p.write_f32::<LE>(d).unwrap();
        }
        Frame { kind: Kind::RetrieveResponse, payload: p }
    }

    pub fn decode(f: &Frame) -> Result<RetrieveResponse> {
        if f.kind != Kind::RetrieveResponse {
            bail!("not a retrieve response");
        }
        let mut r = &f.payload[..];
        let query_id = r.read_u64::<LE>()?;
        let tn = r.read_u32::<LE>()? as usize;
        let dn = r.read_u32::<LE>()? as usize;
        let mut tokens = Vec::with_capacity(tn);
        for _ in 0..tn {
            tokens.push(r.read_u32::<LE>()?);
        }
        let mut dists = Vec::with_capacity(dn);
        for _ in 0..dn {
            dists.push(r.read_f32::<LE>()?);
        }
        Ok(RetrieveResponse { query_id, tokens, dists })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retrieve_request_roundtrip() {
        let req = RetrieveRequest {
            query_id: 5,
            gpu_id: 2,
            query: vec![0.5, -1.0],
            lists: vec![3, 1],
            k: 10,
            want_chunks: true,
        };
        let mut buf = Vec::new();
        req.encode().write_to(&mut buf).unwrap();
        let back = Frame::read_from(&mut &buf[..]).unwrap();
        assert_eq!(RetrieveRequest::decode(&back).unwrap(), req);
    }

    #[test]
    fn retrieve_response_roundtrip() {
        let resp = RetrieveResponse {
            query_id: 5,
            tokens: vec![10, 20, 30],
            dists: vec![0.1, 0.2, 0.3],
        };
        let mut buf = Vec::new();
        resp.encode().write_to(&mut buf).unwrap();
        let back = Frame::read_from(&mut &buf[..]).unwrap();
        assert_eq!(RetrieveResponse::decode(&back).unwrap(), resp);
    }

    #[test]
    fn request_roundtrip() {
        let req = ScanRequest {
            query_id: 42,
            query: vec![1.0, -2.5, 3.25],
            lists: vec![7, 9, 11],
            k: 10,
        };
        let frame = req.encode();
        let mut buf = Vec::new();
        frame.write_to(&mut buf).unwrap();
        let back = Frame::read_from(&mut &buf[..]).unwrap();
        assert_eq!(ScanRequest::decode(&back).unwrap(), req);
    }

    #[test]
    fn response_roundtrip() {
        let resp = ScanResponse {
            query_id: 1,
            node_id: 3,
            dists: vec![0.5, 1.5],
            ids: vec![100, 200],
            modeled_s: 1.25e-3,
        };
        let frame = resp.encode();
        let mut buf = Vec::new();
        frame.write_to(&mut buf).unwrap();
        let back = Frame::read_from(&mut &buf[..]).unwrap();
        assert_eq!(ScanResponse::decode(&back).unwrap(), resp);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = vec![0u8; 16];
        assert!(Frame::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_wrong_kind() {
        let req = ScanRequest { query_id: 0, query: vec![], lists: vec![], k: 1 };
        let f = req.encode();
        assert!(ScanResponse::decode(&f).is_err());
    }

    #[test]
    fn shutdown_frame_roundtrip() {
        let f = Frame { kind: Kind::Shutdown, payload: vec![] };
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let back = Frame::read_from(&mut &buf[..]).unwrap();
        assert_eq!(back.kind, Kind::Shutdown);
    }
}
