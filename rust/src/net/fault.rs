//! Deterministic network fault injection: a seeded schedule of byte-level
//! mutilations (bit flips, truncation, mid-frame disconnects, stalls)
//! applied to a stream, plus a chaos proxy that interposes the schedule
//! between a real client and a real server over loopback TCP.
//!
//! Everything is driven by an explicit seed — the same seed replays the
//! same faults at the same byte offsets, so a chaos run that finds a bug
//! is a reproducer, not an anecdote. This is the network-layer twin of
//! `cluster::fault`'s in-process `FailingBackend`/`StragglerBackend`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::rng::Rng;

/// One injected fault, anchored at an absolute byte offset of the faulted
/// direction's stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// XOR `mask` into the byte at offset `at` (a wire bit flip).
    Flip { at: u64, mask: u8 },
    /// Kill the stream after `at` bytes — mid-frame with high probability,
    /// which is exactly the desync case `FrameReader` must survive.
    Cut { at: u64 },
    /// Pause delivery for `ms` milliseconds once offset `at` passes (a
    /// stalled peer: the reader sees a silent connection, not an error).
    Stall { at: u64, ms: u64 },
}

impl Fault {
    fn at(&self) -> u64 {
        match *self {
            Fault::Flip { at, .. } | Fault::Cut { at } | Fault::Stall { at, .. } => at,
        }
    }
}

/// How many faults of each kind a seeded schedule draws, and the byte
/// window they land in.
#[derive(Clone, Copy, Debug)]
pub struct FaultProfile {
    pub flips: usize,
    pub cuts: usize,
    pub stalls: usize,
    /// Fault offsets are drawn uniformly from [0, window_bytes).
    pub window_bytes: u64,
    /// Stall duration per `Stall` fault.
    pub stall_ms: u64,
}

impl Default for FaultProfile {
    fn default() -> FaultProfile {
        FaultProfile { flips: 3, cuts: 1, stalls: 1, window_bytes: 1 << 16, stall_ms: 20 }
    }
}

/// A deterministic, seed-derived fault schedule over one stream
/// direction. Faults are applied in offset order; a `Cut` ends the
/// stream, so faults scheduled after one never fire.
#[derive(Clone, Debug, Default)]
pub struct FaultSchedule {
    /// Sorted by offset.
    faults: Vec<Fault>,
    /// Index of the next un-applied fault.
    next: usize,
}

impl FaultSchedule {
    /// No faults: the wrapper becomes a transparent passthrough.
    pub fn none() -> FaultSchedule {
        FaultSchedule::default()
    }

    /// Draw a schedule from a seed. Same (seed, profile) -> same faults.
    pub fn from_seed(seed: u64, profile: &FaultProfile) -> FaultSchedule {
        let mut rng = Rng::new(seed);
        let window = profile.window_bytes.max(1);
        let mut faults = Vec::new();
        for _ in 0..profile.flips {
            faults.push(Fault::Flip {
                at: rng.next_u64() % window,
                mask: 1 << rng.below(8) as u8,
            });
        }
        for _ in 0..profile.stalls {
            faults.push(Fault::Stall { at: rng.next_u64() % window, ms: profile.stall_ms });
        }
        for _ in 0..profile.cuts {
            faults.push(Fault::Cut { at: rng.next_u64() % window });
        }
        FaultSchedule::sorted(faults)
    }

    /// An explicit fault list (tests pin exact offsets).
    pub fn of(faults: Vec<Fault>) -> FaultSchedule {
        FaultSchedule::sorted(faults)
    }

    fn sorted(mut faults: Vec<Fault>) -> FaultSchedule {
        faults.sort_by_key(Fault::at);
        FaultSchedule { faults, next: 0 }
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Mutilate `buf`, which carries stream bytes [offset, offset+len).
    /// Returns the number of bytes to deliver (shortened by a `Cut`) and
    /// whether the stream dies after delivering them.
    fn apply(&mut self, offset: u64, buf: &mut [u8]) -> (usize, bool) {
        let mut deliver = buf.len();
        let mut cut = false;
        while self.next < self.faults.len() {
            let f = self.faults[self.next];
            if f.at() >= offset + deliver as u64 {
                break;
            }
            self.next += 1;
            let rel = (f.at() - offset) as usize;
            match f {
                Fault::Flip { mask, .. } => buf[rel] ^= mask,
                Fault::Stall { ms, .. } => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Fault::Cut { .. } => {
                    deliver = rel;
                    cut = true;
                    break;
                }
            }
        }
        (deliver, cut)
    }
}

/// A `Read + Write` wrapper that applies a [`FaultSchedule`] to the bytes
/// *read* from the inner stream (the direction a coordinator observes a
/// memory node through). Writes pass through untouched — faulting one
/// direction keeps a test's cause/effect attributable.
pub struct FaultyStream<S> {
    inner: S,
    schedule: FaultSchedule,
    offset: u64,
    dead: bool,
}

impl<S> FaultyStream<S> {
    pub fn new(inner: S, schedule: FaultSchedule) -> FaultyStream<S> {
        FaultyStream { inner, schedule, offset: 0, dead: false }
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.dead {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "injected fault: connection cut",
            ));
        }
        let n = self.inner.read(buf)?;
        if n == 0 {
            return Ok(0);
        }
        let (deliver, cut) = self.schedule.apply(self.offset, &mut buf[..n]);
        self.offset += n as u64;
        if cut {
            self.dead = true;
            if deliver == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "injected fault: connection cut",
                ));
            }
        }
        Ok(if cut { deliver } else { n })
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.inner.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// A fault-injecting TCP proxy: accepts client connections, connects to
/// `upstream` for each, and pumps bytes both ways — applying a per-
/// connection seeded [`FaultSchedule`] to the upstream->client direction
/// (the replies a coordinator reads from a memory node). Connection `i`
/// uses schedule seed `seed + i`, so a multi-connection chaos run is
/// still a deterministic function of one seed.
///
/// [`blackout`](Self::blackout) models a node vanishing: live pumps are
/// killed and new connections are refused until the window passes, after
/// which the node is reachable again — the recovery path self-healing
/// clients and half-open probation must handle.
pub struct ChaosProxy {
    pub addr: SocketAddr,
    upstream: SocketAddr,
    stop: Arc<AtomicBool>,
    /// Monotonic ns timestamp (from `epoch`) the blackout ends at; 0 = none.
    blackout_until: Arc<AtomicU64>,
    epoch: Instant,
    accept_handle: Option<JoinHandle<()>>,
    /// Connections accepted so far (diagnostics + per-conn seeds).
    conns: Arc<AtomicU64>,
}

impl ChaosProxy {
    pub fn spawn(
        upstream: SocketAddr,
        seed: u64,
        profile: FaultProfile,
    ) -> Result<ChaosProxy> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding chaos proxy")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let blackout_until = Arc::new(AtomicU64::new(0));
        let conns = Arc::new(AtomicU64::new(0));
        let epoch = Instant::now();
        let accept_handle = {
            let stop = Arc::clone(&stop);
            let blackout_until = Arc::clone(&blackout_until);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((client, _)) => {
                            let now_ns = epoch.elapsed().as_nanos() as u64;
                            if now_ns < blackout_until.load(Ordering::Relaxed) {
                                drop(client); // refused: the node is "down"
                                continue;
                            }
                            let i = conns.fetch_add(1, Ordering::Relaxed);
                            let schedule = FaultSchedule::from_seed(
                                seed.wrapping_add(i),
                                &profile,
                            );
                            let stop = Arc::clone(&stop);
                            let blackout_until = Arc::clone(&blackout_until);
                            std::thread::spawn(move || {
                                let _ = pump_conn(
                                    client,
                                    upstream,
                                    schedule,
                                    stop,
                                    blackout_until,
                                    epoch,
                                );
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
        };
        Ok(ChaosProxy {
            addr,
            upstream,
            stop,
            blackout_until,
            epoch,
            accept_handle: Some(accept_handle),
            conns,
        })
    }

    /// The proxied upstream address.
    pub fn upstream(&self) -> SocketAddr {
        self.upstream
    }

    /// Connections accepted so far.
    pub fn connections(&self) -> u64 {
        self.conns.load(Ordering::Relaxed)
    }

    /// Kill every live proxied connection and refuse new ones for `dur` —
    /// the node disappears, then comes back.
    pub fn blackout(&self, dur: Duration) {
        let until = (self.epoch.elapsed() + dur).as_nanos() as u64;
        self.blackout_until.store(until, Ordering::Relaxed);
    }

    /// Whether a blackout window is currently in force.
    pub fn blacked_out(&self) -> bool {
        (self.epoch.elapsed().as_nanos() as u64)
            < self.blackout_until.load(Ordering::Relaxed)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Pump one proxied connection: client->upstream verbatim on a side
/// thread, upstream->client through the fault schedule on this one.
/// Either direction dying (or a blackout window opening) tears the pair
/// down, like a real half-dead TCP connection eventually does.
fn pump_conn(
    client: TcpStream,
    upstream: SocketAddr,
    schedule: FaultSchedule,
    stop: Arc<AtomicBool>,
    blackout_until: Arc<AtomicU64>,
    epoch: Instant,
) -> Result<()> {
    let server = TcpStream::connect_timeout(&upstream, Duration::from_secs(2))
        .context("chaos proxy connecting upstream")?;
    client.set_nodelay(true).ok();
    server.set_nodelay(true).ok();
    // Short read timeouts keep both pumps responsive to stop/blackout.
    let tick = Some(Duration::from_millis(20));
    client.set_read_timeout(tick)?;
    server.set_read_timeout(tick)?;

    let c2s = {
        let mut from = client.try_clone()?;
        let mut to = server.try_clone()?;
        let stop = Arc::clone(&stop);
        let blackout_until = Arc::clone(&blackout_until);
        std::thread::spawn(move || {
            let _ = copy_until(&mut from, &mut to, &stop, &blackout_until, epoch, None);
            // Dying half-closes the pair so the other pump unblocks.
            let _ = to.shutdown(std::net::Shutdown::Both);
        })
    };
    let mut from = server.try_clone()?;
    let mut to = client.try_clone()?;
    let _ = copy_until(
        &mut from,
        &mut to,
        &stop,
        &blackout_until,
        epoch,
        Some(schedule),
    );
    let _ = client.shutdown(std::net::Shutdown::Both);
    let _ = server.shutdown(std::net::Shutdown::Both);
    let _ = c2s.join();
    Ok(())
}

fn copy_until(
    from: &mut TcpStream,
    to: &mut TcpStream,
    stop: &AtomicBool,
    blackout_until: &AtomicU64,
    epoch: Instant,
    schedule: Option<FaultSchedule>,
) -> Result<()> {
    let mut faulty = schedule.map(|s| (s, 0u64, false));
    let mut buf = [0u8; 4096];
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        if (epoch.elapsed().as_nanos() as u64) < blackout_until.load(Ordering::Relaxed)
        {
            anyhow::bail!("blackout: connection killed");
        }
        match from.read(&mut buf) {
            Ok(0) => return Ok(()),
            Ok(n) => {
                let deliver = match faulty.as_mut() {
                    Some((schedule, offset, dead)) => {
                        if *dead {
                            anyhow::bail!("injected fault: connection cut");
                        }
                        let (d, cut) = schedule.apply(*offset, &mut buf[..n]);
                        *offset += n as u64;
                        if cut {
                            *dead = true;
                        }
                        if d > 0 {
                            to.write_all(&buf[..d])?;
                        }
                        if cut {
                            anyhow::bail!("injected fault: connection cut");
                        }
                        continue;
                    }
                    None => n,
                };
                to.write_all(&buf[..deliver])?;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::protocol::{Frame, FrameReader, Kind, ReadProgress, ScanRequest};

    fn sample_frame() -> Frame {
        ScanRequest {
            query_id: 9,
            query: vec![1.0, 2.0, 3.0],
            lists: vec![4, 5],
            k: 7,
        }
        .encode()
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let p = FaultProfile::default();
        let a = FaultSchedule::from_seed(11, &p);
        let b = FaultSchedule::from_seed(11, &p);
        let c = FaultSchedule::from_seed(12, &p);
        assert_eq!(a.faults(), b.faults());
        assert_ne!(a.faults(), c.faults());
        assert_eq!(a.faults().len(), p.flips + p.cuts + p.stalls);
    }

    #[test]
    fn flip_corrupts_exactly_one_byte() {
        let mut wire = Vec::new();
        sample_frame().write_to(&mut wire).unwrap();
        let want = wire.clone();
        let schedule =
            FaultSchedule::of(vec![Fault::Flip { at: 20, mask: 0x40 }]);
        let mut s = FaultyStream::new(&want[..], schedule);
        let mut got = Vec::new();
        s.read_to_end(&mut got).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            if i == 20 {
                assert_eq!(*g, *w ^ 0x40);
            } else {
                assert_eq!(g, w, "byte {i} changed");
            }
        }
    }

    #[test]
    fn cut_truncates_then_kills() {
        let bytes = vec![7u8; 100];
        let schedule = FaultSchedule::of(vec![Fault::Cut { at: 33 }]);
        let mut s = FaultyStream::new(&bytes[..], schedule);
        let mut got = Vec::new();
        let err = s.read_to_end(&mut got).unwrap_err();
        assert_eq!(got.len(), 33);
        assert_eq!(err.kind(), std::io::ErrorKind::ConnectionReset);
    }

    #[test]
    fn flipped_frame_fails_checksum_but_clean_frames_pass() {
        // Two checksummed frames; a flip inside the first frame's payload
        // must error at the reader, while an un-faulted stream delivers
        // both intact — detection, not silent merge.
        let f = sample_frame();
        let mut wire = Vec::new();
        f.write_to_checksummed(&mut wire).unwrap();
        f.write_to_checksummed(&mut wire).unwrap();

        let schedule = FaultSchedule::of(vec![Fault::Flip {
            at: super::super::protocol::FRAME_HEADER_BYTES as u64 + 2,
            mask: 0x08,
        }]);
        let mut s = FaultyStream::new(&wire[..], schedule);
        let mut fr = FrameReader::new();
        fr.set_checksums(true);
        let err = loop {
            match fr.poll(&mut s) {
                Ok(ReadProgress::Idle) => continue,
                Ok(ReadProgress::Frame(_)) => panic!("corrupt frame delivered"),
                Ok(ReadProgress::Closed) => panic!("closed without detecting"),
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("checksum"), "{err}");

        let mut s = FaultyStream::new(&wire[..], FaultSchedule::none());
        let mut fr = FrameReader::new();
        fr.set_checksums(true);
        let mut n = 0;
        loop {
            match fr.poll(&mut s).unwrap() {
                ReadProgress::Frame(g) => {
                    assert_eq!(g.kind, Kind::ScanRequest);
                    assert_eq!(g.payload, f.payload);
                    n += 1;
                }
                ReadProgress::Idle => continue,
                ReadProgress::Closed => break,
            }
        }
        assert_eq!(n, 2);
    }

    #[test]
    fn proxy_passes_clean_traffic_and_blackout_refuses() {
        // Upstream: a trivial echo server.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut conn) = conn else { break };
                let mut buf = [0u8; 256];
                loop {
                    match conn.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if conn.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                break; // serve one connection; the test only needs one
            }
        });

        let profile = FaultProfile { flips: 0, cuts: 0, stalls: 0, ..Default::default() };
        let mut proxy = ChaosProxy::spawn(upstream, 5, profile).unwrap();

        let mut c = TcpStream::connect(proxy.addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        c.write_all(b"ping").unwrap();
        let mut back = [0u8; 4];
        c.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"ping");

        // Blackout: the live connection dies and new ones are refused.
        proxy.blackout(Duration::from_millis(150));
        assert!(proxy.blacked_out());
        let dead = (|| -> std::io::Result<()> {
            c.write_all(b"stale")?;
            let mut b = [0u8; 5];
            c.read_exact(&mut b)?;
            Ok(())
        })()
        .is_err();
        assert!(dead, "blackout must kill the live proxied connection");
        std::thread::sleep(Duration::from_millis(200));
        assert!(!proxy.blacked_out());

        proxy.stop();
        drop(c);
        let _ = echo.join();
    }
}
