//! The memory-node server: listens for scan requests, runs them on its
//! [`MemoryNode`], and replies with the local top-K (the software shape of
//! the paper's FPGA node with its hardware TCP/IP stack).
//!
//! PJRT handles are not `Send` (the xla crate wraps `Rc` internals), so
//! the node is *built inside* the server thread via a builder closure and
//! connections are served sequentially on that thread — matching the
//! paper's single accelerator pipeline per node, which also processes one
//! scan at a time.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::protocol::{Frame, Kind, ScanRequest, ScanResponse};
use crate::chamvs::dispatcher::build_lut_from_raw;
use crate::chamvs::node::MemoryNode;

/// A running memory-node server.
pub struct NodeServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl NodeServer {
    /// Spawn a server on an ephemeral local port. The node is constructed
    /// by `builder` on the server thread; `codebook` is the raw
    /// (m, 256, dsub) PQ centroid tensor shared with the coordinator.
    pub fn spawn_with(
        builder: impl FnOnce() -> MemoryNode + Send + 'static,
        codebook: Vec<f32>,
        nprobe: usize,
    ) -> Result<NodeServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut node = builder();
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let _ =
                            serve_conn(stream, &mut node, &codebook, nprobe, &stop2);
                        if stop2.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(NodeServer { addr, stop, handle: Some(handle) })
    }

    /// Request shutdown (any in-flight connection finishes its frame).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Nudge the accept loop.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    stream: TcpStream,
    node: &mut MemoryNode,
    codebook: &[f32],
    nprobe: usize,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // Poll the stop flag between frames so shutdown() can join even while
    // a client connection sits idle.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match Frame::read_from(&mut reader) {
            Ok(f) => f,
            Err(e) => {
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out {
                    continue;
                }
                return Ok(()); // peer closed / protocol error
            }
        };
        match frame.kind {
            Kind::Shutdown => {
                stop.store(true, Ordering::Relaxed);
                return Ok(());
            }
            Kind::ScanRequest => {
                let req = ScanRequest::decode(&frame)?;
                let m = node.shard.m;
                let dsub = req.query.len() / m;
                // Defensive: drop list ids outside this shard (a buggy or
                // malicious coordinator must not kill the node).
                let nlist = node.shard.list_codes.len() as u32;
                let lists: Vec<u32> =
                    req.lists.iter().copied().filter(|&l| l < nlist).collect();
                let lut = build_lut_from_raw(codebook, &req.query, m, dsub);
                let r = node.scan(&lut, &req.query, codebook, &lists, nprobe)?;
                let resp = ScanResponse {
                    query_id: req.query_id,
                    node_id: node.shard.node_id as u32,
                    dists: r.topk.iter().map(|&(d, _)| d).collect(),
                    ids: r.topk.iter().map(|&(_, i)| i).collect(),
                    modeled_s: r.modeled_s,
                };
                resp.encode().write_to(&mut writer)?;
            }
            other => anyhow::bail!("unexpected frame {other:?} at memory node"),
        }
    }
}
