//! The memory-node server: listens for scan requests, runs them on its
//! [`MemoryNode`], and replies with the local top-K (the software shape of
//! the paper's FPGA node with its hardware TCP/IP stack).
//!
//! Each accepted connection starts with a [`Hello`] handshake (node id +
//! PQ geometry + shard placement), then serves [`ScanRequest`] and
//! [`BatchScanRequest`] frames. Scans execute through the same
//! [`ScanBackend`] round path the in-process dispatcher uses, so local
//! and networked nodes run identical code — a batch frame is one round of
//! jobs, scanned node-major and answered in one response frame. A `Drain`
//! frame retires the node gracefully: in-flight traffic finishes, no new
//! connections are accepted, and the process exits once the draining
//! connection closes — the node-side half of the cluster's live
//! membership transitions.
//!
//! PJRT handles are not `Send` (the xla crate wraps `Rc` internals), so
//! the node is *built inside* the server thread via a builder closure and
//! connections are served sequentially on that thread — matching the
//! paper's single accelerator pipeline per node, which also processes one
//! scan at a time.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::protocol::{
    BatchScanRequest, BatchScanResponse, Frame, FrameReader, Hello, Kind, NodeError,
    ReadProgress, ScanRequest, ScanResponse, HELLO_CAP_CHECKSUMS,
};
use crate::chamvs::backend::{ScanBackend, ScanJob};
use crate::chamvs::node::MemoryNode;
use crate::pq::codebook::KSUB;
use crate::pq::scan::build_lut_raw_into;

/// A running memory-node server.
pub struct NodeServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl NodeServer {
    /// Spawn a server on an ephemeral local port. The node is constructed
    /// by `builder` on the server thread; `codebook` is the raw
    /// (m, 256, dsub) PQ centroid tensor shared with the coordinator.
    pub fn spawn_with(
        builder: impl FnOnce() -> MemoryNode + Send + 'static,
        codebook: Vec<f32>,
        nprobe: usize,
    ) -> Result<NodeServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let draining = Arc::new(AtomicBool::new(false));
        let draining2 = draining.clone();
        let handle = std::thread::spawn(move || {
            let mut node = builder();
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let _ = serve_conn(
                            stream, &mut node, &codebook, nprobe, &stop2, &draining2,
                        );
                        // A drained node retires once the connection that
                        // drained it (or any later one) closes: no new
                        // accepts, clean exit.
                        if draining2.load(Ordering::Relaxed) {
                            stop2.store(true, Ordering::Relaxed);
                        }
                        if stop2.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(NodeServer { addr, stop, draining, handle: Some(handle) })
    }

    /// Whether a client asked this node to retire (Drain frame).
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Whether the server has been asked to stop (set by
    /// [`shutdown`](Self::shutdown) or by a client Shutdown frame) — lets
    /// the `chamvs-node` binary exit instead of parking forever.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Request shutdown (any in-flight connection finishes its frame).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Nudge the accept loop.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    mut stream: TcpStream,
    node: &mut MemoryNode,
    codebook: &[f32],
    nprobe: usize,
    stop: &AtomicBool,
    draining: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // Poll the stop flag between frames so shutdown() can join even while
    // a client connection sits idle.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    // Handshake: the client learns this node's identity, PQ geometry and
    // shard placement (`Shard::carve(index, shard, n_shards)` identity —
    // replicated nodes declare the same shard).
    Hello {
        node_id: node.shard.node_id as u32,
        m: node.shard.m as u32,
        nlist: node.shard.n_lists() as u32,
        shard: node.shard.node_id as u32,
        n_shards: node.shard.n_nodes as u32,
        flags: HELLO_CAP_CHECKSUMS,
    }
    .encode()
    .write_to(&mut writer)?;
    // Incremental decode: a stop-flag poll timeout that lands mid-frame
    // keeps the partial bytes buffered instead of desyncing the stream
    // on a slow coordinator.
    let mut frames = FrameReader::new();
    // Whether this connection negotiated checksummed framing (set once
    // the client answers our Hello with the capability flag).
    let mut checksums = false;
    // Reusable per-connection LUT arena (one (m, 256) table per request
    // of a round; steady state allocates nothing).
    let mut lut_arena: Vec<f32> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match frames.poll(&mut stream) {
            Ok(ReadProgress::Frame(f)) => f,
            Ok(ReadProgress::Idle) => continue,
            // Peer closed, or the stream itself is unframeable (bad
            // magic, oversized length, checksum mismatch): the byte
            // stream can no longer be trusted — tear down. Malformed
            // *payloads* inside a good frame are answered below instead.
            Ok(ReadProgress::Closed) | Err(_) => return Ok(()),
        };
        match frame.kind {
            Kind::Shutdown => {
                stop.store(true, Ordering::Relaxed);
                return Ok(());
            }
            Kind::Drain => {
                // Graceful retirement: keep serving this connection's
                // in-flight traffic; the accept loop stops taking new
                // connections and the process exits once this one closes.
                draining.store(true, Ordering::Relaxed);
            }
            Kind::Hello => {
                // Capability answer to our accept-time Hello: a client
                // that also speaks checksums flips the connection to
                // checksummed framing in both directions from here on.
                if let Ok(h) = Hello::decode(&frame) {
                    if h.wants_checksums() {
                        checksums = true;
                        frames.set_checksums(true);
                    }
                }
            }
            Kind::ScanRequest => match ScanRequest::decode(&frame) {
                Ok(req) => {
                    let qid = req.query_id;
                    match scan_round(node, codebook, nprobe, &[req], &mut lut_arena)
                    {
                        Ok(mut resp) => send_frame(
                            &mut writer,
                            &resp.pop().expect("one response").encode(),
                            checksums,
                        )?,
                        Err(e) => send_error(&mut writer, qid, &e, checksums)?,
                    }
                }
                Err(e) => send_error(&mut writer, 0, &e, checksums)?,
            },
            Kind::BatchScanRequest => match BatchScanRequest::decode(&frame) {
                Ok(req) => {
                    match scan_round(node, codebook, nprobe, &req.items, &mut lut_arena)
                    {
                        Ok(items) => send_frame(
                            &mut writer,
                            &BatchScanResponse {
                                node_id: node.shard.node_id as u32,
                                items,
                            }
                            .encode(),
                            checksums,
                        )?,
                        Err(e) => send_error(&mut writer, 0, &e, checksums)?,
                    }
                }
                Err(e) => send_error(&mut writer, 0, &e, checksums)?,
            },
            other => {
                // Well-framed but nonsensical: answer with an error frame
                // and keep the connection — the stream is still in sync.
                let err = anyhow::anyhow!("unexpected frame {other:?} at memory node");
                send_error(&mut writer, 0, &err, checksums)?;
            }
        }
    }
}

/// Write one frame, checksummed if this connection negotiated it.
fn send_frame(w: &mut TcpStream, frame: &Frame, checksums: bool) -> Result<()> {
    if checksums {
        frame.write_to_checksummed(w)
    } else {
        frame.write_to(w)
    }
}

/// Answer a malformed-but-framed request with a [`NodeError`] frame: the
/// coordinator learns the query failed, the connection stays alive.
fn send_error(
    w: &mut TcpStream,
    query_id: u64,
    err: &anyhow::Error,
    checksums: bool,
) -> Result<()> {
    let f = NodeError { query_id, message: format!("{err:#}") }.encode();
    send_frame(w, &f, checksums)
}

/// Execute one round of scan requests through the node's [`ScanBackend`]
/// path — the same code the in-process dispatcher runs, so networked and
/// local dispatch stay behaviorally identical.
fn scan_round(
    node: &mut MemoryNode,
    codebook: &[f32],
    nprobe: usize,
    reqs: &[ScanRequest],
    lut_arena: &mut Vec<f32>,
) -> Result<Vec<ScanResponse>> {
    let m = node.shard.m;
    let nlist = node.shard.n_lists() as u32;
    // Defensive: drop list ids outside this shard (a buggy or malicious
    // coordinator must not kill the node).
    let filtered: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| r.lists.iter().copied().filter(|&l| l < nlist).collect())
        .collect();
    // Build the round's ADC tables into the reusable arena, then the job
    // list borrowing its slices (same shape as the dispatcher's round).
    // Dim checks error the connection instead of panicking the node.
    let lut_len = m * KSUB;
    let dsub = codebook.len() / lut_len;
    lut_arena.clear();
    let t_lut = std::time::Instant::now();
    for r in reqs {
        anyhow::ensure!(
            r.query.len() == m * dsub && codebook.len() == lut_len * dsub,
            "query dim {} does not match node geometry (m={m}, dsub={dsub})",
            r.query.len()
        );
        let start = lut_arena.len();
        lut_arena.resize(start + lut_len, 0.0);
        build_lut_raw_into(codebook, &r.query, m, dsub, &mut lut_arena[start..]);
    }
    // Per-request share of the round's table-build wall, reported in the
    // response's timing tail for coordinator-side trace attribution.
    let lut_share_s = t_lut.elapsed().as_secs_f64() / reqs.len().max(1) as f64;
    let mut jobs = Vec::with_capacity(reqs.len());
    for ((r, lists), lut) in
        reqs.iter().zip(&filtered).zip(lut_arena.chunks_exact(lut_len))
    {
        jobs.push(ScanJob { query: &r.query, lists, lut, nprobe });
    }
    let results = node.scan_jobs(&jobs, codebook)?;
    Ok(reqs
        .iter()
        .zip(results)
        .map(|(r, nr)| ScanResponse {
            query_id: r.query_id,
            node_id: node.shard.node_id as u32,
            dists: nr.topk.iter().map(|&(d, _)| d).collect(),
            ids: nr.topk.iter().map(|&(_, i)| i).collect(),
            modeled_s: nr.modeled_s,
            measured_s: nr.measured_s,
            n_scanned: nr.n_scanned as u64,
            lut_s: lut_share_s,
            scan_s: nr.measured_s,
        })
        .collect())
}
