//! Coordinator-side client: broadcasts scan requests to remote memory
//! nodes and merges their responses (the networked twin of
//! `chamvs::dispatcher`).

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};

use anyhow::{Context, Result};

use super::protocol::{Frame, Kind, ScanRequest, ScanResponse};
use crate::chamvs::dispatcher::merge_topk;
use crate::chamvs::node::NodeResult;

/// Connections to a set of remote memory nodes.
pub struct NodeClient {
    conns: Vec<(SocketAddr, TcpStream, BufReader<TcpStream>)>,
    pub k: usize,
}

impl NodeClient {
    pub fn connect(addrs: &[SocketAddr], k: usize) -> Result<NodeClient> {
        let mut conns = Vec::with_capacity(addrs.len());
        for &addr in addrs {
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to memory node {addr}"))?;
            stream.set_nodelay(true)?;
            let reader = BufReader::new(stream.try_clone()?);
            conns.push((addr, stream, reader));
        }
        Ok(NodeClient { conns, k })
    }

    pub fn n_nodes(&self) -> usize {
        self.conns.len()
    }

    /// Broadcast one query and merge the per-node top-K responses.
    /// Returns (global top-K, max node modeled seconds).
    pub fn search(
        &mut self,
        query_id: u64,
        query: &[f32],
        lists: &[u32],
    ) -> Result<(Vec<(f32, u64)>, f64)> {
        let req = ScanRequest {
            query_id,
            query: query.to_vec(),
            lists: lists.to_vec(),
            k: self.k as u32,
        };
        let frame = req.encode();
        // Broadcast phase (paper step 5).
        for (_, stream, _) in &mut self.conns {
            frame.write_to(stream)?;
        }
        // Gather phase (paper step 7) — responses arrive in node order on
        // each dedicated connection.
        let mut results = Vec::with_capacity(self.conns.len());
        let mut max_modeled = 0.0f64;
        for (addr, _, reader) in &mut self.conns {
            let f = Frame::read_from(reader)
                .with_context(|| format!("reading response from {addr}"))?;
            let resp = ScanResponse::decode(&f)?;
            anyhow::ensure!(resp.query_id == query_id, "response id mismatch");
            max_modeled = max_modeled.max(resp.modeled_s);
            results.push(NodeResult {
                topk: resp
                    .dists
                    .iter()
                    .zip(&resp.ids)
                    .map(|(&d, &i)| (d, i))
                    .collect(),
                measured_s: 0.0,
                modeled_s: resp.modeled_s,
                n_scanned: 0,
            });
        }
        Ok((merge_topk(&results, self.k), max_modeled))
    }

    /// Ask all nodes to shut down.
    pub fn shutdown_nodes(&mut self) {
        let f = Frame { kind: Kind::Shutdown, payload: vec![] };
        for (_, stream, _) in &mut self.conns {
            let _ = f.write_to(stream);
        }
    }
}
