//! Coordinator-side remote-node backend: one [`RemoteNode`] per memory
//! node connection, implementing [`ScanBackend`] so the regular
//! [`Dispatcher`] fans rounds out over sockets exactly as it does over
//! in-process nodes — including batched rounds, which ship each node its
//! whole job queue in a single network round trip
//! ([`BatchScanRequest`]/[`BatchScanResponse`]).
//!
//! [`NodeClient`] is the thin convenience wrapper the examples, benches
//! and failure tests use: a dispatcher over remote backends with the
//! single-query/broadcast surface of the old networked client. The former
//! client-side copy of the top-K merge is gone — merging happens in the
//! dispatcher, once, for every backend kind.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{
    BatchScanRequest, BatchScanResponse, Frame, Hello, Kind, ScanRequest, ScanResponse,
};
use crate::chamvs::backend::{ScanBackend, ScanJob};
use crate::chamvs::dispatcher::{BatchQuery, Dispatcher, SearchResult};
use crate::chamvs::node::NodeResult;
use crate::hwmodel::fpga::FpgaModel;

/// Socket deadlines for a [`RemoteNode`] connection. A hung node used to
/// block a dispatch round forever; these deadlines are the transport
/// backstop that guarantees every exchange terminates. The defaults are
/// deliberately generous — a *replicated* tier detects stragglers much
/// earlier via the cluster engine's `attempt_timeout` and hedging, while
/// the flat (unreplicated) path has no failover to hand a slow-but-alive
/// node to, so a legitimate heavy round must not be killed by an
/// impatient socket.
#[derive(Clone, Copy, Debug)]
pub struct NetTimeouts {
    /// TCP connect deadline.
    pub connect: Duration,
    /// Per-read deadline while waiting for a scan response.
    pub read: Duration,
    /// Per-write deadline while sending a request.
    pub write: Duration,
}

impl Default for NetTimeouts {
    fn default() -> NetTimeouts {
        NetTimeouts {
            connect: Duration::from_secs(2),
            read: Duration::from_secs(30),
            write: Duration::from_secs(30),
        }
    }
}

/// A connection to one remote `chamvs-node` memory node, usable anywhere
/// the dispatcher takes a scan backend.
pub struct RemoteNode {
    addr: SocketAddr,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Node identity from the connection handshake.
    pub node_id: u32,
    m: usize,
    shard: usize,
    n_shards: usize,
    k: usize,
    timeouts: NetTimeouts,
    fpga: FpgaModel,
    next_id: u64,
    /// Set after a timeout or I/O failure mid-exchange: the stream may
    /// hold a stale half-delivered response, so every later scan on this
    /// connection fails fast instead of merging desynced frames. A
    /// poisoned node rejoins via [`reconnect`](Self::reconnect) (or a
    /// fresh connection).
    poisoned: bool,
}

impl RemoteNode {
    /// Connect with default timeouts and complete the [`Hello`] handshake
    /// (which carries the node's PQ geometry and shard identity, so no
    /// out-of-band contract is needed).
    pub fn connect(addr: SocketAddr, k: usize) -> Result<RemoteNode> {
        RemoteNode::connect_with(addr, k, NetTimeouts::default())
    }

    /// [`connect`](Self::connect) with explicit socket deadlines.
    pub fn connect_with(addr: SocketAddr, k: usize, t: NetTimeouts) -> Result<RemoteNode> {
        let stream = TcpStream::connect_timeout(&addr, t.connect)
            .with_context(|| format!("connecting to memory node {addr}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(t.read))?;
        stream.set_write_timeout(Some(t.write))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let frame = Frame::read_from(&mut reader)
            .with_context(|| format!("reading hello from {addr}"))?;
        let hello = Hello::decode(&frame)?;
        anyhow::ensure!(hello.m > 0, "node {addr} reported m=0");
        Ok(RemoteNode {
            addr,
            stream,
            reader,
            node_id: hello.node_id,
            m: hello.m as usize,
            shard: hello.shard as usize,
            n_shards: hello.n_shards.max(1) as usize,
            k,
            timeouts: t,
            fpga: FpgaModel::default(),
            next_id: 0,
            poisoned: false,
        })
    }

    /// Which shard this node declared holding a replica of.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Shard count the node's carve was taken at.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Whether an earlier failure desynced this connection.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Re-dial the node and redo the handshake, clearing the poisoned
    /// state — the recovery path for a connection a timeout desynced.
    /// Fails (leaving the node poisoned) if the node is unreachable or
    /// came back with a different geometry or shard placement.
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = RemoteNode::connect_with(self.addr, self.k, self.timeouts)?;
        anyhow::ensure!(
            fresh.m == self.m && fresh.shard == self.shard && fresh.n_shards == self.n_shards,
            "node {} changed identity across reconnect (m {}→{}, shard {}/{}→{}/{})",
            self.addr,
            self.m,
            fresh.m,
            self.shard,
            self.n_shards,
            fresh.shard,
            fresh.n_shards
        );
        *self = fresh;
        Ok(())
    }

    fn to_node_result(r: ScanResponse) -> NodeResult {
        NodeResult {
            topk: r.dists.iter().zip(&r.ids).map(|(&d, &i)| (d, i)).collect(),
            // The node's own host wall, carried in the response — the
            // networked path reports honest measured numbers.
            measured_s: r.measured_s,
            modeled_s: r.modeled_s,
            n_scanned: r.n_scanned as usize,
            // Optional timing tail: zeros from a node that predates it.
            lut_s: r.lut_s,
        }
    }

    /// One request/response exchange for a round of jobs (the fallible
    /// half [`ScanBackend::scan_jobs`] wraps with poisoning).
    fn scan_jobs_exchange(&mut self, jobs: &[ScanJob<'_>]) -> Result<Vec<NodeResult>> {
        let base = self.next_id;
        self.next_id += jobs.len() as u64;
        let k = self.k as u32;
        let request = |i: usize| ScanRequest {
            query_id: base + i as u64,
            query: jobs[i].query.to_vec(),
            lists: jobs[i].lists.to_vec(),
            k,
        };
        if jobs.len() == 1 {
            // Single-query broadcast round (paper step 5/7).
            request(0)
                .encode()
                .write_to(&mut self.stream)
                .with_context(|| format!("sending scan to {}", self.addr))?;
            let f = Frame::read_from(&mut self.reader)
                .with_context(|| format!("reading response from {}", self.addr))?;
            let resp = ScanResponse::decode(&f)?;
            anyhow::ensure!(resp.query_id == base, "scan response id mismatch");
            Ok(vec![Self::to_node_result(resp)])
        } else {
            // Batched round: the whole job queue in one round trip.
            BatchScanRequest { items: (0..jobs.len()).map(request).collect() }
                .encode()
                .write_to(&mut self.stream)
                .with_context(|| format!("sending batch scan to {}", self.addr))?;
            let f = Frame::read_from(&mut self.reader)
                .with_context(|| format!("reading batch response from {}", self.addr))?;
            let resp = BatchScanResponse::decode(&f)?;
            anyhow::ensure!(
                resp.items.len() == jobs.len(),
                "batch response arity mismatch: {} vs {}",
                resp.items.len(),
                jobs.len()
            );
            let mut out = Vec::with_capacity(jobs.len());
            for (i, item) in resp.items.into_iter().enumerate() {
                anyhow::ensure!(
                    item.query_id == base + i as u64,
                    "batch response id mismatch at {i}"
                );
                out.push(Self::to_node_result(item));
            }
            Ok(out)
        }
    }
}

impl ScanBackend for RemoteNode {
    fn m(&self) -> usize {
        self.m
    }

    fn fpga(&self) -> &FpgaModel {
        &self.fpga
    }

    /// The node server builds its own ADC table; skip the client-side one.
    fn wants_lut(&self) -> bool {
        false
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], _codebook: &[f32]) -> Result<Vec<NodeResult>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(
            !self.poisoned,
            "connection to memory node {} was poisoned by an earlier \
             timeout/failure — reconnect to rejoin it",
            self.addr
        );
        match self.scan_jobs_exchange(jobs) {
            Ok(out) => Ok(out),
            Err(e) => {
                // The stream may now carry a late or partial response
                // that would desync the next exchange: fail fast until
                // the operator reconnects (bounded failure detection for
                // the cluster engine — never a silently-wrong merge).
                self.poisoned = true;
                Err(e)
            }
        }
    }

    fn shutdown(&mut self) {
        let _ = Frame { kind: Kind::Shutdown, payload: vec![] }.write_to(&mut self.stream);
    }

    /// Ask the node process to retire: it exits once this connection
    /// closes (see the `Drain` handling in `net::server`).
    fn drain(&mut self) {
        let _ = Frame { kind: Kind::Drain, payload: vec![] }.write_to(&mut self.stream);
    }
}

/// Dispatcher-backed client over a set of remote memory nodes.
pub struct NodeClient {
    disp: Dispatcher,
}

impl NodeClient {
    pub fn connect(addrs: &[SocketAddr], k: usize) -> Result<NodeClient> {
        anyhow::ensure!(!addrs.is_empty(), "no memory node addresses");
        let mut backends: Vec<Box<dyn ScanBackend>> = Vec::with_capacity(addrs.len());
        for &addr in addrs {
            backends.push(Box::new(RemoteNode::connect(addr, k)?));
        }
        Ok(NodeClient { disp: Dispatcher::over(backends, k) })
    }

    pub fn n_nodes(&self) -> usize {
        self.disp.nodes.len()
    }

    pub fn k(&self) -> usize {
        self.disp.k
    }

    /// Broadcast one query to all nodes and merge the per-node top-Ks
    /// (one parallel dispatcher round; `measured_wall_s`/`measured_cpu_s`
    /// aggregate the nodes' own reported scan walls).
    pub fn search(&mut self, query: &[f32], lists: &[u32]) -> Result<SearchResult> {
        // Remote nodes probe with their server-side nprobe; the value here
        // only feeds the local latency attribution.
        self.disp.search(query, &[], lists, lists.len().max(1))
    }

    /// Run a whole batch in one dispatcher round: one network round trip
    /// per node carries every query.
    pub fn search_batch(&mut self, batch: &[BatchQuery]) -> Result<Vec<SearchResult>> {
        let nprobe = batch.iter().map(|b| b.lists.len()).max().unwrap_or(1).max(1);
        self.disp.search_batch(batch, &[], nprobe)
    }

    /// The underlying dispatcher (e.g. to hand to a
    /// [`Retriever`](crate::coordinator::retriever::Retriever) for fully
    /// networked serving).
    pub fn into_dispatcher(self) -> Dispatcher {
        self.disp
    }

    /// Ask all nodes to shut down.
    pub fn shutdown_nodes(&mut self) {
        for node in &mut self.disp.nodes {
            node.shutdown();
        }
    }
}
