//! Coordinator-side remote-node backend: one [`RemoteNode`] per memory
//! node connection, implementing [`ScanBackend`] so the regular
//! [`Dispatcher`] fans rounds out over sockets exactly as it does over
//! in-process nodes — including batched rounds, which ship each node its
//! whole job queue in a single network round trip
//! ([`BatchScanRequest`]/[`BatchScanResponse`]).
//!
//! [`NodeClient`] is the thin convenience wrapper the examples, benches
//! and failure tests use: a dispatcher over remote backends with the
//! single-query/broadcast surface of the old networked client. The former
//! client-side copy of the top-K merge is gone — merging happens in the
//! dispatcher, once, for every backend kind.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::protocol::{
    BatchScanRequest, BatchScanResponse, Frame, Hello, Kind, NodeError, ScanRequest,
    ScanResponse, HELLO_CAP_CHECKSUMS,
};
use crate::chamvs::backend::{ScanBackend, ScanJob};
use crate::chamvs::dispatcher::{BatchQuery, Dispatcher, SearchResult};
use crate::chamvs::node::NodeResult;
use crate::hwmodel::fpga::FpgaModel;
use crate::telemetry::{Counter, Registry};
use crate::util::rng::Rng;

/// Process-global connection-health counters. Remote nodes have no
/// per-server registry handle, so poison/heal/reconnect events land in
/// [`Registry::global`] and are merged into every coordinator scrape.
fn net_poisonings() -> &'static Counter {
    static C: std::sync::OnceLock<std::sync::Arc<Counter>> = std::sync::OnceLock::new();
    C.get_or_init(|| Registry::global().counter("net.poisonings"))
}

fn net_reconnects() -> &'static Counter {
    static C: std::sync::OnceLock<std::sync::Arc<Counter>> = std::sync::OnceLock::new();
    C.get_or_init(|| Registry::global().counter("net.reconnects"))
}

fn net_heal_failures() -> &'static Counter {
    static C: std::sync::OnceLock<std::sync::Arc<Counter>> = std::sync::OnceLock::new();
    C.get_or_init(|| Registry::global().counter("net.heal_failures"))
}

/// First reconnect-backoff step after a poisoned exchange; doubles per
/// failed heal attempt up to [`RECONNECT_CAP`], plus deterministic jitter.
const RECONNECT_BASE: Duration = Duration::from_millis(50);
/// Ceiling on the reconnect backoff.
const RECONNECT_CAP: Duration = Duration::from_secs(2);

/// A memory node answered a well-framed request with a [`NodeError`]
/// frame: the request was rejected but the stream is still in sync, so
/// the connection is NOT poisoned.
#[derive(Debug)]
pub struct NodeRejected {
    pub query_id: u64,
    pub message: String,
}

impl std::fmt::Display for NodeRejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "memory node rejected query {}: {}", self.query_id, self.message)
    }
}

impl std::error::Error for NodeRejected {}

/// Socket deadlines for a [`RemoteNode`] connection. A hung node used to
/// block a dispatch round forever; these deadlines are the transport
/// backstop that guarantees every exchange terminates. The defaults are
/// deliberately generous — a *replicated* tier detects stragglers much
/// earlier via the cluster engine's `attempt_timeout` and hedging, while
/// the flat (unreplicated) path has no failover to hand a slow-but-alive
/// node to, so a legitimate heavy round must not be killed by an
/// impatient socket.
#[derive(Clone, Copy, Debug)]
pub struct NetTimeouts {
    /// TCP connect deadline.
    pub connect: Duration,
    /// Per-read deadline while waiting for a scan response.
    pub read: Duration,
    /// Per-write deadline while sending a request.
    pub write: Duration,
}

impl Default for NetTimeouts {
    fn default() -> NetTimeouts {
        NetTimeouts {
            connect: Duration::from_secs(2),
            read: Duration::from_secs(30),
            write: Duration::from_secs(30),
        }
    }
}

/// A connection to one remote `chamvs-node` memory node, usable anywhere
/// the dispatcher takes a scan backend.
pub struct RemoteNode {
    addr: SocketAddr,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Node identity from the connection handshake.
    pub node_id: u32,
    m: usize,
    shard: usize,
    n_shards: usize,
    k: usize,
    timeouts: NetTimeouts,
    fpga: FpgaModel,
    next_id: u64,
    /// Set after a timeout or I/O failure mid-exchange: the stream may
    /// hold a stale half-delivered response, so every later scan on this
    /// connection fails fast instead of merging desynced frames. A
    /// poisoned node self-heals on the next scan once its reconnect
    /// backoff elapses (see [`try_heal`](Self::try_heal)), or rejoins
    /// immediately via an explicit [`reconnect`](Self::reconnect).
    poisoned: bool,
    /// Whether this connection negotiated checksummed framing.
    checksums: bool,
    /// Failed self-heal attempts since the connection was poisoned.
    heal_attempts: u32,
    /// Earliest instant the next self-heal attempt is allowed.
    heal_after: Option<Instant>,
    /// Seed for deterministic reconnect jitter.
    heal_seed: u64,
}

impl RemoteNode {
    /// Connect with default timeouts and complete the [`Hello`] handshake
    /// (which carries the node's PQ geometry and shard identity, so no
    /// out-of-band contract is needed).
    pub fn connect(addr: SocketAddr, k: usize) -> Result<RemoteNode> {
        RemoteNode::connect_with(addr, k, NetTimeouts::default())
    }

    /// [`connect`](Self::connect) with explicit socket deadlines.
    pub fn connect_with(addr: SocketAddr, k: usize, t: NetTimeouts) -> Result<RemoteNode> {
        let mut stream = TcpStream::connect_timeout(&addr, t.connect)
            .with_context(|| format!("connecting to memory node {addr}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(t.read))?;
        stream.set_write_timeout(Some(t.write))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let frame = Frame::read_from(&mut reader)
            .with_context(|| format!("reading hello from {addr}"))?;
        let hello = Hello::decode(&frame)?;
        anyhow::ensure!(hello.m > 0, "node {addr} reported m=0");
        // Capability negotiation: a node that advertises checksummed
        // framing gets a Hello answer carrying the same flag (the answer
        // itself is plain — Hello frames always are), after which both
        // directions append payload checksums. Old nodes never advertise,
        // so mixed fleets interop on plain framing.
        let checksums = hello.wants_checksums();
        if checksums {
            Hello { flags: HELLO_CAP_CHECKSUMS, ..hello }
                .encode()
                .write_to(&mut stream)
                .with_context(|| format!("answering hello to {addr}"))?;
        }
        Ok(RemoteNode {
            addr,
            stream,
            reader,
            node_id: hello.node_id,
            m: hello.m as usize,
            shard: hello.shard as usize,
            n_shards: hello.n_shards.max(1) as usize,
            k,
            timeouts: t,
            fpga: FpgaModel::default(),
            next_id: 0,
            poisoned: false,
            checksums,
            heal_attempts: 0,
            heal_after: None,
            heal_seed: addr.port() as u64,
        })
    }

    /// Which shard this node declared holding a replica of.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Shard count the node's carve was taken at.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Whether an earlier failure desynced this connection.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Failed self-heal attempts since this connection was poisoned.
    pub fn heal_attempts(&self) -> u32 {
        self.heal_attempts
    }

    /// How long until the next self-heal attempt is allowed (None when a
    /// heal may run immediately).
    pub fn heal_backoff_remaining(&self) -> Option<Duration> {
        let at = self.heal_after?;
        at.checked_duration_since(Instant::now())
    }

    /// Self-heal a poisoned connection: re-dial once the capped
    /// exponential backoff (with deterministic jitter) has elapsed. Inside
    /// the backoff window this fails fast without touching the network, so
    /// a dispatch round never stalls behind a dead node's dial timeout.
    /// Called automatically at the top of every scan on a poisoned node.
    pub fn try_heal(&mut self) -> Result<()> {
        if !self.poisoned {
            return Ok(());
        }
        if let Some(left) = self.heal_backoff_remaining() {
            anyhow::bail!(
                "memory node {} poisoned; reconnect backoff has {:?} left \
                 (attempt {})",
                self.addr,
                left,
                self.heal_attempts
            );
        }
        let attempt = self.heal_attempts;
        match self.reconnect() {
            // Success replaced *self with a fresh connection, which reset
            // the heal counters.
            Ok(()) => Ok(()),
            Err(e) => {
                net_heal_failures().inc();
                self.heal_attempts = attempt.saturating_add(1);
                let backoff = RECONNECT_BASE
                    .saturating_mul(1u32 << attempt.min(6))
                    .min(RECONNECT_CAP);
                // Deterministic jitter in [0, backoff/4): replicas that
                // died together don't re-dial in lockstep, and a given
                // (port, attempt) pair replays the same schedule.
                let span_us = (backoff.as_micros() as u64 / 4).max(1);
                let jitter_us = Rng::new(self.heal_seed ^ ((attempt as u64) << 32))
                    .next_u64()
                    % span_us;
                self.heal_after =
                    Some(Instant::now() + backoff + Duration::from_micros(jitter_us));
                Err(e.context(format!(
                    "self-heal attempt {attempt} for memory node {} failed",
                    self.addr
                )))
            }
        }
    }

    /// Re-dial the node and redo the handshake, clearing the poisoned
    /// state — the recovery path for a connection a timeout desynced.
    /// Fails (leaving the node poisoned) if the node is unreachable or
    /// came back with a different geometry or shard placement.
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = RemoteNode::connect_with(self.addr, self.k, self.timeouts)?;
        anyhow::ensure!(
            fresh.m == self.m && fresh.shard == self.shard && fresh.n_shards == self.n_shards,
            "node {} changed identity across reconnect (m {}→{}, shard {}/{}→{}/{})",
            self.addr,
            self.m,
            fresh.m,
            self.shard,
            self.n_shards,
            fresh.shard,
            fresh.n_shards
        );
        *self = fresh;
        net_reconnects().inc();
        Ok(())
    }

    /// Write one frame, checksummed if this connection negotiated it.
    fn send(&mut self, frame: &Frame) -> Result<()> {
        if self.checksums {
            frame.write_to_checksummed(&mut self.stream)
        } else {
            frame.write_to(&mut self.stream)
        }
    }

    /// Read one reply frame: verify/strip the checksum trailer when
    /// negotiated, and surface a [`NodeError`] frame as a typed
    /// [`NodeRejected`] error (the stream stays in sync — the caller must
    /// not poison the connection for it).
    fn read_reply(&mut self, what: &str) -> Result<Frame> {
        let mut f = Frame::read_from(&mut self.reader)
            .with_context(|| format!("reading {what} from {}", self.addr))?;
        if self.checksums {
            f.verify_strip_checksum()
                .with_context(|| format!("verifying {what} from {}", self.addr))?;
        }
        if f.kind == Kind::NodeError {
            let e = NodeError::decode(&f)?;
            return Err(anyhow::Error::new(NodeRejected {
                query_id: e.query_id,
                message: e.message,
            }));
        }
        Ok(f)
    }

    fn to_node_result(r: ScanResponse) -> NodeResult {
        NodeResult {
            topk: r.dists.iter().zip(&r.ids).map(|(&d, &i)| (d, i)).collect(),
            // The node's own host wall, carried in the response — the
            // networked path reports honest measured numbers.
            measured_s: r.measured_s,
            modeled_s: r.modeled_s,
            n_scanned: r.n_scanned as usize,
            // Optional timing tail: zeros from a node that predates it.
            lut_s: r.lut_s,
        }
    }

    /// One request/response exchange for a round of jobs (the fallible
    /// half [`ScanBackend::scan_jobs`] wraps with poisoning).
    fn scan_jobs_exchange(&mut self, jobs: &[ScanJob<'_>]) -> Result<Vec<NodeResult>> {
        let base = self.next_id;
        self.next_id += jobs.len() as u64;
        let k = self.k as u32;
        let request = |i: usize| ScanRequest {
            query_id: base + i as u64,
            query: jobs[i].query.to_vec(),
            lists: jobs[i].lists.to_vec(),
            k,
        };
        if jobs.len() == 1 {
            // Single-query broadcast round (paper step 5/7).
            let frame = request(0).encode();
            self.send(&frame)
                .with_context(|| format!("sending scan to {}", self.addr))?;
            let f = self.read_reply("response")?;
            let resp = ScanResponse::decode(&f)?;
            anyhow::ensure!(resp.query_id == base, "scan response id mismatch");
            Ok(vec![Self::to_node_result(resp)])
        } else {
            // Batched round: the whole job queue in one round trip.
            let frame =
                BatchScanRequest { items: (0..jobs.len()).map(request).collect() }
                    .encode();
            self.send(&frame)
                .with_context(|| format!("sending batch scan to {}", self.addr))?;
            let f = self.read_reply("batch response")?;
            let resp = BatchScanResponse::decode(&f)?;
            anyhow::ensure!(
                resp.items.len() == jobs.len(),
                "batch response arity mismatch: {} vs {}",
                resp.items.len(),
                jobs.len()
            );
            let mut out = Vec::with_capacity(jobs.len());
            for (i, item) in resp.items.into_iter().enumerate() {
                anyhow::ensure!(
                    item.query_id == base + i as u64,
                    "batch response id mismatch at {i}"
                );
                out.push(Self::to_node_result(item));
            }
            Ok(out)
        }
    }
}

impl ScanBackend for RemoteNode {
    fn m(&self) -> usize {
        self.m
    }

    fn fpga(&self) -> &FpgaModel {
        &self.fpga
    }

    /// The node server builds its own ADC table; skip the client-side one.
    fn wants_lut(&self) -> bool {
        false
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], _codebook: &[f32]) -> Result<Vec<NodeResult>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        // Self-heal: a poisoned connection re-dials once its backoff
        // elapses; inside the window this fails fast with no I/O.
        self.try_heal()?;
        match self.scan_jobs_exchange(jobs) {
            Ok(out) => Ok(out),
            Err(e) => {
                // A NodeError reply means the node rejected the request
                // but answered in sync — the connection is fine. Anything
                // else (timeout, I/O, checksum mismatch, decode) may have
                // left a stale half-delivered response on the stream:
                // poison it so the next scan heals instead of merging
                // desynced frames.
                if e.downcast_ref::<NodeRejected>().is_none() {
                    self.poisoned = true;
                    net_poisonings().inc();
                }
                Err(e)
            }
        }
    }

    fn shutdown(&mut self) {
        let _ = self.send(&Frame { kind: Kind::Shutdown, payload: vec![] });
    }

    /// Ask the node process to retire: it exits once this connection
    /// closes (see the `Drain` handling in `net::server`).
    fn drain(&mut self) {
        let _ = self.send(&Frame { kind: Kind::Drain, payload: vec![] });
    }
}

/// Dispatcher-backed client over a set of remote memory nodes.
pub struct NodeClient {
    disp: Dispatcher,
}

impl NodeClient {
    pub fn connect(addrs: &[SocketAddr], k: usize) -> Result<NodeClient> {
        anyhow::ensure!(!addrs.is_empty(), "no memory node addresses");
        let mut backends: Vec<Box<dyn ScanBackend>> = Vec::with_capacity(addrs.len());
        for &addr in addrs {
            backends.push(Box::new(RemoteNode::connect(addr, k)?));
        }
        Ok(NodeClient { disp: Dispatcher::over(backends, k) })
    }

    pub fn n_nodes(&self) -> usize {
        self.disp.nodes.len()
    }

    pub fn k(&self) -> usize {
        self.disp.k
    }

    /// Broadcast one query to all nodes and merge the per-node top-Ks
    /// (one parallel dispatcher round; `measured_wall_s`/`measured_cpu_s`
    /// aggregate the nodes' own reported scan walls).
    pub fn search(&mut self, query: &[f32], lists: &[u32]) -> Result<SearchResult> {
        // Remote nodes probe with their server-side nprobe; the value here
        // only feeds the local latency attribution.
        self.disp.search(query, &[], lists, lists.len().max(1))
    }

    /// Run a whole batch in one dispatcher round: one network round trip
    /// per node carries every query.
    pub fn search_batch(&mut self, batch: &[BatchQuery]) -> Result<Vec<SearchResult>> {
        let nprobe = batch.iter().map(|b| b.lists.len()).max().unwrap_or(1).max(1);
        self.disp.search_batch(batch, &[], nprobe)
    }

    /// The underlying dispatcher (e.g. to hand to a
    /// [`Retriever`](crate::coordinator::retriever::Retriever) for fully
    /// networked serving).
    pub fn into_dispatcher(self) -> Dispatcher {
        self.disp
    }

    /// Ask all nodes to shut down.
    pub fn shutdown_nodes(&mut self) {
        for node in &mut self.disp.nodes {
            node.shutdown();
        }
    }
}
