//! Disaggregation over real sockets: a length-prefixed binary protocol
//! (single-query and whole-batch scan frames, plus a node handshake), a
//! memory-node server (`chamvs-node` binary) and the coordinator-side
//! [`RemoteNode`] scan backend — the same dispatcher that drives
//! in-process nodes drives these connections. The paper's prototype uses
//! a hardware TCP/IP stack on the FPGA and socket programs on the CPU
//! (Sec 5); here both ends are std TCP with blocking I/O and one thread
//! per connection.

pub mod client;
pub mod fault;
pub mod protocol;
pub mod server;

pub use client::{NetTimeouts, NodeClient, NodeRejected, RemoteNode};
pub use fault::{ChaosProxy, Fault, FaultProfile, FaultSchedule, FaultyStream};
pub use protocol::{
    BatchScanRequest, BatchScanResponse, ClusterAck, ClusterOp, ClusterUpdate, Frame,
    Hello, NodeError, ScanRequest, ScanResponse,
};
pub use server::NodeServer;
