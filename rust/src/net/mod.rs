//! Disaggregation over real sockets: a length-prefixed binary protocol,
//! a memory-node server (`chamvs-node` binary) and the coordinator-side
//! client. The paper's prototype uses a hardware TCP/IP stack on the FPGA
//! and socket programs on the CPU (Sec 5); here both ends are std TCP
//! with blocking I/O and one thread per connection.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::NodeClient;
pub use protocol::{Frame, ScanRequest, ScanResponse};
pub use server::NodeServer;
