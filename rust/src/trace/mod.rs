//! End-to-end query tracing for the serving stack.
//!
//! Execution emits cheap events, analysis aggregates offline: the hot
//! path calls [`Tracer::record`], which stamps a [`SpanEvent`] into a
//! preallocated lock-free [`TraceRing`] (no allocation, no locks, a few
//! atomics per event — see `rust/tests/trace_alloc.rs`). After a run,
//! [`TraceRing::snapshot`] drains the ring and [`analysis`] computes
//! per-stage p50/p95/p99, critical-path attribution and
//! hedge/cache/speculation win rates (`chameleon report trace`).
//!
//! Trace ids are allocated by `coordinator::server` (0 = untraced) and
//! carried through the batcher, retriever and dispatcher; remote nodes
//! report their stage timings back over the wire via the optional
//! timing tail on `ScanResponse`/`BatchScanResponse`.

pub mod analysis;
pub mod ring;
pub mod span;

pub use analysis::{analyze, events_from_json, events_to_json, TraceAnalysis};
pub use ring::{TraceRing, Tracer};
pub use span::{SpanEvent, SpanKind};
