//! Offline trace aggregation: per-stage percentiles, critical-path
//! attribution and hedge/cache/speculation win rates over a snapshot of
//! [`SpanEvent`]s (`chameleon report trace`).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::span::{SpanEvent, SpanKind, ALL_KINDS};
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Stage kinds that make up a query's server-side critical path.
/// `NodeScan` contributes its per-trace max (nodes scan in parallel);
/// every other kind contributes the sum of its spans.
pub const CRITICAL_PATH: [SpanKind; 7] = [
    SpanKind::QueueWait,
    SpanKind::CacheProbe,
    SpanKind::SpecVerify,
    SpanKind::LutBuild,
    SpanKind::NodeScan,
    SpanKind::Merge,
    SpanKind::ReplyWrite,
];

/// Aggregated view of one trace snapshot.
#[derive(Clone, Debug)]
pub struct TraceAnalysis {
    pub n_events: usize,
    /// Distinct nonzero trace ids.
    pub n_traces: usize,
    /// Per-kind summary over individual span durations.
    pub per_stage: Vec<(SpanKind, Summary)>,
    /// Per-node scan summary (tag = node index).
    pub per_node: Vec<(u32, Summary)>,
    /// End-to-end `Total` spans (server-side residency per query).
    pub totals: Option<Summary>,
    /// Mean share of each trace's `Total` attributed to each critical-
    /// path stage, in [`CRITICAL_PATH`] order.
    pub critical_share: Vec<(SpanKind, f64)>,
    /// Per-trace (critical-path stage sum) / `Total` — the consistency
    /// measure: near 1.0 means the spans explain the measured e2e time.
    pub coverage: Option<Summary>,
    pub hedges_fired: u64,
    pub hedges_won: u64,
    pub cache_probes: u64,
    pub cache_hits: u64,
    pub spec_verifies: u64,
    pub spec_hits: u64,
}

/// Per-trace critical-path stage durations for one trace id.
fn critical_durations(evs: &[&SpanEvent]) -> BTreeMap<SpanKind, f64> {
    let mut out = BTreeMap::new();
    for ev in evs {
        match ev.kind {
            SpanKind::NodeScan => {
                let e = out.entry(SpanKind::NodeScan).or_insert(0.0f64);
                *e = e.max(ev.dur_s);
            }
            SpanKind::Total | SpanKind::HedgeFired | SpanKind::HedgeWon => {}
            k => *out.entry(k).or_insert(0.0) += ev.dur_s,
        }
    }
    out
}

/// Aggregate a snapshot. Events with `trace_id == 0` still feed the
/// per-stage and hedge/cache counters but not per-trace attribution.
pub fn analyze(events: &[SpanEvent]) -> TraceAnalysis {
    let mut by_kind: BTreeMap<SpanKind, Vec<f64>> = BTreeMap::new();
    let mut by_node: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
    let mut by_trace: BTreeMap<u64, Vec<&SpanEvent>> = BTreeMap::new();
    let (mut hf, mut hw) = (0u64, 0u64);
    let (mut cp, mut ch, mut sv, mut sh) = (0u64, 0u64, 0u64, 0u64);
    for ev in events {
        by_kind.entry(ev.kind).or_default().push(ev.dur_s);
        match ev.kind {
            SpanKind::NodeScan => by_node.entry(ev.tag).or_default().push(ev.dur_s),
            SpanKind::HedgeFired => hf += ev.tag as u64,
            SpanKind::HedgeWon => hw += ev.tag as u64,
            SpanKind::CacheProbe => {
                cp += 1;
                ch += (ev.tag == 1) as u64;
            }
            SpanKind::SpecVerify => {
                sv += 1;
                sh += (ev.tag == 1) as u64;
            }
            _ => {}
        }
        if ev.trace_id != 0 {
            by_trace.entry(ev.trace_id).or_default().push(ev);
        }
    }

    // Critical-path attribution over traces that carry a Total span.
    let mut shares: BTreeMap<SpanKind, Vec<f64>> = BTreeMap::new();
    let mut coverage = Vec::new();
    for evs in by_trace.values() {
        let total: f64 = evs
            .iter()
            .filter(|e| e.kind == SpanKind::Total)
            .map(|e| e.dur_s)
            .sum();
        if total <= 0.0 {
            continue;
        }
        let durs = critical_durations(evs);
        let sum: f64 = durs.values().sum();
        coverage.push(sum / total);
        for k in CRITICAL_PATH {
            shares.entry(k).or_default().push(durs.get(&k).copied().unwrap_or(0.0) / total);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;

    TraceAnalysis {
        n_events: events.len(),
        n_traces: by_trace.len(),
        per_stage: ALL_KINDS
            .iter()
            .filter_map(|k| by_kind.get(k).map(|v| (*k, Summary::of(v))))
            .collect(),
        per_node: by_node.iter().map(|(n, v)| (*n, Summary::of(v))).collect(),
        totals: by_kind.get(&SpanKind::Total).map(|v| Summary::of(v)),
        critical_share: CRITICAL_PATH
            .iter()
            .map(|k| (*k, mean(shares.get(k).map(|v| &v[..]).unwrap_or(&[]))))
            .collect(),
        coverage: if coverage.is_empty() { None } else { Some(Summary::of(&coverage)) },
        hedges_fired: hf,
        hedges_won: hw,
        cache_probes: cp,
        cache_hits: ch,
        spec_verifies: sv,
        spec_hits: sh,
    }
}

impl TraceAnalysis {
    /// Span kinds present in the snapshot.
    pub fn kinds_present(&self) -> Vec<SpanKind> {
        self.per_stage.iter().map(|(k, _)| *k).collect()
    }

    /// Mean critical-path stage sum in seconds (for planner fitting).
    pub fn stage_mean_s(&self, kind: SpanKind) -> f64 {
        if kind == SpanKind::NodeScan {
            // Per-trace max, not the per-span mean: recompute from the
            // attribution shares times the mean total.
            if let (Some(t), Some((_, share))) = (
                &self.totals,
                self.critical_share.iter().find(|(k, _)| *k == SpanKind::NodeScan),
            ) {
                return share * t.mean;
            }
        }
        self.per_stage
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, s)| s.mean)
            .unwrap_or(0.0)
    }

    /// Human-readable report table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace — {} events, {} traces\n",
            self.n_events, self.n_traces
        ));
        out.push_str("stage          n       p50         p95         p99         mean\n");
        for (k, s) in &self.per_stage {
            out.push_str(&format!(
                "{:<12} {:>6} {:>9.3}ms {:>9.3}ms {:>9.3}ms {:>9.3}ms\n",
                k.name(),
                s.n,
                s.p50 * 1e3,
                s.p95 * 1e3,
                s.p99 * 1e3,
                s.mean * 1e3,
            ));
        }
        out.push_str("critical path (mean share of total):");
        for (k, share) in &self.critical_share {
            out.push_str(&format!(" {}={:.1}%", k.name(), share * 100.0));
        }
        out.push('\n');
        if let Some(cov) = &self.coverage {
            out.push_str(&format!(
                "stage-sum coverage of e2e total: p50={:.2} mean={:.2}\n",
                cov.p50, cov.mean
            ));
        }
        if self.cache_probes > 0 {
            out.push_str(&format!(
                "cache: {}/{} hits ({:.1}%)\n",
                self.cache_hits,
                self.cache_probes,
                100.0 * self.cache_hits as f64 / self.cache_probes as f64
            ));
        }
        if self.spec_verifies > 0 {
            out.push_str(&format!(
                "speculation: {}/{} verified hits ({:.1}%)\n",
                self.spec_hits,
                self.spec_verifies,
                100.0 * self.spec_hits as f64 / self.spec_verifies as f64
            ));
        }
        if self.hedges_fired > 0 {
            out.push_str(&format!(
                "hedges: {} fired, {} won ({:.1}%)\n",
                self.hedges_fired,
                self.hedges_won,
                100.0 * self.hedges_won as f64 / self.hedges_fired as f64
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let stage_json = |s: &Summary| {
            obj(vec![
                ("n", Json::Num(s.n as f64)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
                ("p99", Json::Num(s.p99)),
                ("mean", Json::Num(s.mean)),
            ])
        };
        let mut stages = BTreeMap::new();
        for (k, s) in &self.per_stage {
            stages.insert(k.name().to_string(), stage_json(s));
        }
        let mut shares = BTreeMap::new();
        for (k, v) in &self.critical_share {
            shares.insert(k.name().to_string(), Json::Num(*v));
        }
        obj(vec![
            ("n_events", Json::Num(self.n_events as f64)),
            ("n_traces", Json::Num(self.n_traces as f64)),
            ("stages", Json::Obj(stages)),
            ("critical_share", Json::Obj(shares)),
            (
                "coverage_p50",
                self.coverage.as_ref().map(|c| Json::Num(c.p50)).unwrap_or(Json::Null),
            ),
            ("hedges_fired", Json::Num(self.hedges_fired as f64)),
            ("hedges_won", Json::Num(self.hedges_won as f64)),
            ("cache_probes", Json::Num(self.cache_probes as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("spec_verifies", Json::Num(self.spec_verifies as f64)),
            ("spec_hits", Json::Num(self.spec_hits as f64)),
        ])
    }
}

/// Serialize a snapshot for offline analysis (`--trace-out`).
pub fn events_to_json(events: &[SpanEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| {
                Json::Arr(vec![
                    Json::Num(e.trace_id as f64),
                    Json::Num(e.kind as u8 as f64),
                    Json::Num(e.tag as f64),
                    Json::Num(e.t_us as f64),
                    Json::Num(e.dur_s),
                ])
            })
            .collect(),
    )
}

/// Parse a snapshot dumped by [`events_to_json`].
pub fn events_from_json(j: &Json) -> Result<Vec<SpanEvent>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("trace dump: expected array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, row) in arr.iter().enumerate() {
        let f = row.as_arr().ok_or_else(|| anyhow!("trace dump row {i}: expected array"))?;
        if f.len() != 5 {
            return Err(anyhow!("trace dump row {i}: expected 5 fields, got {}", f.len()));
        }
        let num =
            |j: &Json, what: &str| j.as_f64().ok_or_else(|| anyhow!("row {i}: bad {what}"));
        let kind_v = num(&f[1], "kind")? as u8;
        out.push(SpanEvent {
            trace_id: num(&f[0], "trace_id")? as u64,
            kind: SpanKind::from_u8(kind_v)
                .ok_or_else(|| anyhow!("row {i}: unknown span kind {kind_v}"))?,
            tag: num(&f[2], "tag")? as u32,
            t_us: num(&f[3], "t_us")? as u64,
            dur_s: num(&f[4], "dur_s")?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(trace_id: u64, kind: SpanKind, tag: u32, dur_s: f64) -> SpanEvent {
        SpanEvent { trace_id, kind, tag, t_us: 0, dur_s }
    }

    /// One synthetic two-node query: stages sum exactly to the total.
    fn synthetic_trace(id: u64) -> Vec<SpanEvent> {
        vec![
            ev(id, SpanKind::QueueWait, 0, 0.001),
            ev(id, SpanKind::LutBuild, 0, 0.0005),
            ev(id, SpanKind::NodeScan, 0, 0.004),
            ev(id, SpanKind::NodeScan, 1, 0.003),
            ev(id, SpanKind::Merge, 0, 0.0002),
            ev(id, SpanKind::ReplyWrite, 0, 0.0003),
            // total = queue + lut + max(scan) + merge + reply = 0.006
            ev(id, SpanKind::Total, 0, 0.006),
        ]
    }

    #[test]
    fn attribution_uses_max_scan_and_sums_to_total() {
        let mut evs = synthetic_trace(1);
        evs.extend(synthetic_trace(2));
        let a = analyze(&evs);
        assert_eq!(a.n_traces, 2);
        let cov = a.coverage.as_ref().unwrap();
        assert!((cov.mean - 1.0).abs() < 1e-9, "coverage {}", cov.mean);
        let scan_share = a
            .critical_share
            .iter()
            .find(|(k, _)| *k == SpanKind::NodeScan)
            .unwrap()
            .1;
        // max(0.004, 0.003) / 0.006
        assert!((scan_share - 0.004 / 0.006).abs() < 1e-9);
        // Per-node summaries keyed by tag.
        assert_eq!(a.per_node.len(), 2);
        assert!((a.stage_mean_s(SpanKind::NodeScan) - 0.004).abs() < 1e-9);
        assert!((a.stage_mean_s(SpanKind::Merge) - 0.0002).abs() < 1e-9);
    }

    #[test]
    fn rates_count_hits_and_hedges() {
        let evs = vec![
            ev(1, SpanKind::CacheProbe, 1, 1e-6),
            ev(2, SpanKind::CacheProbe, 0, 1e-6),
            ev(2, SpanKind::SpecVerify, 1, 1e-6),
            ev(0, SpanKind::HedgeFired, 3, 0.0),
            ev(0, SpanKind::HedgeWon, 1, 0.0),
        ];
        let a = analyze(&evs);
        assert_eq!((a.cache_probes, a.cache_hits), (2, 1));
        assert_eq!((a.spec_verifies, a.spec_hits), (1, 1));
        assert_eq!((a.hedges_fired, a.hedges_won), (3, 1));
        let text = a.render();
        assert!(text.contains("cache: 1/2"));
        assert!(text.contains("hedges: 3 fired"));
    }

    #[test]
    fn events_roundtrip_through_json() {
        let evs = synthetic_trace(7);
        let j = events_to_json(&evs);
        let back = events_from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(evs, back);
        assert!(events_from_json(&Json::Num(1.0)).is_err());
    }
}
