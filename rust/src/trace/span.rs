//! Span taxonomy: the stages of one query's life through the serving
//! stack (paper Sec 6 decomposes latency over exactly these tiers).

/// The stage a [`SpanEvent`] measures.
///
/// Stable `u8` discriminants — events round-trip through JSON dumps and
/// (for node-side stages) the wire, so renumbering is a format break.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum SpanKind {
    /// Arrival at the coordinator until the dynamic batcher drained the
    /// request into a dispatch round.
    QueueWait = 0,
    /// ADC lookup-table build (coordinator arena fill + node-side share
    /// reported over the wire).
    LutBuild = 1,
    /// One memory node's scan wall for this query (tag = node index;
    /// nodes scan in parallel, so the critical path takes the max).
    NodeScan = 2,
    /// K-way merge of per-node top-k lists.
    Merge = 3,
    /// A hedged duplicate scan was fired this round (tag = count).
    HedgeFired = 4,
    /// A hedged duplicate won the race (tag = count).
    HedgeWon = 5,
    /// Retrieval-cache probe (tag: 1 = hit, 0 = miss).
    CacheProbe = 6,
    /// Speculative-retrieval verification (tag: 1 = hit, 0 = miss/idle).
    SpecVerify = 7,
    /// Encoding + writing the reply frame back to the client.
    ReplyWrite = 8,
    /// Whole server-side residency: arrival until the reply was written.
    Total = 9,
}

/// Every kind, in discriminant order (drives report tables).
pub const ALL_KINDS: [SpanKind; 10] = [
    SpanKind::QueueWait,
    SpanKind::LutBuild,
    SpanKind::NodeScan,
    SpanKind::Merge,
    SpanKind::HedgeFired,
    SpanKind::HedgeWon,
    SpanKind::CacheProbe,
    SpanKind::SpecVerify,
    SpanKind::ReplyWrite,
    SpanKind::Total,
];

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::QueueWait => "queue_wait",
            SpanKind::LutBuild => "lut_build",
            SpanKind::NodeScan => "node_scan",
            SpanKind::Merge => "merge",
            SpanKind::HedgeFired => "hedge_fired",
            SpanKind::HedgeWon => "hedge_won",
            SpanKind::CacheProbe => "cache_probe",
            SpanKind::SpecVerify => "spec_verify",
            SpanKind::ReplyWrite => "reply_write",
            SpanKind::Total => "total",
        }
    }

    pub fn from_u8(v: u8) -> Option<SpanKind> {
        ALL_KINDS.get(v as usize).copied()
    }
}

/// One recorded stage measurement. Plain `Copy` data — the ring stores
/// these inline; recording never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    /// Query trace id (0 = not tied to one query, e.g. hedge counters).
    pub trace_id: u64,
    pub kind: SpanKind,
    /// Kind-specific tag: node index for `NodeScan`, hit flag for
    /// `CacheProbe`/`SpecVerify`, count for hedge events.
    pub tag: u32,
    /// Microseconds since the tracer epoch (event completion time).
    pub t_us: u64,
    /// Stage duration in seconds.
    pub dur_s: f64,
}

impl SpanEvent {
    /// A zeroed placeholder (ring slots start in this state).
    pub const EMPTY: SpanEvent = SpanEvent {
        trace_id: 0,
        kind: SpanKind::QueueWait,
        tag: 0,
        t_us: 0,
        dur_s: 0.0,
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_discriminants_are_stable() {
        for (i, k) in ALL_KINDS.iter().enumerate() {
            assert_eq!(*k as u8, i as u8);
            assert_eq!(SpanKind::from_u8(i as u8), Some(*k));
        }
        assert_eq!(SpanKind::from_u8(10), None);
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> = ALL_KINDS.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ALL_KINDS.len());
    }
}
