//! Lock-free span ring: a fixed-capacity seqlock-slot ring buffer.
//!
//! Writers claim a slot with one `fetch_add` and publish the event under
//! a per-slot sequence word (odd = write in progress, even = stable
//! generation), so recording is wait-free for readers and never blocks
//! another writer — and, critically for the hot path, never allocates:
//! every slot is preallocated at construction. When the ring wraps, the
//! oldest events are overwritten (`dropped()` counts them); tracing is a
//! sampling instrument, not a reliable log.
//!
//! Readers ([`TraceRing::snapshot`]) are expected to run after the
//! traced work quiesced (post-shutdown report aggregation); concurrent
//! snapshots are still safe — a torn slot fails its sequence re-check
//! and is skipped after a bounded retry.

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::span::{SpanEvent, SpanKind};

struct Slot {
    /// 0 = never written; odd = write in progress; even 2(g+1) = stable
    /// value from generation g.
    seq: AtomicU64,
    ev: UnsafeCell<SpanEvent>,
}

/// Fixed-capacity lock-free ring of [`SpanEvent`]s.
pub struct TraceRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
    mask: u64,
    shift: u32,
    epoch: Instant,
}

// The UnsafeCell is guarded by the per-slot seqlock protocol.
unsafe impl Sync for TraceRing {}
unsafe impl Send for TraceRing {}

impl TraceRing {
    /// `capacity` is rounded up to a power of two (min 8).
    pub fn new(capacity: usize) -> TraceRing {
        let cap = capacity.next_power_of_two().max(8);
        let slots: Vec<Slot> = (0..cap)
            .map(|_| Slot { seq: AtomicU64::new(0), ev: UnsafeCell::new(SpanEvent::EMPTY) })
            .collect();
        TraceRing {
            slots: slots.into_boxed_slice(),
            head: AtomicU64::new(0),
            mask: (cap - 1) as u64,
            shift: cap.trailing_zeros(),
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Microseconds since the ring was created (the event timebase).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Events recorded so far (including any overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Events lost to wrap-around.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.capacity() as u64)
    }

    /// Record one event. Wait-free, allocation-free.
    #[inline]
    pub fn record(&self, mut ev: SpanEvent) {
        ev.t_us = self.now_us();
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket & self.mask) as usize];
        let generation = ticket >> self.shift;
        // Seqlock write: mark in-progress (odd), publish, mark stable.
        slot.seq.store(2 * generation + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        // SAFETY: torn reads are detected (and discarded) by the
        // sequence re-check in `snapshot`; SpanEvent is plain Copy data.
        unsafe { std::ptr::write_volatile(slot.ev.get(), ev) };
        fence(Ordering::Release);
        slot.seq.store(2 * (generation + 1), Ordering::Release);
    }

    /// Drain a consistent copy of the held events, oldest first by
    /// record time. Slots mid-write after a bounded retry are skipped.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            for _ in 0..8 {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 == 0 {
                    break; // never written
                }
                if s1 % 2 == 1 {
                    std::hint::spin_loop();
                    continue; // write in progress
                }
                // SAFETY: validated by the s1 == s2 re-check below.
                let ev = unsafe { std::ptr::read_volatile(slot.ev.get()) };
                fence(Ordering::Acquire);
                let s2 = slot.seq.load(Ordering::Relaxed);
                if s1 == s2 {
                    out.push(ev);
                    break;
                }
            }
        }
        out.sort_by_key(|e| e.t_us);
        out
    }
}

/// Cheap cloneable handle threaded through the serving stack. With no
/// ring attached every call is a branch and a return — the disabled
/// path stays off the profile.
#[derive(Clone, Default)]
pub struct Tracer {
    ring: Option<Arc<TraceRing>>,
}

impl Tracer {
    /// A disabled tracer (records nothing).
    pub fn off() -> Tracer {
        Tracer::default()
    }

    /// An enabled tracer over a fresh ring of `capacity` events.
    pub fn new(capacity: usize) -> Tracer {
        Tracer { ring: Some(Arc::new(TraceRing::new(capacity))) }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.ring.is_some()
    }

    /// Record a stage measurement (no-op when disabled).
    #[inline]
    pub fn record(&self, trace_id: u64, kind: SpanKind, tag: u32, dur_s: f64) {
        if let Some(ring) = &self.ring {
            ring.record(SpanEvent { trace_id, kind, tag, t_us: 0, dur_s });
        }
    }

    pub fn snapshot(&self) -> Vec<SpanEvent> {
        self.ring.as_ref().map(|r| r.snapshot()).unwrap_or_default()
    }

    pub fn ring(&self) -> Option<&Arc<TraceRing>> {
        self.ring.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots_in_order() {
        let ring = TraceRing::new(16);
        for i in 0..5u64 {
            ring.record(SpanEvent {
                trace_id: i + 1,
                kind: SpanKind::NodeScan,
                tag: i as u32,
                t_us: 0,
                dur_s: i as f64,
            });
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 5);
        let ids: Vec<u64> = evs.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn wraps_and_counts_drops() {
        let ring = TraceRing::new(8);
        for i in 0..20u64 {
            ring.record(SpanEvent {
                trace_id: i,
                kind: SpanKind::Merge,
                tag: 0,
                t_us: 0,
                dur_s: 0.0,
            });
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 8);
        assert_eq!(ring.recorded(), 20);
        assert_eq!(ring.dropped(), 12);
        // Only the newest capacity-many survive.
        assert!(evs.iter().all(|e| e.trace_id >= 12));
    }

    #[test]
    fn concurrent_writers_never_tear() {
        let ring = Arc::new(TraceRing::new(1024));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let ring = Arc::clone(&ring);
                s.spawn(move || {
                    for i in 0..10_000u64 {
                        ring.record(SpanEvent {
                            trace_id: t * 1_000_000 + i,
                            // dur encodes the id: a torn slot would
                            // mismatch.
                            kind: SpanKind::NodeScan,
                            tag: t as u32,
                            t_us: 0,
                            dur_s: (t * 1_000_000 + i) as f64,
                        });
                    }
                });
            }
            // Concurrent snapshots must stay consistent.
            for _ in 0..50 {
                for ev in ring.snapshot() {
                    assert_eq!(ev.trace_id as f64, ev.dur_s, "torn slot");
                }
            }
        });
        assert_eq!(ring.recorded(), 40_000);
    }

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::off();
        assert!(!t.enabled());
        t.record(1, SpanKind::Total, 0, 1.0);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn tracer_clones_share_the_ring() {
        let t = Tracer::new(64);
        let u = t.clone();
        t.record(1, SpanKind::QueueWait, 0, 0.5);
        u.record(2, SpanKind::Merge, 0, 0.25);
        assert_eq!(t.snapshot().len(), 2);
        assert_eq!(u.snapshot().len(), 2);
    }
}
