//! Epoch-versioned cluster membership: which memory node serves which
//! shard, at what lifecycle state.
//!
//! The map is pure metadata — no sockets, no backends — so membership
//! logic is deterministic and unit-testable. Every transition
//! ([`join`](ClusterMap::join) / [`drain`](ClusterMap::drain) /
//! [`remove`](ClusterMap::remove) / wholesale [`swap`](ClusterMap::swap))
//! bumps the epoch; the serving layer swaps epochs *between* dispatch
//! rounds, so in-flight requests always run against one consistent view.
//!
//! A node serves exactly one shard replica (the shape of a `chamvs-node`
//! process: one [`Shard::carve`](crate::ivf::shard::Shard::carve) slice in
//! DRAM). Replication is therefore expressed as several nodes declaring
//! the same shard; [`carve_plan`](ClusterMap::carve_plan) is the
//! deterministic node→shard assignment used when (re)carving a cluster
//! from an index.

use std::collections::BTreeMap;

use anyhow::Result;

/// Cluster-unique node identity (the coordinator's handle for one
/// backend; independent of the shard the node serves).
pub type NodeId = u32;

/// Lifecycle state of one member.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Serving traffic; eligible for primary/replica selection.
    Active,
    /// Retiring: excluded from new selection, kept in the map so its
    /// in-flight work can finish before [`ClusterMap::remove`].
    Draining,
}

/// One member of the cluster.
#[derive(Clone, Copy, Debug)]
pub struct NodeMeta {
    pub id: NodeId,
    /// The shard this node holds a replica of.
    pub shard: usize,
    pub state: NodeState,
}

/// Epoch-versioned shard→replica-set assignment.
#[derive(Clone, Debug)]
pub struct ClusterMap {
    epoch: u64,
    n_shards: usize,
    nodes: BTreeMap<NodeId, NodeMeta>,
}

impl ClusterMap {
    pub fn new(n_shards: usize) -> ClusterMap {
        ClusterMap { epoch: 0, n_shards: n_shards.max(1), nodes: BTreeMap::new() }
    }

    /// Current membership epoch (bumped by every transition).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Total members, any state.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> Option<&NodeMeta> {
        self.nodes.get(&id)
    }

    /// All members in id order.
    pub fn nodes(&self) -> impl Iterator<Item = &NodeMeta> {
        self.nodes.values()
    }

    /// Deterministic node→shard assignment for a fresh cluster of
    /// `n_nodes` nodes at replication factor `replication`: node `i`
    /// serves shard `i % n_shards` with `n_shards = n_nodes /
    /// replication`, so every shard gets exactly `replication` replicas.
    /// Returns `(node_id, shard)` pairs — the carve instructions a
    /// (re)balance executes via `Shard::carve(index, shard, n_shards)`.
    pub fn carve_plan(n_nodes: usize, replication: usize) -> Result<Vec<(NodeId, usize)>> {
        anyhow::ensure!(replication >= 1, "replication factor must be >= 1");
        anyhow::ensure!(
            n_nodes >= replication && n_nodes % replication == 0,
            "{n_nodes} nodes cannot carry replication {replication} \
             (need a positive multiple of it)"
        );
        let n_shards = n_nodes / replication;
        Ok((0..n_nodes).map(|i| (i as NodeId, i % n_shards)).collect())
    }

    /// Add a node serving a replica of `shard`. Errors on duplicate id or
    /// out-of-range shard. Returns the new epoch.
    pub fn join(&mut self, id: NodeId, shard: usize) -> Result<u64> {
        anyhow::ensure!(
            shard < self.n_shards,
            "shard {shard} out of range (cluster has {} shards)",
            self.n_shards
        );
        anyhow::ensure!(
            !self.nodes.contains_key(&id),
            "node {id} is already a cluster member"
        );
        self.nodes.insert(id, NodeMeta { id, shard, state: NodeState::Active });
        self.epoch += 1;
        Ok(self.epoch)
    }

    /// Mark a node Draining: no new selection, existing work finishes.
    /// Refuses to uncover a shard (the last active replica can't drain).
    pub fn drain(&mut self, id: NodeId) -> Result<u64> {
        let meta =
            *self.nodes.get(&id).ok_or_else(|| anyhow::anyhow!("unknown node {id}"))?;
        if meta.state == NodeState::Active {
            anyhow::ensure!(
                self.replication(meta.shard) > 1,
                "draining node {id} would leave shard {} with no active replica",
                meta.shard
            );
        }
        self.nodes.get_mut(&id).unwrap().state = NodeState::Draining;
        self.epoch += 1;
        Ok(self.epoch)
    }

    /// Remove a node from the map entirely. Refuses to uncover a shard.
    pub fn remove(&mut self, id: NodeId) -> Result<u64> {
        let meta =
            *self.nodes.get(&id).ok_or_else(|| anyhow::anyhow!("unknown node {id}"))?;
        if meta.state == NodeState::Active {
            anyhow::ensure!(
                self.replication(meta.shard) > 1,
                "removing node {id} would leave shard {} with no active replica",
                meta.shard
            );
        }
        self.nodes.remove(&id);
        self.epoch += 1;
        Ok(self.epoch)
    }

    /// Active replicas of one shard, in deterministic rotated order: ids
    /// ascending, rotated left by `shard` so primaries spread across
    /// nodes instead of piling on the lowest id. (Health-aware selection
    /// may reorder on top of this; the rotation is the tie-free base.)
    pub fn replicas(&self, shard: usize) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self
            .nodes
            .values()
            .filter(|n| n.shard == shard && n.state == NodeState::Active)
            .map(|n| n.id)
            .collect();
        if !ids.is_empty() {
            ids.rotate_left(shard % ids.len());
        }
        ids
    }

    /// Number of *active* replicas of one shard.
    pub fn replication(&self, shard: usize) -> usize {
        self.nodes
            .values()
            .filter(|n| n.shard == shard && n.state == NodeState::Active)
            .count()
    }

    /// Smallest active replication across all shards (0 = some shard is
    /// uncovered and dispatch would fail).
    pub fn min_replication(&self) -> usize {
        (0..self.n_shards).map(|s| self.replication(s)).min().unwrap_or(0)
    }

    /// Whether every shard has at least one active replica.
    pub fn is_covered(&self) -> bool {
        self.min_replication() >= 1
    }

    /// Replace the whole membership in one transition (live rebalance:
    /// the new node set was carved from the index at a new shard count).
    /// The epoch stays monotonic across the swap.
    pub fn swap(&mut self, n_shards: usize, members: &[(NodeId, usize)]) -> Result<u64> {
        let n_shards = n_shards.max(1);
        let mut nodes: BTreeMap<NodeId, NodeMeta> = BTreeMap::new();
        for &(id, shard) in members {
            anyhow::ensure!(shard < n_shards, "shard {shard} out of range");
            anyhow::ensure!(
                nodes
                    .insert(id, NodeMeta { id, shard, state: NodeState::Active })
                    .is_none(),
                "duplicate node id {id} in swap"
            );
        }
        for s in 0..n_shards {
            anyhow::ensure!(
                nodes.values().any(|n| n.shard == s),
                "swap leaves shard {s} uncovered"
            );
        }
        self.n_shards = n_shards;
        self.nodes = nodes;
        self.epoch += 1;
        Ok(self.epoch)
    }

    /// Human-readable assignment table for the `chameleon cluster` report.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "cluster map: epoch {} | {} shards | {} nodes | min replication {}",
            self.epoch,
            self.n_shards,
            self.nodes.len(),
            self.min_replication()
        );
        for s in 0..self.n_shards {
            let active = self.replicas(s);
            let draining: Vec<NodeId> = self
                .nodes
                .values()
                .filter(|n| n.shard == s && n.state == NodeState::Draining)
                .map(|n| n.id)
                .collect();
            let _ = writeln!(
                out,
                "  shard {s}: active {active:?} draining {draining:?}"
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map_4x2() -> ClusterMap {
        let mut m = ClusterMap::new(2);
        for (id, shard) in ClusterMap::carve_plan(4, 2).unwrap() {
            m.join(id, shard).unwrap();
        }
        m
    }

    #[test]
    fn carve_plan_gives_exact_replication() {
        let plan = ClusterMap::carve_plan(6, 2).unwrap();
        assert_eq!(plan.len(), 6);
        for s in 0..3 {
            assert_eq!(plan.iter().filter(|&&(_, sh)| sh == s).count(), 2);
        }
        // Deterministic: same inputs, same plan.
        assert_eq!(plan, ClusterMap::carve_plan(6, 2).unwrap());
        assert!(ClusterMap::carve_plan(5, 2).is_err());
        assert!(ClusterMap::carve_plan(4, 0).is_err());
    }

    #[test]
    fn transitions_bump_epoch() {
        let mut m = map_4x2();
        assert_eq!(m.epoch(), 4); // four joins
        let e = m.drain(0).unwrap();
        assert_eq!(e, 5);
        let e = m.remove(0).unwrap();
        assert_eq!(e, 6);
        assert_eq!(m.len(), 3);
        assert!(m.is_covered());
    }

    #[test]
    fn replicas_are_rotated_and_active_only() {
        let m = map_4x2();
        // Shard 0: nodes {0, 2}; shard 1: nodes {1, 3} rotated by 1.
        assert_eq!(m.replicas(0), vec![0, 2]);
        assert_eq!(m.replicas(1), vec![3, 1]);
        let mut m = m;
        m.drain(3).unwrap();
        assert_eq!(m.replicas(1), vec![1]);
        assert_eq!(m.replication(1), 1);
    }

    #[test]
    fn cannot_uncover_a_shard() {
        let mut m = map_4x2();
        m.drain(0).unwrap();
        // Node 2 is now shard 0's last active replica.
        assert!(m.drain(2).is_err());
        assert!(m.remove(2).is_err());
        // Removing the already-draining node is fine.
        m.remove(0).unwrap();
        assert!(m.is_covered());
    }

    #[test]
    fn join_validates() {
        let mut m = ClusterMap::new(2);
        m.join(7, 0).unwrap();
        assert!(m.join(7, 1).is_err(), "duplicate id");
        assert!(m.join(8, 2).is_err(), "shard out of range");
        assert!(!m.is_covered(), "shard 1 uncovered");
    }

    #[test]
    fn swap_is_one_epoch_and_validates_coverage() {
        let mut m = map_4x2();
        let before = m.epoch();
        let members: Vec<(NodeId, usize)> =
            ClusterMap::carve_plan(4, 1).unwrap();
        let e = m.swap(4, &members).unwrap();
        assert_eq!(e, before + 1);
        assert_eq!(m.n_shards(), 4);
        assert_eq!(m.min_replication(), 1);
        assert!(m.swap(2, &[(0, 0)]).is_err(), "shard 1 uncovered");
        // Failed swap must not have mutated the map.
        assert_eq!(m.n_shards(), 4);
    }

    #[test]
    fn render_mentions_epoch_and_shards() {
        let m = map_4x2();
        let r = m.render();
        assert!(r.contains("epoch 4"), "{r}");
        assert!(r.contains("shard 0"), "{r}");
    }
}
