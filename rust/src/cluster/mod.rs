//! The elastic, replicated retrieval tier (membership, failover, hedged
//! scans, live shard rebalancing) — the production layer that turns the
//! fixed node set of the prototype into the independently-scalable
//! ChamVS tier the paper's disaggregation argument promises.
//!
//! * [`map`] — [`ClusterMap`]: epoch-versioned shard→replica-set
//!   assignment with join/drain/remove/swap transitions and the
//!   deterministic [`ClusterMap::carve_plan`] node→shard assignment.
//! * [`health`] — [`HealthTracker`]: per-node scan-latency EWMA, a
//!   consecutive-failure circuit breaker, and the recent-latency window
//!   that prices hedge deadlines.
//! * [`engine`] — [`ClusterEngine`]: persistent per-node workers,
//!   replica selection, retry-on-replica failover, and quantile-deadline
//!   hedging with first-response-wins. Plugs into
//!   [`Dispatcher`](crate::chamvs::dispatcher::Dispatcher) via
//!   [`Dispatcher::clustered`](crate::chamvs::dispatcher::Dispatcher::clustered),
//!   so the whole serving stack (retriever, coordinator server, CLI)
//!   runs over the replicated tier unchanged.
//! * [`fault`] — deterministic fault-injection backends (dying node,
//!   intermittent straggler) shared by the failure tests, the
//!   `cluster_failover` bench and the `chameleon cluster` demo.

pub mod engine;
pub mod fault;
pub mod health;
pub mod map;

pub use engine::{
    ClusterConfig, ClusterEngine, ClusterNode, ClusterStats, DegradedPolicy, HedgeConfig,
    RoundOptions, RoundOutcome, SelectPolicy,
};
pub use fault::{FailingBackend, OutageBackend, StragglerBackend};
pub use health::{Breaker, HealthTracker, NodeHealth};
pub use map::{ClusterMap, NodeId, NodeMeta, NodeState};
