//! The replica-aware execution engine of the elastic retrieval tier: one
//! persistent worker thread per member node (owning its [`ScanBackend`]),
//! a per-round reply channel, and the failover/hedging state machine.
//!
//! A dispatch round sends each shard's job queue to one selected replica
//! (breaker-closed first, latency-EWMA order under the default
//! [`SelectPolicy::HealthAware`]). Because workers are persistent and
//! replies arrive over a channel, the round never blocks on a single
//! node:
//!
//! * **Failover** — a replica that returns an error (dead socket, injected
//!   fault, poisoned connection) is recorded against its health and the
//!   shard retries on the next replica. Replicas hold bit-identical
//!   [`Shard::carve`](crate::ivf::shard::Shard::carve) slices and scans
//!   are deterministic, so the merged top-K is identical to the healthy
//!   cluster's as long as one replica per shard survives.
//! * **Hedging** — with a [`HedgeConfig`], a shard whose reply has not
//!   arrived by the recent-latency quantile deadline fires a duplicate
//!   scan at the next replica; the first response wins and the loser is
//!   discarded on arrival (its latency still feeds the health EWMA).
//! * **Forced failover** — a shard with no reply after
//!   [`ClusterConfig::attempt_timeout`] counts the outstanding attempts as
//!   failures and tries the next replica, bounding detection of a hung
//!   node (the remote transport's socket timeouts bound it first).
//!
//! Membership transitions ([`join`](ClusterEngine::join) /
//! [`drain`](ClusterEngine::drain) / [`remove`](ClusterEngine::remove) /
//! [`swap`](ClusterEngine::swap)) bump the [`ClusterMap`] epoch and take
//! effect at the next round — the serving layer applies them between
//! batches, so no in-flight request ever sees a half-updated view.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::health::HealthTracker;
use super::map::{ClusterMap, NodeId};
use crate::chamvs::backend::{ScanBackend, ScanJob};
use crate::chamvs::node::{MemoryNode, NodeResult, ScanEngine};
use crate::hwmodel::fpga::FpgaModel;
use crate::ivf::index::IvfPqIndex;
use crate::ivf::shard::Shard;

/// How a shard's primary replica is chosen each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectPolicy {
    /// Deterministic map order (rotation only). Used by the hedging A/B
    /// bench so both arms face the same primaries.
    Static,
    /// Breaker-closed replicas first, fastest EWMA first (the default).
    HealthAware,
}

/// Tail-latency hedging knobs.
#[derive(Clone, Copy, Debug)]
pub struct HedgeConfig {
    /// Recent-latency quantile that sets the hedge deadline (e.g. 0.95:
    /// a scan slower than the recent p95 gets a duplicate fired).
    pub quantile: f64,
    /// Deadline floor — never hedge earlier than this, so micro-latency
    /// jitter can't trigger hedge storms.
    pub floor: Duration,
}

impl Default for HedgeConfig {
    fn default() -> HedgeConfig {
        HedgeConfig { quantile: 0.95, floor: Duration::from_micros(200) }
    }
}

/// How a round treats shards that cannot answer (no active replica, all
/// replicas failed, or the round's deadline expired first).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DegradedPolicy {
    /// Any unanswered shard fails the whole round (the legacy contract:
    /// a result is always complete or absent).
    FailFast,
    /// Serve the merged top-k from the shards that answered, tagged with
    /// the coverage fraction — as long as `shards_answered / n_shards`
    /// stays at or above `min_coverage`. Below the floor the round fails.
    ServePartial {
        /// Coverage floor in [0, 1]; 0.0 accepts any non-empty answer.
        min_coverage: f64,
    },
}

impl Default for DegradedPolicy {
    fn default() -> DegradedPolicy {
        DegradedPolicy::FailFast
    }
}

/// Per-round execution options (per-query knobs threaded down from the
/// coordinator; [`Default`] reproduces the legacy fail-fast round).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundOptions {
    /// Partial-result gating for unanswered shards.
    pub degraded: DegradedPolicy,
    /// Absolute end-to-end deadline for this round. Retries and hedges
    /// are only launched while budget remains; shards unresolved at the
    /// deadline are abandoned (failing the round under
    /// [`DegradedPolicy::FailFast`], shrinking coverage under
    /// [`DegradedPolicy::ServePartial`]).
    pub deadline: Option<Instant>,
}

/// Outcome of one [`ClusterEngine::run_round_opts`] call.
#[derive(Debug)]
pub struct RoundOutcome {
    /// Results shaped `[job][answered shard]`, shard order 0..S with
    /// unanswered shards omitted (full rounds keep the legacy shape).
    pub per_job: Vec<Vec<NodeResult>>,
    /// Shards that contributed results this round.
    pub shards_answered: u32,
    /// Total shards in the map.
    pub n_shards: u32,
}

impl RoundOutcome {
    /// Fraction of shards that answered.
    pub fn coverage(&self) -> f64 {
        if self.n_shards == 0 {
            return 1.0;
        }
        self.shards_answered as f64 / self.n_shards as f64
    }

    pub fn is_partial(&self) -> bool {
        self.shards_answered < self.n_shards
    }
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Hedging (None = no duplicate scans; failover still works).
    pub hedge: Option<HedgeConfig>,
    /// Forced-failover deadline for a shard with zero replies.
    pub attempt_timeout: Duration,
    /// Consecutive failures that open a node's circuit breaker.
    pub breaker_threshold: u32,
    /// Primary-selection policy.
    pub select: SelectPolicy,
    /// Pin each worker thread to a planned CPU (`util::affinity`:
    /// round-robin across NUMA nodes), so a replica's memory-bound scans
    /// stay on the socket owning its flat arena. No-op where affinity is
    /// unsupported; successfully pinned workers report their CPU in
    /// [`ClusterStats::pinned`].
    pub pin_workers: bool,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            hedge: None,
            attempt_timeout: Duration::from_secs(10),
            breaker_threshold: 3,
            select: SelectPolicy::HealthAware,
            pin_workers: false,
        }
    }
}

/// Counters over the engine's lifetime (observable via
/// [`ClusterEngine::stats`]; the CLI report and the chaos smoke print
/// them).
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// Rounds executed.
    pub rounds: u64,
    /// Scan attempts sent to workers (primaries + retries + hedges).
    pub attempts: u64,
    /// Retries after a replica failure (failover sends).
    pub retries: u64,
    /// Rounds won by a retry replica (a failover actually served traffic).
    pub failovers: u64,
    /// Hedge scans fired.
    pub hedges: u64,
    /// Rounds won by the hedge replica.
    pub hedge_wins: u64,
    /// Circuit-breaker open transitions.
    pub breaker_trips: u64,
    /// Replies that arrived after their shard was already resolved.
    pub late_responses: u64,
    /// Probation probes sent to breaker-open nodes whose backoff elapsed.
    pub probes: u64,
    /// Probes that answered but were NOT bit-identical to the shard's
    /// winning result (the replica stays out of selection).
    pub probe_mismatches: u64,
    /// Rounds that returned with partial coverage (ServePartial).
    pub partial_rounds: u64,
    /// Shard-rounds that went unanswered (each partial round contributes
    /// `n_shards - shards_answered`).
    pub unanswered_shards: u64,
    /// Shard-rounds abandoned because the round deadline expired.
    pub deadline_expired_shards: u64,
    /// `(node, cpu)` for every worker that successfully pinned and has
    /// served at least one scan since — empty unless
    /// [`ClusterConfig::pin_workers`] is on and the platform supports
    /// affinity.
    pub pinned: Vec<(NodeId, usize)>,
}

impl ClusterStats {
    pub fn render(&self) -> String {
        let mut s = format!(
            "rounds={} attempts={} retries={} failovers={} hedges={} \
             hedge_wins={} breaker_trips={} late_responses={} probes={} \
             probe_mismatches={} partial_rounds={} unanswered_shards={} \
             deadline_expired_shards={}",
            self.rounds,
            self.attempts,
            self.retries,
            self.failovers,
            self.hedges,
            self.hedge_wins,
            self.breaker_trips,
            self.late_responses,
            self.probes,
            self.probe_mismatches,
            self.partial_rounds,
            self.unanswered_shards,
            self.deadline_expired_shards
        );
        if !self.pinned.is_empty() {
            s.push_str(" pinned=[");
            for (i, (node, cpu)) in self.pinned.iter().enumerate() {
                if i > 0 {
                    s.push(' ');
                }
                s.push_str(&format!("n{node}@cpu{cpu}"));
            }
            s.push(']');
        }
        s
    }
}

/// One member handed to the engine: identity, declared shard, backend.
pub struct ClusterNode {
    pub id: NodeId,
    pub shard: usize,
    pub backend: Box<dyn ScanBackend>,
}

/// An owned copy of one round's jobs, shared with the workers
/// (hedged/raced scans outlive the dispatcher's borrowed job slices, so
/// the cluster path pays one job copy per round for its fault
/// tolerance). The codebook is invariant across rounds and shared via
/// the engine's cached [`Arc`] instead of being re-copied.
struct Round {
    jobs: Vec<OwnedJob>,
    codebook: Arc<Vec<f32>>,
}

struct OwnedJob {
    query: Vec<f32>,
    lists: Vec<u32>,
    lut: Vec<f32>,
    nprobe: usize,
}

/// One scan reply from a worker.
struct ScanReply {
    seq: u64,
    shard: usize,
    node: NodeId,
    result: Result<Vec<NodeResult>>,
    /// Worker-observed scan wall (execution on the replica, excluding
    /// queue wait), feeding the EWMA and the hedge-deadline window.
    latency_s: f64,
    /// CPU the worker executed on, when it was successfully pinned
    /// (None: unpinned worker or unsupported platform).
    cpu: Option<usize>,
}

enum Command {
    Scan { seq: u64, shard: usize, round: Arc<Round>, reply: Sender<ScanReply> },
    /// Ask the backend to retire gracefully (remote: send a Drain frame).
    Drain,
    /// Stop the worker, killing the backend (remote: Shutdown frame).
    Shutdown,
    /// Stop the worker without killing the backend (connection just
    /// drops; a drained remote node exits on disconnect).
    Detach,
}

struct Worker {
    tx: Sender<Command>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Worker {
    /// `pin_cpu`: planned CPU from `util::affinity::worker_cpu` — the
    /// thread pins itself at startup; if the kernel refuses (sandbox,
    /// unsupported platform) it runs unpinned and reports no CPU.
    fn spawn(
        id: NodeId,
        mut backend: Box<dyn ScanBackend>,
        pin_cpu: Option<usize>,
    ) -> Result<Worker> {
        let (tx, rx) = channel::<Command>();
        let handle = std::thread::Builder::new()
            .name(format!("cluster-node-{id}"))
            .spawn(move || {
                let pinned =
                    pin_cpu.is_some_and(crate::util::affinity::pin_to_cpu);
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Command::Scan { seq, shard, round, reply } => {
                            let t0 = Instant::now();
                            let jobs: Vec<ScanJob> = round
                                .jobs
                                .iter()
                                .map(|j| ScanJob {
                                    query: &j.query,
                                    lists: &j.lists,
                                    lut: &j.lut,
                                    nprobe: j.nprobe,
                                })
                                .collect();
                            let result = backend.scan_jobs(&jobs, &round.codebook);
                            // The round may already be resolved (hedge
                            // lost) and its receiver gone — ignore.
                            let _ = reply.send(ScanReply {
                                seq,
                                shard,
                                node: id,
                                result,
                                latency_s: t0.elapsed().as_secs_f64(),
                                cpu: if pinned {
                                    crate::util::affinity::current_cpu()
                                } else {
                                    None
                                },
                            });
                        }
                        Command::Drain => backend.drain(),
                        Command::Shutdown => {
                            backend.shutdown();
                            break;
                        }
                        Command::Detach => break,
                    }
                }
            })
            .map_err(|e| anyhow::anyhow!("spawning cluster worker: {e}"))?;
        Ok(Worker { tx, handle: Some(handle) })
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Detach);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-shard state of one in-flight round.
struct ShardRound {
    /// Selection-ordered candidate replicas (snapshot at round start).
    cands: Vec<NodeId>,
    /// Index of the next untried candidate.
    next: usize,
    /// Attempts in flight: (node, attempt kind, already penalized by a
    /// forced-failover timeout — each hung attempt is recorded as a
    /// failure at most once, not once per timeout window).
    outstanding: Vec<(NodeId, Attempt, bool)>,
    done: Option<Vec<NodeResult>>,
    /// Shard abandoned this round (no replica answered, or the deadline
    /// expired): resolved-without-results under `ServePartial`.
    failed: bool,
    /// A probation probe's result that arrived before the shard's winner:
    /// held for the bit-identity comparison (or adopted outright if every
    /// regular replica ends up failing).
    probe_result: Option<(NodeId, Vec<NodeResult>, f64)>,
    /// Armed hedge deadline; cleared once the hedge fires (a shard
    /// hedges at most once per round).
    hedge_at: Option<Instant>,
    timeout_at: Instant,
    /// Last failure seen, for the round's error message.
    last_err: Option<anyhow::Error>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Attempt {
    Primary,
    Retry,
    Hedge,
    /// Half-open probation: the one scan a breaker-open node gets after
    /// its backoff elapses. Its result never races for the shard win
    /// while regular replicas are alive — it is compared bit-identically
    /// against the winner to decide whether the node rejoins selection.
    Probe,
}

/// The elastic, replicated retrieval tier behind a
/// [`Dispatcher`](crate::chamvs::dispatcher::Dispatcher).
pub struct ClusterEngine {
    map: ClusterMap,
    health: HealthTracker,
    workers: BTreeMap<NodeId, Worker>,
    pub cfg: ClusterConfig,
    stats: ClusterStats,
    m: usize,
    wants_lut: bool,
    /// Members whose backend consumes dispatcher-built ADC tables, so
    /// `wants_lut` can be recomputed when they leave (backends live
    /// inside their workers and cannot be queried after spawn).
    lut_nodes: std::collections::BTreeSet<NodeId>,
    fpga: FpgaModel,
    seq: u64,
    /// Workers spawned so far — indexes into the NUMA-interleaved CPU
    /// plan (`util::affinity::worker_cpu`) when pinning is on.
    spawned: usize,
    /// node → observed CPU for successfully pinned workers (from scan
    /// replies; surfaced via [`ClusterStats::pinned`]).
    pinned: BTreeMap<NodeId, usize>,
    /// One-copy codebook cache: rounds share one `Arc` instead of
    /// re-copying ~100 KB per query. Validated by content comparison (a
    /// cheap linear scan against the caller's slice), never by pointer
    /// identity — a reallocated tensor at the same address must not
    /// silently serve stale centroids.
    codebook_cache: Option<Arc<Vec<f32>>>,
}

impl ClusterEngine {
    /// Build an engine over an explicit member set. Validates PQ-width
    /// agreement and full shard coverage.
    pub fn new(
        nodes: Vec<ClusterNode>,
        n_shards: usize,
        cfg: ClusterConfig,
    ) -> Result<ClusterEngine> {
        anyhow::ensure!(!nodes.is_empty(), "cluster needs at least one node");
        let m = nodes[0].backend.m();
        let mut engine = ClusterEngine {
            map: ClusterMap::new(n_shards),
            health: HealthTracker::new(cfg.breaker_threshold),
            workers: BTreeMap::new(),
            cfg,
            stats: ClusterStats::default(),
            m,
            wants_lut: false,
            lut_nodes: std::collections::BTreeSet::new(),
            fpga: FpgaModel::default(),
            seq: 0,
            spawned: 0,
            pinned: BTreeMap::new(),
            codebook_cache: None,
        };
        for node in nodes {
            engine.join(node)?;
        }
        anyhow::ensure!(
            engine.map.is_covered(),
            "cluster does not cover all {} shards",
            engine.map.n_shards()
        );
        Ok(engine)
    }

    /// Convenience builder: an in-process cluster over `n_nodes` fresh
    /// [`MemoryNode`]s carved from `index` at replication factor
    /// `replication` (the [`ClusterMap::carve_plan`] assignment).
    pub fn local(
        index: &IvfPqIndex,
        n_nodes: usize,
        replication: usize,
        k: usize,
        cfg: ClusterConfig,
    ) -> Result<ClusterEngine> {
        let (nodes, n_shards) = local_nodes(index, n_nodes, replication, k)?;
        ClusterEngine::new(nodes, n_shards, cfg)
    }

    /// Add a member: spawns its worker and bumps the epoch. The node must
    /// agree on the PQ width.
    pub fn join(&mut self, node: ClusterNode) -> Result<u64> {
        anyhow::ensure!(
            node.backend.m() == self.m,
            "node {} has PQ width m={} but the cluster uses m={}",
            node.id,
            node.backend.m(),
            self.m
        );
        let epoch = self.map.join(node.id, node.shard)?;
        if node.backend.wants_lut() {
            self.lut_nodes.insert(node.id);
        }
        self.wants_lut = !self.lut_nodes.is_empty();
        let worker = Worker::spawn(node.id, node.backend, self.next_pin_cpu())?;
        self.workers.insert(node.id, worker);
        Ok(epoch)
    }

    /// Planned CPU for the next worker: round-robin over the
    /// NUMA-interleaved plan when pinning is enabled.
    fn next_pin_cpu(&mut self) -> Option<usize> {
        if !self.cfg.pin_workers {
            return None;
        }
        let cpu = crate::util::affinity::worker_cpu(self.spawned);
        self.spawned += 1;
        cpu
    }

    /// Start retiring a member: excluded from new selection; a remote
    /// backend is asked to drain (it exits once its connection closes at
    /// [`remove`](Self::remove) time).
    pub fn drain(&mut self, id: NodeId) -> Result<u64> {
        let epoch = self.map.drain(id)?;
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(Command::Drain);
        }
        Ok(epoch)
    }

    /// Remove a member: drops its worker (and connection) without killing
    /// the backend process — a previously drained `chamvs-node` exits on
    /// the disconnect.
    pub fn remove(&mut self, id: NodeId) -> Result<u64> {
        let epoch = self.map.remove(id)?;
        self.workers.remove(&id); // Worker::drop detaches + joins
        self.pinned.remove(&id);
        self.health.forget(id);
        // Removing the last LUT consumer lets later rounds skip the
        // per-query ADC-table build entirely.
        self.lut_nodes.remove(&id);
        self.wants_lut = !self.lut_nodes.is_empty();
        Ok(epoch)
    }

    /// Live rebalance: replace the whole member set in one epoch (the new
    /// nodes were re-carved from the index at a possibly different shard
    /// count). Health history restarts; the map epoch stays monotonic.
    pub fn swap(&mut self, nodes: Vec<ClusterNode>, n_shards: usize) -> Result<u64> {
        anyhow::ensure!(!nodes.is_empty(), "cluster needs at least one node");
        let m = nodes[0].backend.m();
        anyhow::ensure!(
            nodes.iter().all(|n| n.backend.m() == m),
            "rebalanced nodes disagree on PQ width"
        );
        let members: Vec<(NodeId, usize)> =
            nodes.iter().map(|n| (n.id, n.shard)).collect();
        let lut_nodes: std::collections::BTreeSet<NodeId> = nodes
            .iter()
            .filter(|n| n.backend.wants_lut())
            .map(|n| n.id)
            .collect();
        // Validate the membership on a CLONE, and spawn the replacement
        // workers, before committing anything: a validation error or a
        // failed thread spawn must leave the live engine fully intact
        // (old map, old workers) instead of half-swapped.
        let mut new_map = self.map.clone();
        let epoch = new_map.swap(n_shards, &members)?;
        // The replacement set restarts the CPU plan from slot 0 (the old
        // workers are all about to detach).
        self.spawned = 0;
        self.pinned.clear();
        let mut workers = BTreeMap::new();
        for node in nodes {
            let pin_cpu = self.next_pin_cpu();
            workers.insert(node.id, Worker::spawn(node.id, node.backend, pin_cpu)?);
        }
        self.map = new_map;
        self.m = m;
        self.wants_lut = !lut_nodes.is_empty();
        self.lut_nodes = lut_nodes;
        self.workers = workers; // old workers detach on drop
        self.health = HealthTracker::new(self.cfg.breaker_threshold);
        Ok(epoch)
    }

    /// Re-carve an in-process cluster from `index` at a new shape — the
    /// "live shard rebalancing" path over [`Shard::carve`].
    pub fn rebalance_local(
        &mut self,
        index: &IvfPqIndex,
        n_nodes: usize,
        replication: usize,
        k: usize,
    ) -> Result<u64> {
        let (nodes, n_shards) = local_nodes(index, n_nodes, replication, k)?;
        self.swap(nodes, n_shards)
    }

    /// Kill every backend (remote: Shutdown frames) and join the workers.
    pub fn shutdown_all(&mut self) {
        for w in std::mem::take(&mut self.workers).into_values() {
            let _ = w.tx.send(Command::Shutdown);
            // Worker::drop joins the thread.
        }
    }

    pub fn map(&self) -> &ClusterMap {
        &self.map
    }

    pub fn health(&self) -> &HealthTracker {
        &self.health
    }

    /// Mutable health access, for tuning tracker knobs (EWMA weight,
    /// probation backoff) after construction — tests and the chaos
    /// harness shrink the backoff so rejoin happens on their clock.
    pub fn health_mut(&mut self) -> &mut HealthTracker {
        &mut self.health
    }

    pub fn stats(&self) -> ClusterStats {
        let mut s = self.stats.clone();
        s.pinned = self.pinned.iter().map(|(&n, &c)| (n, c)).collect();
        s
    }

    pub fn epoch(&self) -> u64 {
        self.map.epoch()
    }

    pub fn n_shards(&self) -> usize {
        self.map.n_shards()
    }

    /// PQ width shared by every member.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Whether any member consumes dispatcher-built ADC tables.
    pub fn wants_lut(&self) -> bool {
        self.wants_lut
    }

    /// The FPGA cycle model pricing scans on this tier (replicas share
    /// the default model, as remote nodes do).
    pub fn fpga(&self) -> &FpgaModel {
        &self.fpga
    }

    /// The round's shared codebook: reuse the cached `Arc` while the
    /// caller keeps passing the same centroid tensor, copy once when it
    /// changes. Validation is a content comparison (cheap next to a
    /// scan; bit-equal floats only), so a reallocated tensor can never
    /// alias a stale cache entry.
    fn shared_codebook(&mut self, codebook: &[f32]) -> Arc<Vec<f32>> {
        if let Some(arc) = &self.codebook_cache {
            if arc.len() == codebook.len()
                && arc.iter().zip(codebook).all(|(a, b)| a.to_bits() == b.to_bits())
            {
                return arc.clone();
            }
        }
        let arc = Arc::new(codebook.to_vec());
        self.codebook_cache = Some(arc.clone());
        arc
    }

    /// Assignment + health + counters, for `chameleon cluster`.
    pub fn render_report(&self) -> String {
        format!(
            "{}\n{}\nstats: {}\n",
            self.map.render(),
            self.health.render(),
            self.stats().render()
        )
    }

    /// Execute one round of jobs across the cluster, returning results
    /// shaped `[job][shard]` (shard order 0..S — the exact shape the
    /// dispatcher's flat path produces per node, so the k-way merge and
    /// every downstream consumer are unchanged). Legacy fail-fast
    /// contract: every shard answered or the round errored.
    pub fn run_round(
        &mut self,
        jobs: &[ScanJob<'_>],
        codebook: &[f32],
    ) -> Result<Vec<Vec<NodeResult>>> {
        Ok(self.run_round_opts(jobs, codebook, &RoundOptions::default())?.per_job)
    }

    /// [`run_round`](Self::run_round) with per-round options: a
    /// [`DegradedPolicy`] deciding whether unanswered shards fail the
    /// round or shrink its coverage, and an end-to-end deadline that
    /// every retry and hedge draws from. Also runs half-open probation:
    /// a breaker-open replica whose backoff has elapsed gets exactly one
    /// probe scan riding the round, and rejoins selection only if its
    /// result is bit-identical to the shard's winning result.
    pub fn run_round_opts(
        &mut self,
        jobs: &[ScanJob<'_>],
        codebook: &[f32],
        opts: &RoundOptions,
    ) -> Result<RoundOutcome> {
        let n_shards = self.map.n_shards();
        let n_jobs = jobs.len();
        let fail_fast = matches!(opts.degraded, DegradedPolicy::FailFast);
        self.seq += 1;
        self.stats.rounds += 1;
        let seq = self.seq;
        let round = Arc::new(Round {
            jobs: jobs
                .iter()
                .map(|j| OwnedJob {
                    query: j.query.to_vec(),
                    lists: j.lists.to_vec(),
                    lut: j.lut.to_vec(),
                    nprobe: j.nprobe,
                })
                .collect(),
            codebook: self.shared_codebook(codebook),
        });
        let (tx, rx): (Sender<ScanReply>, Receiver<ScanReply>) = channel();

        let health_aware = self.cfg.select == SelectPolicy::HealthAware;
        let hedge_deadline: Option<Duration> = self.cfg.hedge.and_then(|h| {
            self.health
                .deadline_s(h.quantile)
                .map(|d| Duration::from_secs_f64(d).max(h.floor))
        });

        // Seed every shard with its primary attempt, plus at most one
        // probation probe to a breaker-open replica whose backoff is up.
        let now = Instant::now();
        let mut states: Vec<ShardRound> = Vec::with_capacity(n_shards);
        let mut remaining = 0usize;
        let mut probes_out = 0usize;
        for shard in 0..n_shards {
            let cands = self.health.order(&self.map.replicas(shard), health_aware);
            let mut st = ShardRound {
                cands,
                next: 0,
                outstanding: Vec::new(),
                done: None,
                failed: false,
                probe_result: None,
                hedge_at: hedge_deadline.map(|d| now + d),
                timeout_at: now + self.cfg.attempt_timeout,
                last_err: None,
            };
            let seeded =
                send_next(&self.workers, &mut st, Attempt::Primary, seq, shard, &round, &tx);
            if seeded {
                self.stats.attempts += 1;
                remaining += 1;
                let probe_cand = st.cands.iter().copied().find(|&id| {
                    self.health.probe_due(id)
                        && !st.outstanding.iter().any(|&(o, _, _)| o == id)
                });
                if let Some(id) = probe_cand {
                    if self.health.begin_probe(id)
                        && send_to(&self.workers, id, &mut st, Attempt::Probe, seq, shard, &round, &tx)
                    {
                        self.stats.attempts += 1;
                        self.stats.probes += 1;
                        probes_out += 1;
                    }
                }
            } else if fail_fast {
                anyhow::bail!(
                    "shard {shard} has no reachable replica (epoch {})",
                    self.map.epoch()
                );
            } else {
                st.failed = true;
            }
            states.push(st);
        }

        // Event loop: replies, hedge deadlines, forced-failover timeouts,
        // the round deadline, and a short probe-drain grace at the end.
        let mut drain_started: Option<Instant> = None;
        'round: while remaining > 0 || probes_out > 0 {
            let now = Instant::now();
            // End-to-end deadline: abandon every unresolved shard and
            // stop waiting for probes. Abandonment is NOT a node failure
            // — the budget ran out, not the replica.
            if let Some(dl) = opts.deadline {
                if now >= dl {
                    let mut expired = 0usize;
                    for st in states.iter_mut() {
                        if st.done.is_none() && !st.failed {
                            st.failed = true;
                            expired += 1;
                            remaining -= 1;
                            self.stats.deadline_expired_shards += 1;
                        }
                    }
                    if fail_fast && expired > 0 {
                        anyhow::bail!(
                            "round deadline expired with {expired} shard(s) unanswered"
                        );
                    }
                    break 'round;
                }
            }
            // Probe drain: the round itself is resolved; wait only a
            // short grace for outstanding probes instead of stalling the
            // caller on a wedged node.
            if remaining == 0 {
                let t0 = *drain_started.get_or_insert(now);
                if now >= t0 + PROBE_DRAIN {
                    break 'round;
                }
            }
            let mut next_event: Option<Instant> = None;
            for shard in 0..n_shards {
                let st = &mut states[shard];
                if st.done.is_some() || st.failed {
                    continue;
                }
                // Hedge: fire a duplicate scan once the deadline passes —
                // but only if the round's remaining budget could still
                // fit the duplicate (pricing against the recent-latency
                // quantile the hedge deadline itself came from).
                if let Some(h) = st.hedge_at {
                    if now >= h {
                        st.hedge_at = None;
                        let est = hedge_deadline.unwrap_or(Duration::ZERO);
                        let affordable =
                            opts.deadline.map_or(true, |dl| now + est <= dl);
                        if affordable {
                            let fired = send_next(
                                &self.workers, st, Attempt::Hedge, seq, shard, &round, &tx,
                            );
                            if fired {
                                self.stats.attempts += 1;
                                self.stats.hedges += 1;
                            }
                        }
                    } else {
                        next_event = Some(next_event.map_or(h, |e| e.min(h)));
                    }
                }
                // Forced failover: a shard with replies outstanding past
                // the attempt timeout counts them failed and moves on —
                // and once every replica has been tried, the shard is
                // abandoned (failing the round under FailFast) rather
                // than waited on forever (the bounded-detection contract;
                // socket-backed nodes error out earlier via their own
                // transport timeouts).
                if now >= st.timeout_at {
                    for (id, _, penalized) in st.outstanding.iter_mut() {
                        if !*penalized {
                            *penalized = true;
                            if self.health.record_failure(*id) {
                                self.stats.breaker_trips += 1;
                            }
                        }
                    }
                    if send_next(&self.workers, st, Attempt::Retry, seq, shard, &round, &tx) {
                        self.stats.attempts += 1;
                        self.stats.retries += 1;
                        st.timeout_at = now + self.cfg.attempt_timeout;
                    } else if fail_fast {
                        anyhow::bail!(
                            "shard {shard}: all replicas timed out or failed{}",
                            match &st.last_err {
                                Some(e) => format!(" (last error: {e:#})"),
                                None => String::new(),
                            }
                        );
                    } else {
                        st.failed = true;
                        remaining -= 1;
                        continue;
                    }
                }
                let t = st.timeout_at;
                next_event = Some(next_event.map_or(t, |e| e.min(t)));
            }

            let wait = match next_event {
                Some(t) => t
                    .saturating_duration_since(Instant::now())
                    .max(Duration::from_micros(50)),
                None => Duration::from_millis(25),
            };
            // Never sleep past the round deadline or the probe grace.
            let wait = match opts.deadline {
                Some(dl) => wait.min(
                    dl.saturating_duration_since(Instant::now())
                        .max(Duration::from_micros(50)),
                ),
                None => wait,
            };
            let reply = match rx.recv_timeout(wait) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all cluster workers exited mid-round")
                }
            };
            if reply.seq != seq || reply.shard >= n_shards {
                // Defensive: replies from an older round come over that
                // round's own (dropped) channel, so this never fires —
                // but a bug there must not corrupt this round.
                continue;
            }
            if let Some(cpu) = reply.cpu {
                self.pinned.insert(reply.node, cpu);
            }
            let st = &mut states[reply.shard];
            let attempt = match st
                .outstanding
                .iter()
                .position(|&(id, _, _)| id == reply.node)
            {
                Some(i) => st.outstanding.remove(i).1,
                None => Attempt::Primary,
            };
            if attempt == Attempt::Probe {
                probes_out -= 1;
                match reply.result {
                    Ok(results) => {
                        // Held for the bit-identity comparison after the
                        // round resolves (or adoption if no regular
                        // replica ends up answering).
                        st.probe_result = Some((reply.node, results, reply.latency_s));
                    }
                    Err(e) => {
                        // Failed probe: re-opens with doubled backoff.
                        if self.health.record_failure(reply.node) {
                            self.stats.breaker_trips += 1;
                        }
                        st.last_err = Some(e);
                    }
                }
                continue;
            }
            match reply.result {
                Ok(results) => {
                    self.health.record_ok(reply.node, reply.latency_s);
                    if st.done.is_some() || st.failed {
                        // A hedge/retry raced and lost (or its shard was
                        // already abandoned); its latency still warmed
                        // the health window above.
                        self.stats.late_responses += 1;
                        continue;
                    }
                    anyhow::ensure!(
                        results.len() == n_jobs,
                        "node {} answered {} results for {} jobs",
                        reply.node,
                        results.len(),
                        n_jobs
                    );
                    st.done = Some(results);
                    remaining -= 1;
                    match attempt {
                        Attempt::Hedge => self.stats.hedge_wins += 1,
                        Attempt::Retry => self.stats.failovers += 1,
                        Attempt::Primary | Attempt::Probe => {}
                    }
                }
                Err(e) => {
                    if self.health.record_failure(reply.node) {
                        self.stats.breaker_trips += 1;
                    }
                    if st.done.is_some() || st.failed {
                        self.stats.late_responses += 1;
                        continue;
                    }
                    st.last_err = Some(e);
                    // Retry immediately unless another attempt (e.g. a
                    // hedge) is still in flight for this shard.
                    if st.outstanding.is_empty() {
                        let sent = send_next(
                            &self.workers,
                            st,
                            Attempt::Retry,
                            seq,
                            reply.shard,
                            &round,
                            &tx,
                        );
                        if sent {
                            self.stats.attempts += 1;
                            self.stats.retries += 1;
                            st.timeout_at = Instant::now() + self.cfg.attempt_timeout;
                        } else if fail_fast {
                            anyhow::bail!(
                                "shard {} failed on all replicas: {:#}",
                                reply.shard,
                                st.last_err.take().expect("just set")
                            );
                        } else {
                            st.failed = true;
                            remaining -= 1;
                        }
                    }
                }
            }
        }

        // Resolve probation: compare every held probe result against its
        // shard's winner (bit-identity decides whether the replica
        // rejoins), adopt it outright when no regular replica answered,
        // and fail probes that never replied — no node may be stranded in
        // half-open past the round.
        for st in states.iter_mut() {
            let unanswered_probe = st
                .outstanding
                .iter()
                .any(|&(_, attempt, _)| attempt == Attempt::Probe);
            if let Some((id, results, latency_s)) = st.probe_result.take() {
                if st.done.is_none() && results.len() == n_jobs {
                    // The probed replica is the only one that answered:
                    // adopt its result — probation recovery of a shard
                    // whose regular replicas are all dark.
                    self.health.record_ok(id, latency_s);
                    st.done = Some(results);
                    st.failed = false;
                    self.stats.failovers += 1;
                } else if st
                    .done
                    .as_ref()
                    .is_some_and(|d| results_identical(d, &results))
                {
                    self.health.record_ok(id, latency_s);
                } else {
                    self.stats.probe_mismatches += 1;
                    if self.health.record_failure(id) {
                        self.stats.breaker_trips += 1;
                    }
                }
            } else if unanswered_probe {
                for &(id, attempt, _) in st.outstanding.iter() {
                    if attempt == Attempt::Probe {
                        if self.health.record_failure(id) {
                            self.stats.breaker_trips += 1;
                        }
                    }
                }
            }
        }

        let shards_answered = states.iter().filter(|s| s.done.is_some()).count();
        if let DegradedPolicy::ServePartial { min_coverage } = opts.degraded {
            let coverage = if n_shards == 0 {
                1.0
            } else {
                shards_answered as f64 / n_shards as f64
            };
            if coverage + 1e-9 < min_coverage.clamp(0.0, 1.0) {
                anyhow::bail!(
                    "degraded round coverage {coverage:.3} below floor {min_coverage:.3} \
                     ({shards_answered}/{n_shards} shards answered{})",
                    match states.iter().find_map(|s| s.last_err.as_ref()) {
                        Some(e) => format!("; last error: {e:#}"),
                        None => String::new(),
                    }
                );
            }
            if shards_answered < n_shards {
                self.stats.partial_rounds += 1;
                self.stats.unanswered_shards += (n_shards - shards_answered) as u64;
            }
        }

        // Transpose [shard][job] -> [job][answered shard]; shard order
        // preserved, unanswered shards omitted.
        let mut per_job: Vec<Vec<NodeResult>> =
            (0..n_jobs).map(|_| Vec::with_capacity(n_shards)).collect();
        for st in states {
            if let Some(results) = st.done {
                for (j, r) in results.into_iter().enumerate() {
                    per_job[j].push(r);
                }
            }
        }
        Ok(RoundOutcome {
            per_job,
            shards_answered: shards_answered as u32,
            n_shards: n_shards as u32,
        })
    }
}

impl Drop for ClusterEngine {
    fn drop(&mut self) {
        // Workers detach (connections close; backends are not killed) —
        // matching the flat dispatcher, where dropping never sends
        // Shutdown frames. Use `shutdown_all` to kill remote processes.
        self.workers.clear();
    }
}

/// The carve-plan node set for an in-process cluster: `n_shards =
/// n_nodes / replication` fresh [`MemoryNode`]s over [`Shard::carve`]
/// slices. Shared by [`ClusterEngine::local`] and
/// [`ClusterEngine::rebalance_local`] so the build and rebalance paths
/// cannot drift apart.
fn local_nodes(
    index: &IvfPqIndex,
    n_nodes: usize,
    replication: usize,
    k: usize,
) -> Result<(Vec<ClusterNode>, usize)> {
    let plan = ClusterMap::carve_plan(n_nodes, replication)?;
    let n_shards = n_nodes / replication;
    let nodes = plan
        .into_iter()
        .map(|(id, shard)| ClusterNode {
            id,
            shard,
            backend: Box::new(MemoryNode::new(
                Shard::carve(index, shard, n_shards),
                ScanEngine::Native,
                k,
            )) as Box<dyn ScanBackend>,
        })
        .collect();
    Ok((nodes, n_shards))
}

/// How long a resolved round waits for its outstanding probation probes
/// before abandoning them (an abandoned probe counts as a failed one) —
/// a wedged half-open node must not stall an otherwise-fast round.
const PROBE_DRAIN: Duration = Duration::from_millis(250);

/// Bit-identity comparison for probation: a probed replica rejoins only
/// if its per-job top-K (distances AND ids) matches the winner exactly.
fn results_identical(a: &[NodeResult], b: &[NodeResult]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.topk == y.topk)
}

/// Send a scan command to one *specific* replica (probation probes target
/// the half-open node directly) without advancing the shard's failover
/// cursor. Returns false when the node has no live worker.
#[allow(clippy::too_many_arguments)]
fn send_to(
    workers: &BTreeMap<NodeId, Worker>,
    id: NodeId,
    st: &mut ShardRound,
    attempt: Attempt,
    seq: u64,
    shard: usize,
    round: &Arc<Round>,
    reply: &Sender<ScanReply>,
) -> bool {
    if let Some(w) = workers.get(&id) {
        let cmd = Command::Scan {
            seq,
            shard,
            round: round.clone(),
            reply: reply.clone(),
        };
        if w.tx.send(cmd).is_ok() {
            st.outstanding.push((id, attempt, false));
            return true;
        }
    }
    false
}

/// Send the shard's next untried candidate a scan command. Returns false
/// when every candidate has been tried (or has no live worker).
#[allow(clippy::too_many_arguments)]
fn send_next(
    workers: &BTreeMap<NodeId, Worker>,
    st: &mut ShardRound,
    attempt: Attempt,
    seq: u64,
    shard: usize,
    round: &Arc<Round>,
    reply: &Sender<ScanReply>,
) -> bool {
    while st.next < st.cands.len() {
        let id = st.cands[st.next];
        st.next += 1;
        if let Some(w) = workers.get(&id) {
            let cmd = Command::Scan {
                seq,
                shard,
                round: round.clone(),
                reply: reply.clone(),
            };
            if w.tx.send(cmd).is_ok() {
                st.outstanding.push((id, attempt, false));
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fault::{FailingBackend, OutageBackend, StragglerBackend};
    use crate::util::rng::Rng;

    fn toy_index() -> (IvfPqIndex, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (2400, 32, 8, 24);
        let data = rng.normal_vec(n * d);
        (IvfPqIndex::build(&data, n, d, m, nlist, 3), d)
    }

    fn run_query(
        engine: &mut ClusterEngine,
        idx: &IvfPqIndex,
        q: &[f32],
    ) -> Result<Vec<Vec<NodeResult>>> {
        let lists = idx.probe(q, 6);
        let lut = crate::pq::scan::build_lut(&idx.pq, q);
        let jobs = [ScanJob { query: q, lists: &lists, lut: &lut, nprobe: 6 }];
        engine.run_round(&jobs, &idx.pq.centroids)
    }

    #[test]
    fn round_shape_matches_shard_count() {
        let (idx, d) = toy_index();
        let mut engine = ClusterEngine::local(&idx, 4, 2, 10, ClusterConfig::default()).unwrap();
        assert_eq!(engine.n_shards(), 2);
        let mut rng = Rng::new(7);
        let q = rng.normal_vec(d);
        let per_job = run_query(&mut engine, &idx, &q).unwrap();
        assert_eq!(per_job.len(), 1);
        assert_eq!(per_job[0].len(), 2, "one result per shard");
        assert_eq!(engine.stats().rounds, 1);
        assert_eq!(engine.stats().retries, 0);
    }

    #[test]
    fn failover_retries_on_replica() {
        let (idx, d) = toy_index();
        // Shard 0 primary dies after one call; its replica must take over
        // with identical results.
        let n_shards = 2;
        let mk = |shard: usize| {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, shard, n_shards),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        let nodes = vec![
            ClusterNode { id: 0, shard: 0, backend: Box::new(FailingBackend::new(mk(0), 1)) },
            ClusterNode { id: 1, shard: 0, backend: mk(0) },
            ClusterNode { id: 2, shard: 1, backend: mk(1) },
            ClusterNode { id: 3, shard: 1, backend: mk(1) },
        ];
        let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
        let mut engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
        let mut rng = Rng::new(9);
        let q = rng.normal_vec(d);
        let healthy = run_query(&mut engine, &idx, &q).unwrap();
        let after = run_query(&mut engine, &idx, &q).unwrap();
        assert_eq!(healthy[0].len(), after[0].len());
        for (a, b) in healthy[0].iter().zip(&after[0]) {
            assert_eq!(a.topk, b.topk, "failover result must be bit-identical");
        }
        assert!(engine.stats().retries >= 1);
        assert!(engine.stats().failovers >= 1);
    }

    #[test]
    fn breaker_routes_away_after_consecutive_failures() {
        let (idx, d) = toy_index();
        let mk = || {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, 0, 1),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        let nodes = vec![
            ClusterNode { id: 0, shard: 0, backend: Box::new(FailingBackend::new(mk(), 0)) },
            ClusterNode { id: 1, shard: 0, backend: mk() },
        ];
        let cfg = ClusterConfig {
            select: SelectPolicy::Static,
            breaker_threshold: 2,
            ..Default::default()
        };
        let mut engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
        let mut rng = Rng::new(5);
        // Static order for shard 0 is [0, 1]: node 0 fails every call.
        for _ in 0..3 {
            let q = rng.normal_vec(d);
            run_query(&mut engine, &idx, &q).unwrap();
        }
        assert!(engine.health().breaker_open(0), "breaker must be open");
        assert_eq!(engine.stats().breaker_trips, 1);
        let retries_so_far = engine.stats().retries;
        // With the breaker open, node 1 is selected first: no new retries.
        let q = rng.normal_vec(d);
        run_query(&mut engine, &idx, &q).unwrap();
        assert_eq!(engine.stats().retries, retries_so_far);
    }

    #[test]
    fn all_replicas_dead_fails_the_round() {
        let (idx, d) = toy_index();
        let mk = || {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, 0, 1),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        let nodes = vec![
            ClusterNode { id: 0, shard: 0, backend: Box::new(FailingBackend::new(mk(), 0)) },
            ClusterNode { id: 1, shard: 0, backend: Box::new(FailingBackend::new(mk(), 0)) },
        ];
        let mut engine = ClusterEngine::new(nodes, 1, ClusterConfig::default()).unwrap();
        let mut rng = Rng::new(6);
        let q = rng.normal_vec(d);
        assert!(run_query(&mut engine, &idx, &q).is_err());
    }

    #[test]
    fn membership_transitions_take_effect_next_round() {
        let (idx, d) = toy_index();
        let mut engine = ClusterEngine::local(&idx, 2, 1, 10, ClusterConfig::default()).unwrap();
        let e0 = engine.epoch();
        // Join a replica for shard 0, then drain + remove the original.
        let replica = ClusterNode {
            id: 10,
            shard: 0,
            backend: Box::new(MemoryNode::new(
                Shard::carve(&idx, 0, 2),
                ScanEngine::Native,
                10,
            )),
        };
        assert_eq!(engine.join(replica).unwrap(), e0 + 1);
        assert_eq!(engine.drain(0).unwrap(), e0 + 2);
        let mut rng = Rng::new(8);
        let q = rng.normal_vec(d);
        let r = run_query(&mut engine, &idx, &q).unwrap();
        assert_eq!(r[0].len(), 2);
        assert_eq!(engine.remove(0).unwrap(), e0 + 3);
        let r2 = run_query(&mut engine, &idx, &q).unwrap();
        for (a, b) in r[0].iter().zip(&r2[0]) {
            assert_eq!(a.topk, b.topk, "results stable across the epoch swap");
        }
    }

    #[test]
    fn rebalance_recarves_and_preserves_results() {
        let (idx, d) = toy_index();
        let mut engine = ClusterEngine::local(&idx, 2, 1, 10, ClusterConfig::default()).unwrap();
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 6);
        let lut = crate::pq::scan::build_lut(&idx.pq, &q);
        let jobs = [ScanJob { query: &q, lists: &lists, lut: &lut, nprobe: 6 }];
        let before = engine.run_round(&jobs, &idx.pq.centroids).unwrap();
        let merged_before = crate::chamvs::dispatcher::merge_topk(&before[0], 10);
        let e = engine.rebalance_local(&idx, 4, 1, 10).unwrap();
        assert!(e > 2, "epoch stays monotonic");
        assert_eq!(engine.n_shards(), 4);
        let after = engine.run_round(&jobs, &idx.pq.centroids).unwrap();
        assert_eq!(after[0].len(), 4);
        let merged_after = crate::chamvs::dispatcher::merge_topk(&after[0], 10);
        assert_eq!(
            merged_before, merged_after,
            "re-carved cluster must serve identical top-k"
        );
    }

    #[test]
    fn serve_partial_covers_live_shards_when_one_is_dark() {
        let (idx, d) = toy_index();
        let n_shards = 2;
        let mk = |shard: usize| {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, shard, n_shards),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        // Both replicas of shard 0 are dead from the first call; shard 1
        // is healthy.
        let nodes = vec![
            ClusterNode { id: 0, shard: 0, backend: Box::new(FailingBackend::new(mk(0), 0)) },
            ClusterNode { id: 1, shard: 0, backend: Box::new(FailingBackend::new(mk(0), 0)) },
            ClusterNode { id: 2, shard: 1, backend: mk(1) },
            ClusterNode { id: 3, shard: 1, backend: mk(1) },
        ];
        let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
        let mut engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
        let mut rng = Rng::new(12);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 6);
        let lut = crate::pq::scan::build_lut(&idx.pq, &q);
        let jobs = [ScanJob { query: &q, lists: &lists, lut: &lut, nprobe: 6 }];
        let opts = RoundOptions {
            degraded: DegradedPolicy::ServePartial { min_coverage: 0.5 },
            ..Default::default()
        };
        let out = engine.run_round_opts(&jobs, &idx.pq.centroids, &opts).unwrap();
        assert_eq!(out.n_shards, 2);
        assert_eq!(out.shards_answered, 1);
        assert!(out.is_partial());
        assert!((out.coverage() - 0.5).abs() < 1e-9);
        assert_eq!(out.per_job[0].len(), 1, "only the live shard contributes");
        let stats = engine.stats();
        assert_eq!(stats.partial_rounds, 1);
        assert_eq!(stats.unanswered_shards, 1);
        // A floor above the achievable coverage fails the round instead.
        let opts = RoundOptions {
            degraded: DegradedPolicy::ServePartial { min_coverage: 0.9 },
            ..Default::default()
        };
        assert!(engine.run_round_opts(&jobs, &idx.pq.centroids, &opts).is_err());
    }

    #[test]
    fn deadline_bounds_a_straggling_round() {
        let (idx, d) = toy_index();
        let mk = || {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, 0, 1),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        // The shard's only replica sleeps far past the deadline on every
        // scan; the attempt timeout is set high so only the round deadline
        // can end the wait.
        let slow = Box::new(StragglerBackend::new(mk(), Duration::from_millis(400), 1));
        let nodes = vec![ClusterNode { id: 0, shard: 0, backend: slow }];
        let cfg = ClusterConfig {
            select: SelectPolicy::Static,
            attempt_timeout: Duration::from_secs(5),
            ..Default::default()
        };
        let mut engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
        let mut rng = Rng::new(13);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 6);
        let lut = crate::pq::scan::build_lut(&idx.pq, &q);
        let jobs = [ScanJob { query: &q, lists: &lists, lut: &lut, nprobe: 6 }];
        let t0 = Instant::now();
        let opts = RoundOptions {
            degraded: DegradedPolicy::ServePartial { min_coverage: 0.0 },
            deadline: Some(Instant::now() + Duration::from_millis(40)),
        };
        let out = engine.run_round_opts(&jobs, &idx.pq.centroids, &opts).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(300),
            "deadline must bound the round, took {:?}",
            t0.elapsed()
        );
        assert_eq!(out.shards_answered, 0);
        assert_eq!(engine.stats().deadline_expired_shards, 1);
        assert!(
            !engine.health().breaker_open(0),
            "deadline expiry is a budget event, not a node failure"
        );
        // Under FailFast the expired deadline is an error instead.
        let opts = RoundOptions {
            degraded: DegradedPolicy::FailFast,
            deadline: Some(Instant::now() + Duration::from_millis(40)),
        };
        assert!(engine.run_round_opts(&jobs, &idx.pq.centroids, &opts).is_err());
    }

    #[test]
    fn probation_probe_rejoins_node_with_bit_identical_results() {
        let (idx, d) = toy_index();
        let mk = || {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, 0, 1),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        // Node 0 fails its first two scans (opening the breaker at
        // threshold 2), then heals; node 1 stays healthy throughout.
        let nodes = vec![
            ClusterNode { id: 0, shard: 0, backend: Box::new(OutageBackend::new(mk(), 0, 2)) },
            ClusterNode { id: 1, shard: 0, backend: mk() },
        ];
        let cfg = ClusterConfig {
            select: SelectPolicy::Static,
            breaker_threshold: 2,
            ..Default::default()
        };
        let mut engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
        engine.health_mut().breaker_backoff = Duration::from_millis(5);
        let mut rng = Rng::new(14);
        let q = rng.normal_vec(d);
        let r1 = run_query(&mut engine, &idx, &q).unwrap();
        let r2 = run_query(&mut engine, &idx, &q).unwrap();
        assert!(engine.health().breaker_open(0), "breaker open after threshold");
        // Wait out the probation backoff, then run a round: node 1 serves
        // it while node 0 gets its one probe (now healed), which must
        // match the winner bit-identically before the breaker closes.
        std::thread::sleep(Duration::from_millis(20));
        let r3 = run_query(&mut engine, &idx, &q).unwrap();
        assert_eq!(engine.stats().probes, 1);
        assert_eq!(engine.stats().probe_mismatches, 0);
        assert!(
            !engine.health().breaker_open(0),
            "identical probe result closes the breaker"
        );
        for (a, b) in r1[0].iter().zip(&r3[0]).chain(r2[0].iter().zip(&r3[0])) {
            assert_eq!(a.topk, b.topk, "results stable through outage and rejoin");
        }
    }
}
