//! Fault-injection scan backends for failover and tail-latency testing:
//! deterministic wrappers that make a healthy backend die or straggle on
//! cue. Used by the failure tests, `benches/cluster_failover.rs` and the
//! `chameleon cluster` demo — they live in the library (not `#[cfg(test)]`)
//! so benches and the CLI can inject the same faults the tests pin.

use std::time::Duration;

use anyhow::Result;

use crate::chamvs::backend::{ScanBackend, ScanJob};
use crate::chamvs::node::NodeResult;
use crate::hwmodel::fpga::FpgaModel;

/// A backend that serves `healthy_calls` scans, then fails every scan
/// after — the in-process model of a node dying mid-workload.
pub struct FailingBackend {
    inner: Box<dyn ScanBackend>,
    healthy_calls: usize,
    calls: usize,
}

impl FailingBackend {
    pub fn new(inner: Box<dyn ScanBackend>, healthy_calls: usize) -> FailingBackend {
        FailingBackend { inner, healthy_calls, calls: 0 }
    }

    /// Scan calls observed (healthy + failed).
    pub fn calls(&self) -> usize {
        self.calls
    }
}

impl ScanBackend for FailingBackend {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn fpga(&self) -> &FpgaModel {
        self.inner.fpga()
    }

    fn wants_lut(&self) -> bool {
        self.inner.wants_lut()
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>> {
        self.calls += 1;
        anyhow::ensure!(
            self.calls <= self.healthy_calls,
            "injected fault: node is down (call {} > {} healthy)",
            self.calls,
            self.healthy_calls
        );
        self.inner.scan_jobs(jobs, codebook)
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }

    fn drain(&mut self) {
        self.inner.drain();
    }
}

/// A backend that fails every scan inside the call window
/// `[down_from, down_to)` (0-based call index) and serves normally
/// outside it — a transient outage that heals, for exercising the
/// breaker's half-open probation and rejoin path.
pub struct OutageBackend {
    inner: Box<dyn ScanBackend>,
    down_from: usize,
    down_to: usize,
    calls: usize,
}

impl OutageBackend {
    pub fn new(
        inner: Box<dyn ScanBackend>,
        down_from: usize,
        down_to: usize,
    ) -> OutageBackend {
        OutageBackend { inner, down_from, down_to, calls: 0 }
    }

    /// Scan calls observed (healthy + failed).
    pub fn calls(&self) -> usize {
        self.calls
    }
}

impl ScanBackend for OutageBackend {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn fpga(&self) -> &FpgaModel {
        self.inner.fpga()
    }

    fn wants_lut(&self) -> bool {
        self.inner.wants_lut()
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>> {
        let call = self.calls;
        self.calls += 1;
        anyhow::ensure!(
            call < self.down_from || call >= self.down_to,
            "injected fault: node is down (outage window {}..{}, call {call})",
            self.down_from,
            self.down_to
        );
        self.inner.scan_jobs(jobs, codebook)
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }

    fn drain(&mut self) {
        self.inner.drain();
    }
}

/// A backend that sleeps `delay` before every `every`-th scan — an
/// intermittent straggler (GC pause, page fault storm, noisy neighbor)
/// that selection alone cannot route around, which is exactly the case
/// hedged dispatch exists for.
pub struct StragglerBackend {
    inner: Box<dyn ScanBackend>,
    delay: Duration,
    every: usize,
    calls: usize,
}

impl StragglerBackend {
    pub fn new(inner: Box<dyn ScanBackend>, delay: Duration, every: usize) -> StragglerBackend {
        StragglerBackend { inner, delay, every: every.max(1), calls: 0 }
    }
}

impl ScanBackend for StragglerBackend {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn fpga(&self) -> &FpgaModel {
        self.inner.fpga()
    }

    fn wants_lut(&self) -> bool {
        self.inner.wants_lut()
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>> {
        self.calls += 1;
        if self.calls % self.every == 0 {
            std::thread::sleep(self.delay);
        }
        self.inner.scan_jobs(jobs, codebook)
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }

    fn drain(&mut self) {
        self.inner.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::node::{MemoryNode, ScanEngine};
    use crate::ivf::index::IvfPqIndex;
    use crate::ivf::shard::Shard;
    use crate::pq::scan::build_lut;
    use crate::util::rng::Rng;

    fn node() -> (Box<dyn ScanBackend>, IvfPqIndex, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (1200, 16, 4, 16);
        let data = rng.normal_vec(n * d);
        let idx = IvfPqIndex::build(&data, n, d, m, nlist, 2);
        let node = MemoryNode::new(Shard::carve(&idx, 0, 1), ScanEngine::Native, 10);
        (Box::new(node), idx, d)
    }

    #[test]
    fn failing_backend_dies_on_cue() {
        let (inner, idx, d) = node();
        let mut b = FailingBackend::new(inner, 2);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let lut = build_lut(&idx.pq, &q);
        let jobs = [ScanJob { query: &q, lists: &lists, lut: &lut, nprobe: 4 }];
        assert!(b.scan_jobs(&jobs, &idx.pq.centroids).is_ok());
        assert!(b.scan_jobs(&jobs, &idx.pq.centroids).is_ok());
        assert!(b.scan_jobs(&jobs, &idx.pq.centroids).is_err(), "third call fails");
        assert!(b.scan_jobs(&jobs, &idx.pq.centroids).is_err(), "stays down");
        assert_eq!(b.calls(), 4);
    }

    #[test]
    fn straggler_preserves_results() {
        let (inner, idx, d) = node();
        let mut rng = Rng::new(5);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let lut = build_lut(&idx.pq, &q);
        let jobs = [ScanJob { query: &q, lists: &lists, lut: &lut, nprobe: 4 }];
        let (mut plain, _idx2, _d2) = node();
        let want = plain.scan_jobs(&jobs, &idx.pq.centroids).unwrap();
        let mut slow =
            StragglerBackend::new(node().0, Duration::from_micros(200), 1);
        let got = slow.scan_jobs(&jobs, &idx.pq.centroids).unwrap();
        assert_eq!(got[0].topk, want[0].topk, "delay must not change numerics");
    }
}
