//! Per-node health tracking for the elastic retrieval tier: a scan-latency
//! EWMA per node, a consecutive-failure circuit breaker, and the recent
//! round-trip latency window that prices hedge deadlines.
//!
//! Fed from dispatch results by the cluster engine: every reply records a
//! success (with its coordinator-observed round-trip latency) or a
//! failure. The breaker opens after `breaker_threshold` *consecutive*
//! failures — an open node is deprioritized by replica selection (tried
//! only when every closed replica is exhausted). Recovery goes through
//! **half-open probation**: once the breaker's backoff elapses, exactly
//! one probe query is admitted ([`begin_probe`](HealthTracker::begin_probe));
//! a failed probe re-opens the breaker with a doubled backoff, a
//! successful one (the engine additionally demands bit-identical results
//! against a healthy replica) closes it and restores selection weight —
//! so a node that recovers rejoins the rotation without an operator
//! transition, and a flapping node is retried ever more rarely.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::map::NodeId;
use crate::util::stats::percentile;

/// Recent-latency window size for hedge-deadline quantiles.
const RECENT_CAP: usize = 512;

/// Ceiling on the breaker's re-open backoff (doubles per failed probe).
const BREAKER_BACKOFF_CAP: Duration = Duration::from_secs(10);

/// Circuit-breaker state of one node.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Breaker {
    /// Healthy: full selection weight.
    #[default]
    Closed,
    /// Tripped: deprioritized until `until`, when one probe may run.
    Open { until: Instant, backoff: Duration },
    /// Probation: the one admitted probe is in flight; no other traffic
    /// is steered here until it reports.
    HalfOpen { backoff: Duration },
}

/// Health state of one node.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeHealth {
    /// EWMA of coordinator-observed scan round-trip latency (seconds);
    /// 0.0 until the first sample.
    pub ewma_s: f64,
    /// Successful scans recorded.
    pub ok: u64,
    /// Failed scans recorded.
    pub failures: u64,
    /// Current consecutive-failure run length.
    pub consecutive_failures: u32,
    /// Circuit-breaker state (non-`Closed` nodes are deprioritized).
    pub breaker: Breaker,
}

/// Health registry over the cluster's nodes.
#[derive(Clone, Debug)]
pub struct HealthTracker {
    nodes: BTreeMap<NodeId, NodeHealth>,
    /// EWMA weight of a new sample.
    pub alpha: f64,
    /// Consecutive failures that open the breaker.
    pub breaker_threshold: u32,
    /// First probation backoff after the breaker opens; doubles on every
    /// failed probe, capped at [`BREAKER_BACKOFF_CAP`].
    pub breaker_backoff: Duration,
    /// Recent successful round-trip latencies across all nodes (ring).
    recent: VecDeque<f64>,
}

impl Default for HealthTracker {
    fn default() -> HealthTracker {
        HealthTracker {
            nodes: BTreeMap::new(),
            alpha: 0.2,
            breaker_threshold: 3,
            breaker_backoff: Duration::from_millis(200),
            recent: VecDeque::new(),
        }
    }
}

impl HealthTracker {
    pub fn new(breaker_threshold: u32) -> HealthTracker {
        HealthTracker { breaker_threshold: breaker_threshold.max(1), ..Default::default() }
    }

    /// Record a successful scan and its round-trip latency. Resets the
    /// consecutive-failure run and closes the breaker — from `HalfOpen`
    /// this is the probe succeeding, which ends probation and restores
    /// full selection weight.
    pub fn record_ok(&mut self, id: NodeId, latency_s: f64) {
        let h = self.nodes.entry(id).or_default();
        h.ewma_s = if h.ok == 0 {
            latency_s
        } else {
            self.alpha * latency_s + (1.0 - self.alpha) * h.ewma_s
        };
        h.ok += 1;
        h.consecutive_failures = 0;
        h.breaker = Breaker::Closed;
        self.recent.push_back(latency_s);
        while self.recent.len() > RECENT_CAP {
            self.recent.pop_front();
        }
    }

    /// Record a failed scan. Returns `true` iff this failure tripped the
    /// breaker open (the threshold crossing or a failed probe re-opening
    /// it — not every failure beyond them). A failure during `HalfOpen`
    /// probation re-opens with a *doubled* backoff, so a flapping node
    /// gets exponentially rarer probes.
    pub fn record_failure(&mut self, id: NodeId) -> bool {
        let threshold = self.breaker_threshold;
        let base = self.breaker_backoff;
        let h = self.nodes.entry(id).or_default();
        h.failures += 1;
        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
        match h.breaker {
            Breaker::Closed if h.consecutive_failures >= threshold => {
                h.breaker = Breaker::Open { until: Instant::now() + base, backoff: base };
                true
            }
            Breaker::HalfOpen { backoff } => {
                let next = backoff.saturating_mul(2).min(BREAKER_BACKOFF_CAP);
                h.breaker = Breaker::Open { until: Instant::now() + next, backoff: next };
                true
            }
            _ => false,
        }
    }

    /// Whether the node is out of normal selection (breaker `Open` or in
    /// `HalfOpen` probation).
    pub fn breaker_open(&self, id: NodeId) -> bool {
        self.nodes.get(&id).map(|h| h.breaker != Breaker::Closed).unwrap_or(false)
    }

    /// The node's breaker state (`Closed` for unknown nodes).
    pub fn breaker(&self, id: NodeId) -> Breaker {
        self.nodes.get(&id).map(|h| h.breaker).unwrap_or_default()
    }

    /// Whether an open node's backoff has elapsed, making it eligible for
    /// a probation probe.
    pub fn probe_due(&self, id: NodeId) -> bool {
        matches!(
            self.nodes.get(&id).map(|h| h.breaker),
            Some(Breaker::Open { until, .. }) if Instant::now() >= until
        )
    }

    /// Admit the single probation probe for an open node whose backoff
    /// has elapsed: transitions `Open` → `HalfOpen` and returns `true`.
    /// Returns `false` for closed nodes, nodes still inside their
    /// backoff, and nodes whose probe is already in flight — so exactly
    /// one probe runs per backoff expiry no matter how many rounds race
    /// past it. Report the probe through [`record_ok`](Self::record_ok)
    /// (close) or [`record_failure`](Self::record_failure) (re-open,
    /// doubled backoff).
    pub fn begin_probe(&mut self, id: NodeId) -> bool {
        let Some(h) = self.nodes.get_mut(&id) else { return false };
        match h.breaker {
            Breaker::Open { until, backoff } if Instant::now() >= until => {
                h.breaker = Breaker::HalfOpen { backoff };
                true
            }
            _ => false,
        }
    }

    /// Latency EWMA, `None` before the first successful scan.
    pub fn ewma(&self, id: NodeId) -> Option<f64> {
        self.nodes.get(&id).filter(|h| h.ok > 0).map(|h| h.ewma_s)
    }

    pub fn node(&self, id: NodeId) -> Option<&NodeHealth> {
        self.nodes.get(&id)
    }

    /// Forget a removed node's history.
    pub fn forget(&mut self, id: NodeId) {
        self.nodes.remove(&id);
    }

    /// Hedge deadline: the `q`-quantile of recent successful round-trip
    /// latencies. `None` until enough samples exist to make the quantile
    /// meaningful (a cold cluster never hedges — it has no baseline to
    /// call a scan "late" against).
    pub fn deadline_s(&self, q: f64) -> Option<f64> {
        if self.recent.len() < 8 {
            return None;
        }
        let samples: Vec<f64> = self.recent.iter().copied().collect();
        Some(percentile(&samples, q))
    }

    /// Order replica candidates for selection: breaker-closed nodes first
    /// (health-sorted by EWMA when `health_aware`, otherwise in the given
    /// base order), breaker-open nodes last as the availability fallback.
    pub fn order(&self, candidates: &[NodeId], health_aware: bool) -> Vec<NodeId> {
        let mut closed: Vec<NodeId> = Vec::with_capacity(candidates.len());
        let mut open: Vec<NodeId> = Vec::new();
        for &id in candidates {
            if self.breaker_open(id) {
                open.push(id);
            } else {
                closed.push(id);
            }
        }
        if health_aware {
            // Unmeasured nodes sort first (ewma 0.0): give fresh joiners
            // traffic so their EWMA warms up. Stable sort keeps the
            // rotation order among ties.
            closed.sort_by(|&a, &b| {
                let ea = self.ewma(a).unwrap_or(0.0);
                let eb = self.ewma(b).unwrap_or(0.0);
                ea.partial_cmp(&eb).unwrap_or(std::cmp::Ordering::Equal)
            });
        }
        closed.extend(open);
        closed
    }

    /// Human-readable health table for the `chameleon cluster` report.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from(
            "node   ewma_ms    ok       failures consec  breaker\n",
        );
        for (id, h) in &self.nodes {
            let _ = writeln!(
                out,
                "{id:<6} {:<10.4} {:<8} {:<8} {:<7} {}",
                h.ewma_s * 1e3,
                h.ok,
                h.failures,
                h.consecutive_failures,
                match h.breaker {
                    Breaker::Closed => "closed",
                    Breaker::Open { .. } => "OPEN",
                    Breaker::HalfOpen { .. } => "PROBE",
                }
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_tracks_latency() {
        let mut t = HealthTracker::default();
        t.record_ok(1, 1.0);
        assert!((t.ewma(1).unwrap() - 1.0).abs() < 1e-12, "first sample seeds");
        t.record_ok(1, 2.0);
        let e = t.ewma(1).unwrap();
        assert!(e > 1.0 && e < 2.0, "{e}");
        assert_eq!(t.ewma(2), None);
    }

    /// A tracker whose probation backoff is short enough for tests to
    /// wait out without slowing the suite.
    fn fast_tracker(threshold: u32) -> HealthTracker {
        let mut t = HealthTracker::new(threshold);
        t.breaker_backoff = Duration::from_millis(5);
        t
    }

    fn wait_probe_due(t: &HealthTracker, id: NodeId) {
        let t0 = Instant::now();
        while !t.probe_due(id) {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "probe never became due"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn breaker_opens_at_threshold_and_closes_on_success() {
        let mut t = HealthTracker::new(3);
        assert!(!t.record_failure(5));
        assert!(!t.record_failure(5));
        assert!(t.record_failure(5), "third consecutive failure trips");
        assert!(t.breaker_open(5));
        assert!(matches!(t.breaker(5), Breaker::Open { .. }));
        assert!(!t.record_failure(5), "already open: not a fresh trip");
        t.record_ok(5, 0.001);
        assert!(!t.breaker_open(5), "success closes the breaker");
        assert!(!t.record_failure(5), "run length was reset");
    }

    #[test]
    fn probation_admits_exactly_one_probe() {
        let mut t = fast_tracker(1);
        assert!(t.record_failure(7), "threshold 1 trips immediately");
        assert!(
            !t.begin_probe(7),
            "no probe inside the backoff window"
        );
        wait_probe_due(&t, 7);
        assert!(t.begin_probe(7), "first probe admitted after backoff");
        assert!(matches!(t.breaker(7), Breaker::HalfOpen { .. }));
        assert!(!t.probe_due(7), "half-open is not due again");
        assert!(
            !t.begin_probe(7),
            "second concurrent probe must be refused"
        );
        assert!(t.breaker_open(7), "probation still out of selection");
    }

    #[test]
    fn failed_probe_reopens_with_doubled_backoff() {
        let mut t = fast_tracker(1);
        t.record_failure(3);
        let Breaker::Open { backoff: first, .. } = t.breaker(3) else {
            panic!("breaker must be open");
        };
        wait_probe_due(&t, 3);
        assert!(t.begin_probe(3));
        assert!(t.record_failure(3), "failed probe re-opens the breaker");
        let Breaker::Open { backoff: second, .. } = t.breaker(3) else {
            panic!("breaker must re-open after a failed probe");
        };
        assert_eq!(second, first * 2, "backoff doubles per failed probe");
        // And doubles again on the next failed probe.
        wait_probe_due(&t, 3);
        assert!(t.begin_probe(3));
        assert!(t.record_failure(3));
        let Breaker::Open { backoff: third, .. } = t.breaker(3) else {
            panic!("breaker must re-open again");
        };
        assert_eq!(third, second * 2);
    }

    #[test]
    fn successful_probe_restores_selection_weight() {
        let mut t = fast_tracker(1);
        t.record_ok(1, 0.002);
        t.record_ok(2, 0.001);
        t.record_failure(1);
        // Out of selection while open: ordered last even under the
        // static policy that otherwise keeps base order.
        assert_eq!(t.order(&[1, 2], false), vec![2, 1]);
        wait_probe_due(&t, 1);
        assert!(t.begin_probe(1));
        t.record_ok(1, 0.0005);
        assert!(!t.breaker_open(1), "successful probe closes the breaker");
        assert!(
            matches!(t.breaker(1), Breaker::Closed),
            "probation over: full selection weight"
        );
        // Restored: back in the closed pool, base order again.
        assert_eq!(t.order(&[1, 2], false), vec![1, 2]);
    }

    #[test]
    fn order_prefers_closed_then_fast() {
        let mut t = HealthTracker::new(1);
        t.record_ok(1, 0.010);
        t.record_ok(2, 0.001);
        t.record_failure(3); // breaker opens (threshold 1)
        let order = t.order(&[1, 2, 3], true);
        assert_eq!(order, vec![2, 1, 3]);
        // Static policy keeps base order among closed nodes.
        let order = t.order(&[1, 2, 3], false);
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn deadline_needs_warm_window() {
        let mut t = HealthTracker::default();
        assert_eq!(t.deadline_s(0.9), None);
        for i in 0..20 {
            t.record_ok(0, 0.001 + i as f64 * 1e-5);
        }
        let d = t.deadline_s(0.9).unwrap();
        assert!(d >= 0.001 && d < 0.002, "{d}");
    }

    #[test]
    fn forget_drops_history() {
        let mut t = HealthTracker::new(1);
        t.record_failure(9);
        assert!(t.breaker_open(9));
        t.forget(9);
        assert!(!t.breaker_open(9));
    }
}
