//! Dataset substrates: deterministic synthetic vector datasets standing in
//! for SIFT1B/Deep1B (paper Table 3), exact ground truth, recall
//! measurement, and a synthetic token corpus + vocabulary for the RALM
//! text path.

pub mod corpus;
pub mod recall;
pub mod synthetic;

pub use recall::recall_at_k;
pub use synthetic::SyntheticDataset;
