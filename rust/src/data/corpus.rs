//! Synthetic token corpus + vocabulary — the textual side of the RALM
//! database (paper Sec 3: the coordinator "converts the retrieved vector
//! IDs into their respective textual representations").
//!
//! Every database vector id maps to (a) a next-token (for decoder-only
//! kNN-LM retrieval) and (b) a token chunk (for encoder-decoder RETRO-
//! style retrieval). The corpus is generated from a deterministic Markov
//! chain so the LM actually has learnable structure (used by the training
//! example, where loss must visibly fall).

use crate::util::rng::Rng;

/// Token store mapping vector ids to retrieved content.
pub struct Corpus {
    pub vocab: usize,
    pub chunk_len: usize,
    /// Next token per database entry (decoder-only retrieval payload).
    pub next_tokens: Vec<u32>,
    /// Token chunk per database entry (EncDec retrieval payload).
    pub chunks: Vec<u32>,
}

impl Corpus {
    /// Build a corpus of `n` entries over `vocab` tokens.
    pub fn generate(n: usize, vocab: usize, chunk_len: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let mut next_tokens = Vec::with_capacity(n);
        let mut chunks = Vec::with_capacity(n * chunk_len);
        for _ in 0..n {
            let mut t = rng.below(vocab) as u32;
            next_tokens.push(t);
            for _ in 0..chunk_len {
                chunks.push(t);
                t = markov_next(t, vocab, &mut rng);
            }
        }
        Corpus { vocab, chunk_len, next_tokens, chunks }
    }

    pub fn next_token(&self, id: u64) -> u32 {
        self.next_tokens[id as usize % self.next_tokens.len()]
    }

    pub fn chunk(&self, id: u64) -> &[u32] {
        let n = self.next_tokens.len();
        let i = id as usize % n;
        &self.chunks[i * self.chunk_len..(i + 1) * self.chunk_len]
    }

    /// Token ids for the K retrieved neighbors (decoder-only payload).
    pub fn gather_next_tokens(&self, ids: &[u64]) -> Vec<u32> {
        ids.iter().map(|&i| self.next_token(i)).collect()
    }

    /// Concatenated chunks for the K retrieved neighbors (EncDec payload).
    pub fn gather_chunks(&self, ids: &[u64]) -> Vec<u32> {
        let mut out = Vec::with_capacity(ids.len() * self.chunk_len);
        for &i in ids {
            out.extend_from_slice(self.chunk(i));
        }
        out
    }
}

/// Deterministic Markov structure: each token transitions within a small
/// neighborhood, giving sequences n-gram statistics an LM can learn.
fn markov_next(t: u32, vocab: usize, rng: &mut Rng) -> u32 {
    let step = [1, 2, 3, 5, 7][rng.below(5)];
    ((t as usize + step) % vocab) as u32
}

/// Generate a training corpus of token sequences with Markov structure.
pub fn training_sequences(
    n_seqs: usize,
    seq_len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n_seqs)
        .map(|_| {
            let mut t = rng.below(vocab) as u32;
            let mut seq = Vec::with_capacity(seq_len);
            for _ in 0..seq_len {
                seq.push(t);
                t = markov_next(t, vocab, &mut rng);
            }
            seq
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let c = Corpus::generate(100, 2048, 8, 1);
        assert_eq!(c.next_tokens.len(), 100);
        assert_eq!(c.chunks.len(), 800);
        assert!(c.next_tokens.iter().all(|&t| (t as usize) < 2048));
    }

    #[test]
    fn gather_shapes() {
        let c = Corpus::generate(50, 512, 4, 2);
        let ids = [0u64, 7, 49];
        assert_eq!(c.gather_next_tokens(&ids).len(), 3);
        assert_eq!(c.gather_chunks(&ids).len(), 12);
    }

    #[test]
    fn chunk_starts_with_next_token() {
        // The chunk's first token is the entry's next-token (the chunk is
        // "the continuation text" of the neighbor).
        let c = Corpus::generate(20, 128, 8, 3);
        for id in 0..20u64 {
            assert_eq!(c.chunk(id)[0], c.next_token(id));
        }
    }

    #[test]
    fn training_sequences_learnable_structure() {
        // Transitions must be confined to the 5-step neighborhood.
        let seqs = training_sequences(10, 64, 100, 4);
        for s in &seqs {
            for w in s.windows(2) {
                let delta = (w[1] as i64 - w[0] as i64).rem_euclid(100);
                assert!([1, 2, 3, 5, 7].contains(&delta), "delta {delta}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = Corpus::generate(30, 256, 4, 9);
        let b = Corpus::generate(30, 256, 4, 9);
        assert_eq!(a.next_tokens, b.next_tokens);
        assert_eq!(a.chunks, b.chunks);
    }
}
