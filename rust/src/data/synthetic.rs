//! Deterministic synthetic vector datasets (paper Sec 6.1).
//!
//! The paper uses SIFT1B/Deep1B plus two synthetic sets built by
//! *replicating* SIFT vectors to RALM dimensionalities (512/1024). We
//! reproduce that recipe at reduced scale: a clustered base distribution
//! (so IVF pruning behaves like real data — uniform noise would make
//! nprobe meaningless) and the same replication trick for SYN-512/1024.

use crate::config::DatasetConfig;
use crate::util::rng::Rng;

/// An in-memory synthetic dataset: database + query vectors.
pub struct SyntheticDataset {
    pub cfg: &'static DatasetConfig,
    pub n: usize,
    pub d: usize,
    pub data: Vec<f32>,
    pub queries: Vec<f32>,
    pub n_queries: usize,
}

impl SyntheticDataset {
    /// Generate the scaled version of a Table 3 dataset.
    pub fn generate(cfg: &'static DatasetConfig, seed: u64) -> SyntheticDataset {
        Self::generate_sized(cfg, cfg.n_scaled, 256, seed)
    }

    /// Generate with explicit sizes (tests use small n).
    pub fn generate_sized(
        cfg: &'static DatasetConfig,
        n: usize,
        n_queries: usize,
        seed: u64,
    ) -> SyntheticDataset {
        // SIFT-like base: 128-dim clustered vectors; higher-D datasets
        // replicate the base columns (paper's SYN recipe).
        let base_d = 128.min(cfg.d);
        let reps = cfg.d / base_d;
        assert_eq!(cfg.d % base_d, 0, "d must be a multiple of {base_d}");

        let mut rng = Rng::new(seed);
        let n_clusters = (n as f64).sqrt() as usize;
        let centers: Vec<f32> = (0..n_clusters * base_d)
            .map(|_| rng.normal() * 4.0)
            .collect();

        let gen_block = |rng: &mut Rng, count: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(count * cfg.d);
            for _ in 0..count {
                let c = rng.below(n_clusters);
                let mut base = vec![0.0f32; base_d];
                for j in 0..base_d {
                    base[j] = centers[c * base_d + j] + rng.normal();
                }
                for _ in 0..reps {
                    out.extend_from_slice(&base);
                }
            }
            out
        };

        let data = gen_block(&mut rng, n);
        let queries = gen_block(&mut rng, n_queries);
        SyntheticDataset { cfg, n, d: cfg.d, data, queries, n_queries }
    }

    pub fn query(&self, i: usize) -> &[f32] {
        &self.queries[i * self.d..(i + 1) * self.d]
    }

    pub fn vector(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SIFT, SYN512};

    #[test]
    fn deterministic() {
        let a = SyntheticDataset::generate_sized(&SIFT, 100, 10, 5);
        let b = SyntheticDataset::generate_sized(&SIFT, 100, 10, 5);
        assert_eq!(a.data, b.data);
        assert_eq!(a.queries, b.queries);
    }

    #[test]
    fn shapes() {
        let ds = SyntheticDataset::generate_sized(&SYN512, 50, 7, 1);
        assert_eq!(ds.data.len(), 50 * 512);
        assert_eq!(ds.queries.len(), 7 * 512);
    }

    #[test]
    fn syn_replication_structure() {
        // SYN-512 vectors replicate a 128-dim base 4x (paper Sec 6.1).
        let ds = SyntheticDataset::generate_sized(&SYN512, 20, 2, 2);
        for i in 0..20 {
            let v = ds.vector(i);
            for r in 1..4 {
                for j in 0..128 {
                    assert_eq!(v[j], v[r * 128 + j], "vector {i} rep {r}");
                }
            }
        }
    }

    #[test]
    fn data_is_clustered() {
        // Clustered data: mean nearest-neighbor distance must be far below
        // the mean pairwise distance (uniform data would have them close).
        let ds = SyntheticDataset::generate_sized(&SIFT, 400, 1, 3);
        let _d = ds.d;
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut nn = 0.0f64;
        let mut all = 0.0f64;
        let mut all_n = 0usize;
        for i in 0..100 {
            let mut best = f32::MAX;
            for j in 0..400 {
                if i == j {
                    continue;
                }
                let dd = dist(ds.vector(i), ds.vector(j));
                best = best.min(dd);
                all += dd as f64;
                all_n += 1;
            }
            nn += best as f64;
        }
        let mean_nn = nn / 100.0;
        let mean_all = all / all_n as f64;
        assert!(mean_nn * 3.0 < mean_all, "nn {mean_nn} vs all {mean_all}");
    }
}
