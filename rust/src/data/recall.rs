//! Recall measurement: R@K against exact ground truth (paper Sec 2.2 /
//! Sec 6.1 — the setup targets R@100 = 93-94% at nprobe=32).

use crate::pq::flat::flat_search;

/// R@K: overlap fraction between approximate `got` ids and the exact
/// top-K ids for one query.
pub fn recall_at_k(got: &[u64], exact: &[u32]) -> f64 {
    if exact.is_empty() {
        return 1.0;
    }
    let hits = got
        .iter()
        .filter(|g| exact.contains(&(**g as u32)))
        .count();
    hits as f64 / exact.len() as f64
}

/// Compute exact ground-truth neighbor ids for a batch of queries.
pub fn ground_truth(
    data: &[f32],
    n: usize,
    d: usize,
    queries: &[f32],
    n_queries: usize,
    k: usize,
) -> Vec<Vec<u32>> {
    (0..n_queries)
        .map(|q| flat_search(data, n, d, &queries[q * d..(q + 1) * d], k).0)
        .collect()
}

/// Mean recall over a batch of (approximate, exact) result lists.
pub fn mean_recall(results: &[Vec<u64>], truth: &[Vec<u32>]) -> f64 {
    assert_eq!(results.len(), truth.len());
    let total: f64 = results
        .iter()
        .zip(truth)
        .map(|(g, e)| recall_at_k(g, e))
        .sum();
    total / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_recall() {
        assert_eq!(recall_at_k(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn partial_recall() {
        assert!((recall_at_k(&[1, 9, 3], &[1, 2, 3]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_recall() {
        assert_eq!(recall_at_k(&[7, 8], &[1, 2]), 0.0);
    }

    #[test]
    fn ground_truth_self_query() {
        // Querying with a database vector must return that vector first.
        let data = vec![
            0.0, 0.0, //
            5.0, 5.0, //
            9.0, 9.0,
        ];
        let gt = ground_truth(&data, 3, 2, &data, 3, 1);
        assert_eq!(gt[0], vec![0]);
        assert_eq!(gt[1], vec![1]);
        assert_eq!(gt[2], vec![2]);
    }

    #[test]
    fn mean_recall_averages() {
        let r = mean_recall(
            &[vec![1, 2], vec![9, 9]],
            &[vec![1, 2], vec![1, 2]],
        );
        assert!((r - 0.5).abs() < 1e-12);
    }
}
