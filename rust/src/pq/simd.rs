//! Explicit-SIMD ADC scan kernels with one-time runtime dispatch.
//!
//! The paper's CPU-inefficiency argument (Sec 2.3) is that PQ distance
//! scanning lands around ~1 GB/s/core even "SIMD-optimized"; the scalar
//! unrolled kernels in `pq::scan` sit in exactly that band. This module
//! pushes the scan toward the roofline with `core::arch` intrinsics (no
//! new crates): AVX2 on x86-64, AVX-512 behind the opt-in `avx512` cargo
//! feature, and NEON on aarch64, all behind the existing m-specialized
//! kernel interface so `adc_scan_into`, `scan_list_into_sink`, and the
//! fused selector are untouched as callers.
//!
//! **Bit-identity contract.** Kernels vectorize *across vectors*: each
//! SIMD lane owns one code row, and accumulator `u` (of four) sums
//! columns `4g + u` in ascending `g` — exactly the scalar unrolled
//! kernel's `a0..a3` assignment — before the final `(a0+a1)+(a2+a3)`
//! combine. Per lane the float additions happen in the same order as the
//! scalar m-specialized reference (`adc_scan_scalar_into`), so distances
//! — and therefore top-k — are bit-for-bit identical at every width.
//! The m=64 kernel keeps the two-pass L1 column-blocking structure
//! (32-column halves). Row tails that don't fill a SIMD block fall back
//! to the scalar kernel, which preserves per-row operation order.
//!
//! The LUT build (`build_lut_raw_into`, the other per-query hot loop)
//! gets the same treatment: lanes own centroids, the subtract-square
//! accumulation runs in scalar `j` order with explicit sub/mul/add (no
//! FMA contraction), so LUT entries are bit-identical too.
//!
//! **Dispatch.** `active()` resolves the kernel set once per process
//! (`OnceLock`): runtime feature detection picks the best compiled-in
//! ISA, overridable via `CHAM_FORCE_SCALAR=1` or
//! `CHAM_KERNEL=scalar|avx2|avx512|neon|auto`. Env-free A/B (perf-ab,
//! benches, tests) goes through `ScanKernels::for_kind`, which clamps
//! the request to what the host actually supports.

use std::sync::OnceLock;

use super::scan;

/// Scan kernel signature: `(codes, n, lut, out)` with a fixed PQ width
/// baked into the kernel (`codes.len() == n * m`, `lut.len() == m * 256`).
pub type ScanFn = fn(&[u8], usize, &[f32], &mut [f32]);

/// LUT-build kernel signature: `(centroids, query, m, dsub, out)`.
pub type LutFn = fn(&[f32], &[f32], usize, usize, &mut [f32]);

/// Instruction-set families a kernel set can be built from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsaKind {
    /// The scalar m-specialized reference kernels in `pq::scan`.
    Scalar,
    /// 8-lane x86-64 kernels (`vgatherdps` + `vaddps`).
    Avx2,
    /// 16-lane x86-64 kernels; requires building with `--features avx512`
    /// *and* runtime `avx512f`, otherwise clamps to AVX2.
    Avx512,
    /// 4-lane aarch64 kernels (NEON is baseline on aarch64).
    Neon,
}

impl IsaKind {
    pub fn name(&self) -> &'static str {
        match self {
            IsaKind::Scalar => "scalar",
            IsaKind::Avx2 => "avx2",
            IsaKind::Avx512 => "avx512",
            IsaKind::Neon => "neon",
        }
    }

    /// Parse a kernel-override token (`CHAM_KERNEL`, `perf-ab --kernel`).
    /// `auto`/`simd` resolve to the detected best; unknown tokens are
    /// `None` so callers can fall through to auto.
    pub fn parse(s: &str) -> Option<IsaKind> {
        match s {
            "scalar" => Some(IsaKind::Scalar),
            "avx2" => Some(IsaKind::Avx2),
            "avx512" => Some(IsaKind::Avx512),
            "neon" => Some(IsaKind::Neon),
            "auto" | "simd" => Some(detect()),
            _ => None,
        }
    }
}

/// Best ISA this binary can actually run on this host: compile-time
/// gates (arch, the `avx512` feature) intersected with runtime CPUID.
pub fn detect() -> IsaKind {
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> IsaKind {
    #[cfg(feature = "avx512")]
    {
        if is_x86_feature_detected!("avx512f") {
            return IsaKind::Avx512;
        }
    }
    if is_x86_feature_detected!("avx2") {
        IsaKind::Avx2
    } else {
        IsaKind::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> IsaKind {
    // NEON is mandatory in AArch64; every Rust aarch64 target has it.
    IsaKind::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> IsaKind {
    IsaKind::Scalar
}

/// Human-readable runtime feature summary for banners (`perf-ab`,
/// bench records). Reports what the *CPU* has, independent of what this
/// build can use — e.g. `avx512f` shows up even without `--features
/// avx512`, so a capability gap is visible in the output.
pub fn detected_features() -> String {
    let mut feats: Vec<&str> = Vec::new();
    fill_features(&mut feats);
    if feats.is_empty() {
        "none".to_string()
    } else {
        feats.join("+")
    }
}

#[cfg(target_arch = "x86_64")]
fn fill_features(feats: &mut Vec<&'static str>) {
    if is_x86_feature_detected!("avx2") {
        feats.push("avx2");
    }
    if is_x86_feature_detected!("avx512f") {
        feats.push("avx512f");
    }
    if is_x86_feature_detected!("avx512bw") {
        feats.push("avx512bw");
    }
}

#[cfg(target_arch = "aarch64")]
fn fill_features(feats: &mut Vec<&'static str>) {
    feats.push("neon");
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn fill_features(_feats: &mut Vec<&'static str>) {}

/// A resolved kernel set: one scan kernel per paper PQ width plus the
/// LUT-build kernel. Widths outside {16, 32, 64} always take the scalar
/// `scan_generic` path (they are not hot in any shipped dataset).
#[derive(Clone, Copy)]
pub struct ScanKernels {
    pub kind: IsaKind,
    m16: ScanFn,
    m32: ScanFn,
    m64: ScanFn,
    lut: LutFn,
}

impl ScanKernels {
    /// The scalar reference set (the pre-SIMD hot kernels).
    pub fn scalar() -> ScanKernels {
        ScanKernels {
            kind: IsaKind::Scalar,
            m16: scan::scan_unrolled::<16>,
            m32: scan::scan_unrolled::<32>,
            m64: scan::scan_blocked_64,
            lut: scan::build_lut_scalar_into,
        }
    }

    /// Kernel set for `req`, clamped to what this build + host supports
    /// (asking for `avx512` without the feature or CPU yields AVX2;
    /// asking for any SIMD on a scalar-only host yields scalar). This is
    /// the env-free entry point for A/B harnesses.
    pub fn for_kind(req: IsaKind) -> ScanKernels {
        let kind = clamp(req, detect());
        match kind {
            #[cfg(target_arch = "x86_64")]
            IsaKind::Avx2 => x86::kernels_avx2(),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            IsaKind::Avx512 => x86_512::kernels(),
            #[cfg(target_arch = "aarch64")]
            IsaKind::Neon => neon::kernels(),
            _ => ScanKernels::scalar(),
        }
    }

    /// m-dispatched ADC scan through this kernel set. Same contract as
    /// `pq::scan::adc_scan_into`.
    pub fn scan_into(&self, codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
        match m {
            16 => (self.m16)(codes, n, lut, out),
            32 => (self.m32)(codes, n, lut, out),
            64 => (self.m64)(codes, n, lut, out),
            _ => scan::scan_generic(codes, n, m, lut, out),
        }
    }

    /// LUT build through this kernel set. Same contract as
    /// `pq::scan::build_lut_raw_into`.
    pub fn build_lut_into(
        &self,
        centroids: &[f32],
        query: &[f32],
        m: usize,
        dsub: usize,
        out: &mut [f32],
    ) {
        (self.lut)(centroids, query, m, dsub, out)
    }

    /// Name of the kernel serving width `m` in this set.
    pub fn kernel_name(&self, m: usize) -> &'static str {
        match m {
            16 | 32 | 64 => self.kind.name(),
            _ => "scalar-generic",
        }
    }
}

/// Clamp a requested ISA to the detected best: scalar always wins a
/// scalar request (or a scalar host); a SIMD request on a host from a
/// different family resolves to that host's best.
fn clamp(req: IsaKind, best: IsaKind) -> IsaKind {
    use IsaKind::*;
    match (req, best) {
        (Scalar, _) | (_, Scalar) => Scalar,
        (Avx512, Avx512) => Avx512,
        (Avx512, b) => b,
        (Avx2, Avx512) | (Avx2, Avx2) => Avx2,
        (Avx2, b) => b,
        (Neon, Neon) => Neon,
        (Neon, b) => b,
    }
}

/// `CHAM_FORCE_SCALAR` / `CHAM_KERNEL` override, if any.
fn env_override() -> Option<IsaKind> {
    if let Some(v) = std::env::var_os("CHAM_FORCE_SCALAR") {
        if !v.is_empty() && v != "0" {
            return Some(IsaKind::Scalar);
        }
    }
    let v = std::env::var("CHAM_KERNEL").ok()?;
    IsaKind::parse(&v)
}

static ACTIVE: OnceLock<ScanKernels> = OnceLock::new();

/// The process-wide kernel set: resolved once on first use from runtime
/// detection, honoring `CHAM_FORCE_SCALAR=1` and
/// `CHAM_KERNEL=scalar|avx2|avx512|neon|auto`.
pub fn active() -> &'static ScanKernels {
    ACTIVE.get_or_init(|| ScanKernels::for_kind(env_override().unwrap_or_else(detect)))
}

/// Geometry asserts shared by every SIMD kernel wrapper: the unsafe
/// gather bodies rely on exactly these bounds.
#[allow(dead_code)] // unused on ISAs with no SIMD kernels compiled in
fn check_scan(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
    assert_eq!(codes.len(), n * m, "codes length mismatch");
    assert_eq!(lut.len(), m * crate::pq::codebook::KSUB, "lut length mismatch");
    assert!(out.len() >= n, "out buffer too small");
}

#[allow(dead_code)]
fn check_lut(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
    let ksub = crate::pq::codebook::KSUB;
    assert_eq!(query.len(), m * dsub, "query length mismatch");
    assert_eq!(centroids.len(), m * ksub * dsub, "centroid table mismatch");
    assert_eq!(out.len(), m * ksub, "lut out mismatch");
}

// ---------------------------------------------------------------------------
// AVX2 (x86-64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use super::{check_lut, check_scan, IsaKind, ScanKernels};
    use crate::pq::codebook::KSUB;
    use crate::pq::scan;

    const LANES: usize = 8;

    pub fn kernels_avx2() -> ScanKernels {
        ScanKernels {
            kind: IsaKind::Avx2,
            m16: scan_m16,
            m32: scan_m32,
            m64: scan_m64,
            lut: lut_build,
        }
    }

    /// Accumulate an 8-row block over columns `[c0, c0 + cols)` (cols a
    /// multiple of 4). Lane `l` owns row `v + l`; `acc[u]` sums columns
    /// `c0 + 4g + u` in ascending `g` — the scalar kernel's `a0..a3`.
    ///
    /// Safety: caller guarantees AVX2, `v + 8 <= n`, `c0 + cols <= m`,
    /// `codes.len() == n * m`, `lut.len() == m * KSUB`.
    #[inline(always)]
    unsafe fn block8(
        codes: &[u8],
        v: usize,
        m: usize,
        c0: usize,
        cols: usize,
        lut: &[f32],
        acc: &mut [__m256; 4],
    ) {
        let mask = _mm256_set1_epi32(0xFF);
        let row0 = codes.as_ptr().add(v * m);
        for g in 0..cols / 4 {
            let col = c0 + 4 * g;
            // One unaligned u32 load grabs 4 consecutive code bytes per
            // row; little-endian x86 puts code[col] in byte 0.
            let mut packed = [0u32; LANES];
            for (l, slot) in packed.iter_mut().enumerate() {
                *slot = (row0.add(l * m + col) as *const u32).read_unaligned();
            }
            let pack = _mm256_loadu_si256(packed.as_ptr() as *const __m256i);
            let i0 = _mm256_and_si256(pack, mask);
            let i1 = _mm256_and_si256(_mm256_srli_epi32::<8>(pack), mask);
            let i2 = _mm256_and_si256(_mm256_srli_epi32::<16>(pack), mask);
            let i3 = _mm256_srli_epi32::<24>(pack);
            let l0 = lut.as_ptr().add(col * KSUB);
            acc[0] = _mm256_add_ps(acc[0], _mm256_i32gather_ps::<4>(l0, i0));
            acc[1] = _mm256_add_ps(acc[1], _mm256_i32gather_ps::<4>(l0.add(KSUB), i1));
            acc[2] = _mm256_add_ps(acc[2], _mm256_i32gather_ps::<4>(l0.add(2 * KSUB), i2));
            acc[3] = _mm256_add_ps(acc[3], _mm256_i32gather_ps::<4>(l0.add(3 * KSUB), i3));
        }
    }

    /// `(a0 + a1) + (a2 + a3)` — the scalar kernel's combine tree.
    #[inline(always)]
    unsafe fn combine(acc: [__m256; 4]) -> __m256 {
        _mm256_add_ps(_mm256_add_ps(acc[0], acc[1]), _mm256_add_ps(acc[2], acc[3]))
    }

    /// Single-pass scan (LUT fits L1): m = 16 or 32.
    #[inline(always)]
    unsafe fn flat_body(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
        let blocks = n / LANES * LANES;
        let mut v = 0;
        while v < blocks {
            let mut acc = [_mm256_setzero_ps(); 4];
            block8(codes, v, m, 0, m, lut, &mut acc);
            _mm256_storeu_ps(out.as_mut_ptr().add(v), combine(acc));
            v += LANES;
        }
        if blocks < n {
            scan::adc_scan_scalar_into(
                &codes[blocks * m..n * m],
                n - blocks,
                m,
                lut,
                &mut out[blocks..n],
            );
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scan_m16_avx2(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        flat_body(codes, n, 16, lut, out)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scan_m32_avx2(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        flat_body(codes, n, 32, lut, out)
    }

    /// m=64 keeps the scalar kernel's two-pass column blocking: each pass
    /// touches a 32 KiB half-LUT that stays L1-resident.
    #[target_feature(enable = "avx2")]
    unsafe fn scan_m64_avx2(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        const M: usize = 64;
        const HALF: usize = 32;
        let blocks = n / LANES * LANES;
        let mut v = 0;
        while v < blocks {
            let mut acc = [_mm256_setzero_ps(); 4];
            block8(codes, v, M, 0, HALF, lut, &mut acc);
            _mm256_storeu_ps(out.as_mut_ptr().add(v), combine(acc));
            v += LANES;
        }
        let mut v = 0;
        while v < blocks {
            let mut acc = [_mm256_setzero_ps(); 4];
            block8(codes, v, M, HALF, HALF, lut, &mut acc);
            let prev = _mm256_loadu_ps(out.as_ptr().add(v));
            _mm256_storeu_ps(out.as_mut_ptr().add(v), _mm256_add_ps(prev, combine(acc)));
            v += LANES;
        }
        if blocks < n {
            scan::adc_scan_scalar_into(
                &codes[blocks * M..n * M],
                n - blocks,
                M,
                lut,
                &mut out[blocks..n],
            );
        }
    }

    /// Subtract-square-accumulate over `dsub` dims, 8 centroids per
    /// vector. Lane `l` owns centroid `c + l` (gather stride `dsub`);
    /// the `j` loop runs in scalar order with explicit sub/mul/add so no
    /// FMA contraction can change bits.
    #[inline(always)]
    unsafe fn lut_body(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        let stride = _mm256_setr_epi32(
            0,
            dsub as i32,
            2 * dsub as i32,
            3 * dsub as i32,
            4 * dsub as i32,
            5 * dsub as i32,
            6 * dsub as i32,
            7 * dsub as i32,
        );
        for i in 0..m {
            let sub = query.as_ptr().add(i * dsub);
            let cents = centroids.as_ptr().add(i * KSUB * dsub);
            let row = out.as_mut_ptr().add(i * KSUB);
            let mut c = 0;
            while c < KSUB {
                let mut acc = _mm256_setzero_ps();
                let base = cents.add(c * dsub);
                for j in 0..dsub {
                    let q = _mm256_set1_ps(*sub.add(j));
                    let g = _mm256_i32gather_ps::<4>(base.add(j), stride);
                    let t = _mm256_sub_ps(q, g);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(t, t));
                }
                _mm256_storeu_ps(row.add(c), acc);
                c += LANES;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn lut_avx2(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        // The dsub match lets constant propagation specialize the inner
        // loop for every shipped dataset geometry.
        match dsub {
            2 => lut_body(centroids, query, m, 2, out),
            4 => lut_body(centroids, query, m, 4, out),
            6 => lut_body(centroids, query, m, 6, out),
            8 => lut_body(centroids, query, m, 8, out),
            16 => lut_body(centroids, query, m, 16, out),
            _ => lut_body(centroids, query, m, dsub, out),
        }
    }

    // Safe wrappers: geometry asserts make the raw gathers in-bounds,
    // and these fns are only installed after AVX2 was detected.

    fn scan_m16(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 16, lut, out);
        unsafe { scan_m16_avx2(codes, n, lut, out) }
    }

    fn scan_m32(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 32, lut, out);
        unsafe { scan_m32_avx2(codes, n, lut, out) }
    }

    fn scan_m64(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 64, lut, out);
        unsafe { scan_m64_avx2(codes, n, lut, out) }
    }

    fn lut_build(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        check_lut(centroids, query, m, dsub, out);
        unsafe { lut_avx2(centroids, query, m, dsub, out) }
    }
}

// ---------------------------------------------------------------------------
// AVX-512 (x86-64, opt-in `avx512` cargo feature)
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod x86_512 {
    use core::arch::x86_64::*;

    use super::{check_lut, check_scan, IsaKind, ScanKernels};
    use crate::pq::codebook::KSUB;
    use crate::pq::scan;

    const LANES: usize = 16;

    pub fn kernels() -> ScanKernels {
        ScanKernels {
            kind: IsaKind::Avx512,
            m16: scan_m16,
            m32: scan_m32,
            m64: scan_m64,
            lut: lut_build,
        }
    }

    /// 16-row block over columns `[c0, c0 + cols)`; same accumulator
    /// assignment and combine tree as the AVX2/scalar kernels.
    #[inline(always)]
    unsafe fn block16(
        codes: &[u8],
        v: usize,
        m: usize,
        c0: usize,
        cols: usize,
        lut: &[f32],
        acc: &mut [__m512; 4],
    ) {
        let row0 = codes.as_ptr().add(v * m);
        for g in 0..cols / 4 {
            for (u, a) in acc.iter_mut().enumerate() {
                let col = c0 + 4 * g + u;
                let mut idx = [0i32; LANES];
                for (l, slot) in idx.iter_mut().enumerate() {
                    *slot = *row0.add(l * m + col) as i32;
                }
                let iv: __m512i = core::mem::transmute(idx);
                let base = lut.as_ptr().add(col * KSUB);
                *a = _mm512_add_ps(*a, _mm512_i32gather_ps::<4>(iv, base as *const _));
            }
        }
    }

    #[inline(always)]
    unsafe fn combine(acc: [__m512; 4]) -> __m512 {
        _mm512_add_ps(_mm512_add_ps(acc[0], acc[1]), _mm512_add_ps(acc[2], acc[3]))
    }

    #[inline(always)]
    unsafe fn flat_body(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
        let blocks = n / LANES * LANES;
        let mut v = 0;
        while v < blocks {
            let mut acc = [_mm512_setzero_ps(); 4];
            block16(codes, v, m, 0, m, lut, &mut acc);
            _mm512_storeu_ps(out.as_mut_ptr().add(v), combine(acc));
            v += LANES;
        }
        if blocks < n {
            scan::adc_scan_scalar_into(
                &codes[blocks * m..n * m],
                n - blocks,
                m,
                lut,
                &mut out[blocks..n],
            );
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn scan_m16_512(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        flat_body(codes, n, 16, lut, out)
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn scan_m32_512(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        flat_body(codes, n, 32, lut, out)
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn scan_m64_512(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        const M: usize = 64;
        const HALF: usize = 32;
        let blocks = n / LANES * LANES;
        let mut v = 0;
        while v < blocks {
            let mut acc = [_mm512_setzero_ps(); 4];
            block16(codes, v, M, 0, HALF, lut, &mut acc);
            _mm512_storeu_ps(out.as_mut_ptr().add(v), combine(acc));
            v += LANES;
        }
        let mut v = 0;
        while v < blocks {
            let mut acc = [_mm512_setzero_ps(); 4];
            block16(codes, v, M, HALF, HALF, lut, &mut acc);
            let prev = _mm512_loadu_ps(out.as_ptr().add(v));
            _mm512_storeu_ps(out.as_mut_ptr().add(v), _mm512_add_ps(prev, combine(acc)));
            v += LANES;
        }
        if blocks < n {
            scan::adc_scan_scalar_into(
                &codes[blocks * M..n * M],
                n - blocks,
                M,
                lut,
                &mut out[blocks..n],
            );
        }
    }

    #[inline(always)]
    unsafe fn lut_body(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        let mut stride = [0i32; LANES];
        for (l, slot) in stride.iter_mut().enumerate() {
            *slot = (l * dsub) as i32;
        }
        let stride: __m512i = core::mem::transmute(stride);
        for i in 0..m {
            let sub = query.as_ptr().add(i * dsub);
            let cents = centroids.as_ptr().add(i * KSUB * dsub);
            let row = out.as_mut_ptr().add(i * KSUB);
            let mut c = 0;
            while c < KSUB {
                let mut acc = _mm512_setzero_ps();
                let base = cents.add(c * dsub);
                for j in 0..dsub {
                    let q = _mm512_set1_ps(*sub.add(j));
                    let g = _mm512_i32gather_ps::<4>(stride, base.add(j) as *const _);
                    let t = _mm512_sub_ps(q, g);
                    acc = _mm512_add_ps(acc, _mm512_mul_ps(t, t));
                }
                _mm512_storeu_ps(row.add(c), acc);
                c += LANES;
            }
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn lut_512(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        match dsub {
            2 => lut_body(centroids, query, m, 2, out),
            4 => lut_body(centroids, query, m, 4, out),
            6 => lut_body(centroids, query, m, 6, out),
            8 => lut_body(centroids, query, m, 8, out),
            16 => lut_body(centroids, query, m, 16, out),
            _ => lut_body(centroids, query, m, dsub, out),
        }
    }

    fn scan_m16(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 16, lut, out);
        unsafe { scan_m16_512(codes, n, lut, out) }
    }

    fn scan_m32(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 32, lut, out);
        unsafe { scan_m32_512(codes, n, lut, out) }
    }

    fn scan_m64(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 64, lut, out);
        unsafe { scan_m64_512(codes, n, lut, out) }
    }

    fn lut_build(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        check_lut(centroids, query, m, dsub, out);
        unsafe { lut_512(centroids, query, m, dsub, out) }
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    use super::{check_lut, check_scan, IsaKind, ScanKernels};
    use crate::pq::codebook::KSUB;
    use crate::pq::scan;

    const LANES: usize = 4;

    pub fn kernels() -> ScanKernels {
        ScanKernels {
            kind: IsaKind::Neon,
            m16: scan_m16,
            m32: scan_m32,
            m64: scan_m64,
            lut: lut_build,
        }
    }

    /// NEON has no gather; assemble each 4-lane LUT read on the stack.
    /// Accumulator/combine structure matches the scalar kernel exactly.
    #[inline(always)]
    unsafe fn block4(
        codes: &[u8],
        v: usize,
        m: usize,
        c0: usize,
        cols: usize,
        lut: &[f32],
        acc: &mut [float32x4_t; 4],
    ) {
        let row0 = codes.as_ptr().add(v * m);
        for g in 0..cols / 4 {
            for (u, a) in acc.iter_mut().enumerate() {
                let col = c0 + 4 * g + u;
                let lrow = lut.as_ptr().add(col * KSUB);
                let vals = [
                    *lrow.add(*row0.add(col) as usize),
                    *lrow.add(*row0.add(m + col) as usize),
                    *lrow.add(*row0.add(2 * m + col) as usize),
                    *lrow.add(*row0.add(3 * m + col) as usize),
                ];
                *a = vaddq_f32(*a, vld1q_f32(vals.as_ptr()));
            }
        }
    }

    #[inline(always)]
    unsafe fn combine(acc: [float32x4_t; 4]) -> float32x4_t {
        vaddq_f32(vaddq_f32(acc[0], acc[1]), vaddq_f32(acc[2], acc[3]))
    }

    #[inline(always)]
    unsafe fn flat_body(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
        let blocks = n / LANES * LANES;
        let mut v = 0;
        while v < blocks {
            let mut acc = [vdupq_n_f32(0.0); 4];
            block4(codes, v, m, 0, m, lut, &mut acc);
            vst1q_f32(out.as_mut_ptr().add(v), combine(acc));
            v += LANES;
        }
        if blocks < n {
            scan::adc_scan_scalar_into(
                &codes[blocks * m..n * m],
                n - blocks,
                m,
                lut,
                &mut out[blocks..n],
            );
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn scan_m16_neon(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        flat_body(codes, n, 16, lut, out)
    }

    #[target_feature(enable = "neon")]
    unsafe fn scan_m32_neon(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        flat_body(codes, n, 32, lut, out)
    }

    #[target_feature(enable = "neon")]
    unsafe fn scan_m64_neon(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        const M: usize = 64;
        const HALF: usize = 32;
        let blocks = n / LANES * LANES;
        let mut v = 0;
        while v < blocks {
            let mut acc = [vdupq_n_f32(0.0); 4];
            block4(codes, v, M, 0, HALF, lut, &mut acc);
            vst1q_f32(out.as_mut_ptr().add(v), combine(acc));
            v += LANES;
        }
        let mut v = 0;
        while v < blocks {
            let mut acc = [vdupq_n_f32(0.0); 4];
            block4(codes, v, M, HALF, HALF, lut, &mut acc);
            let prev = vld1q_f32(out.as_ptr().add(v));
            vst1q_f32(out.as_mut_ptr().add(v), vaddq_f32(prev, combine(acc)));
            v += LANES;
        }
        if blocks < n {
            scan::adc_scan_scalar_into(
                &codes[blocks * M..n * M],
                n - blocks,
                M,
                lut,
                &mut out[blocks..n],
            );
        }
    }

    #[inline(always)]
    unsafe fn lut_body(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        for i in 0..m {
            let sub = query.as_ptr().add(i * dsub);
            let cents = centroids.as_ptr().add(i * KSUB * dsub);
            let row = out.as_mut_ptr().add(i * KSUB);
            let mut c = 0;
            while c < KSUB {
                let mut acc = vdupq_n_f32(0.0);
                let base = cents.add(c * dsub);
                for j in 0..dsub {
                    let q = vdupq_n_f32(*sub.add(j));
                    let vals = [
                        *base.add(j),
                        *base.add(dsub + j),
                        *base.add(2 * dsub + j),
                        *base.add(3 * dsub + j),
                    ];
                    let t = vsubq_f32(q, vld1q_f32(vals.as_ptr()));
                    acc = vaddq_f32(acc, vmulq_f32(t, t));
                }
                vst1q_f32(row.add(c), acc);
                c += LANES;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn lut_neon(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        match dsub {
            2 => lut_body(centroids, query, m, 2, out),
            4 => lut_body(centroids, query, m, 4, out),
            6 => lut_body(centroids, query, m, 6, out),
            8 => lut_body(centroids, query, m, 8, out),
            16 => lut_body(centroids, query, m, 16, out),
            _ => lut_body(centroids, query, m, dsub, out),
        }
    }

    fn scan_m16(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 16, lut, out);
        unsafe { scan_m16_neon(codes, n, lut, out) }
    }

    fn scan_m32(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 32, lut, out);
        unsafe { scan_m32_neon(codes, n, lut, out) }
    }

    fn scan_m64(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
        check_scan(codes, n, 64, lut, out);
        unsafe { scan_m64_neon(codes, n, lut, out) }
    }

    fn lut_build(centroids: &[f32], query: &[f32], m: usize, dsub: usize, out: &mut [f32]) {
        check_lut(centroids, query, m, dsub, out);
        unsafe { lut_neon(centroids, query, m, dsub, out) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::codebook::KSUB;
    use crate::util::rng::Rng;

    /// Every kernel set that is real on this host (dedup'd: a clamped
    /// request that resolves to an already-listed kind is skipped).
    fn available_sets() -> Vec<ScanKernels> {
        let mut kinds = vec![IsaKind::Scalar];
        for req in [IsaKind::Avx2, IsaKind::Avx512, IsaKind::Neon] {
            let set = ScanKernels::for_kind(req);
            if !kinds.contains(&set.kind) {
                kinds.push(set.kind);
            }
        }
        kinds.into_iter().map(ScanKernels::for_kind).collect()
    }

    fn random_case(rng: &mut Rng, n: usize, m: usize) -> (Vec<u8>, Vec<f32>) {
        let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
        let lut: Vec<f32> = (0..m * KSUB).map(|_| rng.f32() * 4.0 - 2.0).collect();
        (codes, lut)
    }

    #[test]
    fn simd_scan_bit_identical_to_scalar_all_widths_and_tails() {
        let scalar = ScanKernels::scalar();
        let mut rng = Rng::new(0xADC5);
        for set in available_sets() {
            for &m in &[16usize, 32, 64] {
                // Cover empty input, sub-block sizes, exact blocks for
                // every lane count (4/8/16), and off-by-one tails.
                for &n in &[0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 257, 1000] {
                    let (codes, lut) = random_case(&mut rng, n, m);
                    let mut a = vec![f32::NAN; n];
                    let mut b = vec![f32::NAN; n];
                    scalar.scan_into(&codes, n, m, &lut, &mut a);
                    set.scan_into(&codes, n, m, &lut, &mut b);
                    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "kind={} m={m} n={n} row {i}: scalar {x} vs simd {y}",
                            set.kind.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn generic_widths_route_to_scalar_generic() {
        let mut rng = Rng::new(7);
        for set in available_sets() {
            for &m in &[4usize, 12, 20, 48] {
                let n = 37;
                let (codes, lut) = random_case(&mut rng, n, m);
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                scan::scan_generic(&codes, n, m, &lut, &mut a);
                set.scan_into(&codes, n, m, &lut, &mut b);
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "kind={} m={m}", set.kind.name());
                }
            }
        }
    }

    #[test]
    fn simd_lut_build_bit_identical_to_scalar() {
        let mut rng = Rng::new(0x1007);
        // Shipped geometries (dsub 2/4/6/8/16) plus odd ones hitting the
        // generic fallback arm.
        for set in available_sets() {
            for &(m, dsub) in &[(16usize, 8usize), (16, 6), (32, 16), (64, 2), (8, 3), (4, 5)] {
                let centroids: Vec<f32> =
                    (0..m * KSUB * dsub).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let query: Vec<f32> = (0..m * dsub).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let mut a = vec![f32::NAN; m * KSUB];
                let mut b = vec![f32::NAN; m * KSUB];
                scan::build_lut_scalar_into(&centroids, &query, m, dsub, &mut a);
                set.build_lut_into(&centroids, &query, m, dsub, &mut b);
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "kind={} m={m} dsub={dsub} slot {i}",
                        set.kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn for_kind_clamps_to_host_capability() {
        assert_eq!(ScanKernels::for_kind(IsaKind::Scalar).kind, IsaKind::Scalar);
        let best = detect();
        // Asking for the detected best yields it; asking for anything
        // never yields a kind the host can't run.
        assert_eq!(ScanKernels::for_kind(best).kind, best);
        for req in [IsaKind::Avx2, IsaKind::Avx512, IsaKind::Neon] {
            let got = ScanKernels::for_kind(req).kind;
            assert_eq!(got, clamp(req, best));
        }
    }

    #[test]
    fn kernel_override_tokens_parse() {
        assert_eq!(IsaKind::parse("scalar"), Some(IsaKind::Scalar));
        assert_eq!(IsaKind::parse("avx2"), Some(IsaKind::Avx2));
        assert_eq!(IsaKind::parse("avx512"), Some(IsaKind::Avx512));
        assert_eq!(IsaKind::parse("neon"), Some(IsaKind::Neon));
        assert_eq!(IsaKind::parse("auto"), Some(detect()));
        assert_eq!(IsaKind::parse("simd"), Some(detect()));
        assert_eq!(IsaKind::parse("mmx"), None);
    }

    #[test]
    fn active_resolves_to_an_available_kind() {
        let k = active();
        let avail: Vec<IsaKind> = available_sets().iter().map(|s| s.kind).collect();
        assert!(avail.contains(&k.kind), "active kind {:?} not available", k.kind);
        // And it scans correctly end to end.
        let mut rng = Rng::new(3);
        let (codes, lut) = random_case(&mut rng, 40, 16);
        let mut a = vec![0.0f32; 40];
        let mut b = vec![0.0f32; 40];
        ScanKernels::scalar().scan_into(&codes, 40, 16, &lut, &mut a);
        k.scan_into(&codes, 40, 16, &lut, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
