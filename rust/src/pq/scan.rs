//! ADC (asymmetric distance computation) scan over PQ codes — the CPU
//! baseline of Fig 9 and the hot loop the paper calibrates at ~1 GB/s/core.
//!
//! `build_lut` mirrors paper Fig 2 step 5 (per-query distance table);
//! `adc_scan` mirrors step 6 (per-code lookups + accumulate). The unrolled
//! variants are the Sec §Perf-optimized hot path; correctness is pinned to
//! the scalar reference by unit + property tests.

use super::codebook::{PqCodebook, KSUB};
use crate::kselect::DistanceSink;

/// Build the (m, 256) distance lookup table for one query.
pub fn build_lut(cb: &PqCodebook, query: &[f32]) -> Vec<f32> {
    assert_eq!(query.len(), cb.d);
    let mut lut = vec![0.0f32; cb.m * KSUB];
    build_lut_raw_into(&cb.centroids, query, cb.m, cb.dsub(), &mut lut);
    lut
}

/// Build a (m, 256) LUT into a caller-provided buffer straight from the
/// raw (m, 256, dsub) centroid tensor — no codebook construction, no
/// centroid copy, no allocation (the arena path of a dispatch round).
pub fn build_lut_raw_into(
    centroids: &[f32],
    query: &[f32],
    m: usize,
    dsub: usize,
    out: &mut [f32],
) {
    assert_eq!(query.len(), m * dsub);
    assert_eq!(centroids.len(), m * KSUB * dsub);
    assert_eq!(out.len(), m * KSUB);
    super::simd::active().build_lut_into(centroids, query, m, dsub, out);
}

/// Scalar reference LUT build — the pre-SIMD hot loop, kept as the
/// bit-identity ground truth and the `CHAM_FORCE_SCALAR` fallback.
pub fn build_lut_scalar_into(
    centroids: &[f32],
    query: &[f32],
    m: usize,
    dsub: usize,
    out: &mut [f32],
) {
    assert_eq!(query.len(), m * dsub);
    assert_eq!(centroids.len(), m * KSUB * dsub);
    assert_eq!(out.len(), m * KSUB);
    for i in 0..m {
        let sub = &query[i * dsub..(i + 1) * dsub];
        let cents = &centroids[i * KSUB * dsub..(i + 1) * KSUB * dsub];
        let row = &mut out[i * KSUB..(i + 1) * KSUB];
        for (c, slot) in row.iter_mut().enumerate() {
            let cent = &cents[c * dsub..(c + 1) * dsub];
            let mut acc = 0.0f32;
            for j in 0..dsub {
                let t = sub[j] - cent[j];
                acc += t * t;
            }
            *slot = acc;
        }
    }
}

/// Scan `n` PQ codes against a LUT, returning one distance per code.
pub fn adc_scan(codes: &[u8], n: usize, m: usize, lut: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    adc_scan_into(codes, n, m, lut, &mut out);
    out
}

/// Scan into a caller-provided buffer (hot path: zero allocation).
///
/// Dispatches through the process-wide kernel set (`pq::simd::active()`):
/// explicit-SIMD kernels for the paper's PQ widths where the host supports
/// them, the scalar m-specialized loops otherwise — bit-identical either
/// way. Override with `CHAM_FORCE_SCALAR=1` / `CHAM_KERNEL=...`.
pub fn adc_scan_into(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
    assert_eq!(codes.len(), n * m);
    assert_eq!(lut.len(), m * KSUB);
    assert!(out.len() >= n);
    super::simd::active().scan_into(codes, n, m, lut, out);
}

/// Scalar m-specialized scan — the pre-SIMD hot path, kept as the
/// bit-identity ground truth, the SIMD kernels' row-tail handler, and the
/// `CHAM_FORCE_SCALAR` fallback.
pub fn adc_scan_scalar_into(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
    assert_eq!(codes.len(), n * m);
    assert_eq!(lut.len(), m * KSUB);
    assert!(out.len() >= n);
    match m {
        16 => scan_unrolled::<16>(codes, n, lut, out),
        32 => scan_unrolled::<32>(codes, n, lut, out),
        // m=64's LUT is 64 KiB — larger than L1D — so a single pass
        // thrashes the cache (measured 0.65 GB/s/core vs 1.55 at m=16).
        // Two column-blocked passes keep each 32 KiB half-LUT resident
        // (EXPERIMENTS.md §Perf).
        64 => scan_blocked_64(codes, n, lut, out),
        _ => scan_generic(codes, n, m, lut, out),
    }
}

/// Scalar reference implementation (kept simple; ground truth for tests).
pub fn scan_generic(codes: &[u8], n: usize, m: usize, lut: &[f32], out: &mut [f32]) {
    for v in 0..n {
        let code = &codes[v * m..(v + 1) * m];
        let mut acc = 0.0f32;
        for (i, &c) in code.iter().enumerate() {
            acc += lut[i * KSUB + c as usize];
        }
        out[v] = acc;
    }
}

/// Const-generic unrolled scan: four independent accumulators break the
/// lookup->add dependency chain the paper blames for CPU inefficiency
/// (Sec 2.3); the compiler keeps the LUT base addresses in registers.
///
/// Public so the SIMD dispatcher (`pq::simd`) can install it as the
/// scalar kernel set and A/B harnesses can time it directly.
pub fn scan_unrolled<const M: usize>(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
    debug_assert_eq!(M % 4, 0);
    for v in 0..n {
        let code = &codes[v * M..(v + 1) * M];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut i = 0;
        while i < M {
            a0 += lut[i * KSUB + code[i] as usize];
            a1 += lut[(i + 1) * KSUB + code[i + 1] as usize];
            a2 += lut[(i + 2) * KSUB + code[i + 2] as usize];
            a3 += lut[(i + 3) * KSUB + code[i + 3] as usize];
            i += 4;
        }
        out[v] = (a0 + a1) + (a2 + a3);
    }
}

/// Ablation reference: the single-pass unrolled m=64 scan (the L1-blocked
/// variant replaced it on the hot path; kept benchable for the §Perf A/B).
pub fn scan_unrolled_m64_unblocked(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
    scan_unrolled::<64>(codes, n, lut, out)
}

/// Column-blocked scan for m=64: two passes over the codes, each using a
/// 32 KiB half of the LUT that fits L1D. The second pass accumulates onto
/// the first's partial sums; code rows are 64 B (one cache line), so the
/// extra pass re-reads each line once — cheap next to the avoided LUT
/// misses.
pub fn scan_blocked_64(codes: &[u8], n: usize, lut: &[f32], out: &mut [f32]) {
    const M: usize = 64;
    const HALF: usize = 32;
    for v in 0..n {
        let code = &codes[v * M..v * M + HALF];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut i = 0;
        while i < HALF {
            a0 += lut[i * KSUB + code[i] as usize];
            a1 += lut[(i + 1) * KSUB + code[i + 1] as usize];
            a2 += lut[(i + 2) * KSUB + code[i + 2] as usize];
            a3 += lut[(i + 3) * KSUB + code[i + 3] as usize];
            i += 4;
        }
        out[v] = (a0 + a1) + (a2 + a3);
    }
    let hi_lut = &lut[HALF * KSUB..];
    for v in 0..n {
        let code = &codes[v * M + HALF..(v + 1) * M];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut i = 0;
        while i < HALF {
            a0 += hi_lut[i * KSUB + code[i] as usize];
            a1 += hi_lut[(i + 1) * KSUB + code[i + 1] as usize];
            a2 += hi_lut[(i + 2) * KSUB + code[i + 2] as usize];
            a3 += hi_lut[(i + 3) * KSUB + code[i + 3] as usize];
            i += 4;
        }
        out[v] += (a0 + a1) + (a2 + a3);
    }
}

/// Exact ADC distance of a single code against a LUT (for verification).
pub fn adc_one(code: &[u8], lut: &[f32]) -> f32 {
    code.iter().enumerate().map(|(i, &c)| lut[i * KSUB + c as usize]).sum()
}

/// Tile width of the fused scan+select path: distances are staged through
/// an L1-resident scratch tile (4 KiB of f32) between the m-specialized
/// scan kernels and the selector, so no O(n) distance buffer ever exists.
pub const FUSED_TILE: usize = 1024;

/// Fused scan+select over one list's code block, in place: scan `codes`
/// (length `ids.len() * m`) against `lut` and stream every distance into
/// `sink` tagged with its gather-order position (`order_base + i`) and
/// global id (`ids[i]`).
///
/// This is the per-list entry point of the zero-copy pipeline: a shard
/// scan calls it once per probed list with the list's in-place slices —
/// no gather copy, no materialized distance vector. `scratch` is a
/// reusable tile buffer (grown once to [`FUSED_TILE`], then steady-state
/// allocation-free); tiling keeps the staging L1-resident while reusing
/// the unrolled / cache-blocked `adc_scan_into` kernels per PQ width.
pub fn scan_list_into_sink<S: DistanceSink>(
    codes: &[u8],
    m: usize,
    lut: &[f32],
    ids: &[u64],
    order_base: u64,
    scratch: &mut Vec<f32>,
    sink: &mut S,
) {
    let n = ids.len();
    assert_eq!(codes.len(), n * m);
    if scratch.len() < FUSED_TILE {
        scratch.resize(FUSED_TILE, 0.0);
    }
    let mut off = 0usize;
    while off < n {
        let t = (n - off).min(FUSED_TILE);
        adc_scan_into(&codes[off * m..(off + t) * m], t, m, lut, &mut scratch[..t]);
        for (i, &d) in scratch[..t].iter().enumerate() {
            sink.offer(d, order_base + (off + i) as u64, ids[off + i]);
        }
        off += t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_lut(rng: &mut Rng, m: usize) -> Vec<f32> {
        (0..m * KSUB).map(|_| rng.f32() * 10.0).collect()
    }

    #[test]
    fn unrolled_matches_generic_for_paper_widths() {
        let mut rng = Rng::new(1);
        for &m in &[16usize, 32, 64] {
            // Not a multiple of anything — and below, sizes exercising
            // empty input and every SIMD lane-count tail (4/8/16).
            for &n in &[257usize, 0, 1, 7, 9, 15, 17, 33] {
                let codes: Vec<u8> =
                    (0..n * m).map(|_| rng.below(256) as u8).collect();
                let lut = random_lut(&mut rng, m);
                let mut fast = vec![0.0f32; n];
                let mut slow = vec![0.0f32; n];
                let mut scalar = vec![0.0f32; n];
                adc_scan_into(&codes, n, m, &lut, &mut fast);
                scan_generic(&codes, n, m, &lut, &mut slow);
                adc_scan_scalar_into(&codes, n, m, &lut, &mut scalar);
                for (a, b) in fast.iter().zip(&slow) {
                    // Different accumulation order: relative f32 tolerance.
                    assert!((a - b).abs() < 1e-5 * a.abs().max(1.0), "{a} vs {b}");
                }
                // Whatever kernel set is active (SIMD or scalar), the
                // dispatched result is bit-identical to the scalar
                // m-specialized reference.
                for (a, b) in fast.iter().zip(&scalar) {
                    assert_eq!(a.to_bits(), b.to_bits(), "m={m} n={n}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn prop_active_kernels_bit_match_scalar_reference() {
        prop::check(
            "adc-scan-simd-bit-identity",
            |rng| {
                let m = [16, 32, 64][rng.below(3)];
                let n = rng.below(300); // includes n = 0
                let codes: Vec<u8> =
                    (0..n * m).map(|_| rng.below(256) as u8).collect();
                let lut: Vec<f32> =
                    (0..m * KSUB).map(|_| rng.normal().abs()).collect();
                (m, n, codes, lut)
            },
            |(m, n, codes, lut)| {
                let mut fast = vec![f32::NAN; *n];
                let mut scalar = vec![f32::NAN; *n];
                adc_scan_into(codes, *n, *m, lut, &mut fast);
                adc_scan_scalar_into(codes, *n, *m, lut, &mut scalar);
                for (a, b) in fast.iter().zip(&scalar) {
                    assert_eq!(a.to_bits(), b.to_bits(), "m={m} n={n}");
                }
            },
        );
    }

    #[test]
    fn adc_equals_reconstruction_distance() {
        // d(x, c(y)) computed via LUT must equal the explicit distance to
        // the reconstructed vector (paper Sec 2.2 formula).
        let mut rng = Rng::new(2);
        let (n, d, m) = (300, 16, 4);
        let data = rng.normal_vec(n * d);
        let cb = PqCodebook::train(&data, n, d, m, 3);
        let q = rng.normal_vec(d);
        let lut = build_lut(&cb, &q);
        let codes = cb.encode(&data, n);
        let dists = adc_scan(&codes, n, m, &lut);
        let mut rec = vec![0.0f32; d];
        for v in 0..n {
            cb.decode_one(&codes[v * m..(v + 1) * m], &mut rec);
            let explicit: f32 =
                q.iter().zip(&rec).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(
                (explicit - dists[v]).abs() < 1e-3,
                "v={v}: {explicit} vs {}",
                dists[v]
            );
        }
    }

    #[test]
    fn prop_scan_matches_scalar_any_m() {
        prop::check(
            "adc-scan-matches",
            |rng| {
                let m = [4, 8, 12, 16, 20, 32, 48, 64][rng.below(8)];
                let n = 1 + rng.below(100);
                let codes: Vec<u8> =
                    (0..n * m).map(|_| rng.below(256) as u8).collect();
                let lut: Vec<f32> =
                    (0..m * KSUB).map(|_| rng.normal().abs()).collect();
                (m, n, codes, lut)
            },
            |(m, n, codes, lut)| {
                let mut fast = vec![0.0f32; *n];
                let mut slow = vec![0.0f32; *n];
                adc_scan_into(codes, *n, *m, lut, &mut fast);
                scan_generic(codes, *n, *m, lut, &mut slow);
                for (a, b) in fast.iter().zip(&slow) {
                    assert!((a - b).abs() < 1e-5 * a.abs().max(1.0));
                }
            },
        );
    }

    #[test]
    fn raw_lut_matches_codebook_lut() {
        let mut rng = Rng::new(9);
        let (n, d, m) = (400, 16, 4);
        let data = rng.normal_vec(n * d);
        let cb = PqCodebook::train(&data, n, d, m, 5);
        let q = rng.normal_vec(d);
        let want = build_lut(&cb, &q);
        let mut got = vec![0.0f32; m * KSUB];
        build_lut_raw_into(&cb.centroids, &q, m, cb.dsub(), &mut got);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dispatched_lut_bit_matches_scalar_reference() {
        // Every shipped dataset geometry (dsub 2/6/8/16) plus an odd
        // width hitting the generic arm: the active (possibly SIMD) LUT
        // build must be bit-identical to the scalar loop.
        let mut rng = Rng::new(11);
        for &(m, dsub) in &[(16usize, 8usize), (16, 6), (32, 16), (64, 16), (4, 2), (8, 5)] {
            let centroids: Vec<f32> =
                (0..m * KSUB * dsub).map(|_| rng.normal()).collect();
            let q: Vec<f32> = (0..m * dsub).map(|_| rng.normal()).collect();
            let mut fast = vec![f32::NAN; m * KSUB];
            let mut scalar = vec![f32::NAN; m * KSUB];
            build_lut_raw_into(&centroids, &q, m, dsub, &mut fast);
            build_lut_scalar_into(&centroids, &q, m, dsub, &mut scalar);
            for (i, (a, b)) in fast.iter().zip(&scalar).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "m={m} dsub={dsub} slot {i}");
            }
        }
    }

    #[test]
    fn fused_list_scan_matches_flat_scan() {
        // Per-list fused scan+select over in-place slices must reproduce
        // the gather-then-scan-then-sort reference bit for bit, across
        // tile boundaries (n > FUSED_TILE) and tie groups.
        use crate::kselect::FusedSelector;
        let mut rng = Rng::new(10);
        for &m in &[4usize, 16, 64] {
            let lut: Vec<f32> =
                (0..m * KSUB).map(|_| (rng.below(8) as f32) * 0.5).collect();
            let lens = [3usize, 0, FUSED_TILE + 37, 129];
            let lists: Vec<(Vec<u8>, Vec<u64>)> = lens
                .iter()
                .scan(0u64, |next_id, &n| {
                    let codes =
                        (0..n * m).map(|_| rng.below(256) as u8).collect();
                    let ids = (*next_id..*next_id + n as u64).collect();
                    *next_id += n as u64;
                    Some((codes, ids))
                })
                .collect();
            let k = 25;
            let mut sel = FusedSelector::new(k);
            let mut scratch = Vec::new();
            let mut order = 0u64;
            for (codes, ids) in &lists {
                scan_list_into_sink(codes, m, &lut, ids, order, &mut scratch, &mut sel);
                order += ids.len() as u64;
            }
            let mut got = Vec::new();
            sel.emit_into(&mut got);

            // Reference: concatenate, scan flat, stable sort, truncate.
            let flat_codes: Vec<u8> =
                lists.iter().flat_map(|(c, _)| c.iter().copied()).collect();
            let flat_ids: Vec<u64> =
                lists.iter().flat_map(|(_, i)| i.iter().copied()).collect();
            let dists = adc_scan(&flat_codes, flat_ids.len(), m, &lut);
            let mut all: Vec<(f32, u64)> =
                dists.iter().zip(&flat_ids).map(|(&d, &i)| (d, i)).collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            all.truncate(k);
            assert_eq!(got.len(), all.len(), "m={m}");
            for (g, w) in got.iter().zip(&all) {
                assert_eq!(g.0.to_bits(), w.0.to_bits(), "m={m}");
                assert_eq!(g.1, w.1, "m={m}: tie order must match gather order");
            }
        }
    }

    #[test]
    fn lut_rows_are_subspace_distances() {
        let mut rng = Rng::new(4);
        let (n, d, m) = (400, 8, 2);
        let data = rng.normal_vec(n * d);
        let cb = PqCodebook::train(&data, n, d, m, 5);
        let q = rng.normal_vec(d);
        let lut = build_lut(&cb, &q);
        let dsub = cb.dsub();
        for i in 0..m {
            for c in 0..KSUB {
                let cent = cb.centroid(i, c);
                let expect: f32 = q[i * dsub..(i + 1) * dsub]
                    .iter()
                    .zip(cent)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!((lut[i * KSUB + c] - expect).abs() < 1e-4);
            }
        }
    }
}
