//! Lloyd's k-means with k-means++-style seeding — the clustering substrate
//! for PQ codebooks (256 centroids per sub-space) and IVF coarse
//! quantizers (paper Sec 2.2).

use crate::util::rng::Rng;

/// Result of a k-means run.
pub struct KmeansResult {
    /// Row-major (k, d) centroid matrix.
    pub centroids: Vec<f32>,
    /// Assignment of each input vector to its nearest centroid.
    pub assign: Vec<u32>,
    /// Final mean squared distance (inertia / n).
    pub mse: f32,
}

/// Run k-means over `n` row-major `d`-dim vectors.
///
/// Deterministic for a given seed. Empty clusters are re-seeded from the
/// points of the largest cluster (Faiss-style split).
pub fn kmeans(
    data: &[f32],
    n: usize,
    d: usize,
    k: usize,
    iters: usize,
    seed: u64,
) -> KmeansResult {
    assert_eq!(data.len(), n * d);
    assert!(k >= 1 && n >= k, "need n >= k ({n} vs {k})");
    let mut rng = Rng::new(seed);

    // k-means++ seeding: spread the initial centroids by sampling each
    // next seed proportionally to squared distance from the chosen set.
    let mut centroids = Vec::with_capacity(k * d);
    let first = rng.below(n);
    centroids.extend_from_slice(&data[first * d..(first + 1) * d]);
    let mut d2: Vec<f32> = (0..n)
        .map(|i| {
            let v = &data[i * d..(i + 1) * d];
            v.iter()
                .zip(&centroids[..d])
                .map(|(a, b)| (a - b) * (a - b))
                .sum()
        })
        .collect();
    for c in 1..k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut idx = n - 1;
            for (i, &x) in d2.iter().enumerate() {
                target -= x as f64;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centroids.extend_from_slice(&data[pick * d..(pick + 1) * d]);
        // Update nearest-seed distances.
        let new_c = &data[pick * d..(pick + 1) * d];
        for i in 0..n {
            let v = &data[i * d..(i + 1) * d];
            let dist: f32 =
                v.iter().zip(new_c).map(|(a, b)| (a - b) * (a - b)).sum();
            if dist < d2[i] {
                d2[i] = dist;
            }
        }
        let _ = c;
    }

    let mut assign = vec![0u32; n];
    let mut mse = f32::MAX;
    for _iter in 0..iters {
        // Assignment step.
        let mut inertia = 0.0f64;
        for i in 0..n {
            let v = &data[i * d..(i + 1) * d];
            let (best, dist) = nearest(v, &centroids, k, d);
            assign[i] = best as u32;
            inertia += dist as f64;
        }
        mse = (inertia / n as f64) as f32;

        // Update step.
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i] as usize;
            counts[c] += 1;
            let v = &data[i * d..(i + 1) * d];
            for (s, &x) in sums[c * d..(c + 1) * d].iter_mut().zip(v) {
                *s += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster near a random point of the largest.
                let big = (0..k).max_by_key(|&j| counts[j]).unwrap();
                let members: Vec<usize> =
                    (0..n).filter(|&i| assign[i] as usize == big).collect();
                let pick = members[rng.below(members.len())];
                for j in 0..d {
                    centroids[c * d + j] =
                        data[pick * d + j] + 0.01 * rng.normal();
                }
            } else {
                for j in 0..d {
                    centroids[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    // Final assignment against the last centroid update.
    let mut inertia = 0.0f64;
    for i in 0..n {
        let v = &data[i * d..(i + 1) * d];
        let (best, dist) = nearest(v, &centroids, k, d);
        assign[i] = best as u32;
        inertia += dist as f64;
    }
    mse = mse.min((inertia / n as f64) as f32);
    KmeansResult { centroids, assign, mse }
}

/// Index + squared distance of the centroid nearest to `v`.
#[inline]
pub fn nearest(v: &[f32], centroids: &[f32], k: usize, d: usize) -> (usize, f32) {
    let mut best = 0usize;
    let mut best_d = f32::MAX;
    for c in 0..k {
        let mut dist = 0.0f32;
        let row = &centroids[c * d..(c + 1) * d];
        for j in 0..d {
            let t = v[j] - row[j];
            dist += t * t;
        }
        if dist < best_d {
            best_d = dist;
            best = c;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Three well-separated Gaussian blobs must be recovered exactly.
    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(1);
        let d = 4;
        let centers = [[0.0; 4], [10.0, 10.0, 10.0, 10.0], [-10.0, 5.0, -5.0, 10.0]];
        let mut data = Vec::new();
        for i in 0..300 {
            let c = &centers[i % 3];
            for j in 0..d {
                data.push(c[j] + 0.1 * rng.normal());
            }
        }
        let r = kmeans(&data, 300, d, 3, 10, 42);
        assert!(r.mse < 0.1, "mse {}", r.mse);
        // All members of one blob share an assignment.
        for blob in 0..3 {
            let first = r.assign[blob];
            for i in (blob..300).step_by(3) {
                assert_eq!(r.assign[i], first, "blob {blob} split");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(2);
        let data = rng.normal_vec(100 * 8);
        let a = kmeans(&data, 100, 8, 10, 5, 7);
        let b = kmeans(&data, 100, 8, 10, 5, 7);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn mse_decreases_with_more_clusters() {
        let mut rng = Rng::new(3);
        let data = rng.normal_vec(500 * 8);
        let a = kmeans(&data, 500, 8, 2, 8, 1).mse;
        let b = kmeans(&data, 500, 8, 32, 8, 1).mse;
        assert!(b < a, "{b} !< {a}");
    }

    #[test]
    fn handles_k_equals_n() {
        let mut rng = Rng::new(4);
        let data = rng.normal_vec(16 * 4);
        let r = kmeans(&data, 16, 4, 16, 4, 1);
        assert!(r.mse < 1e-6); // every point its own centroid
    }

    #[test]
    fn assignments_in_range() {
        let mut rng = Rng::new(5);
        let data = rng.normal_vec(200 * 6);
        let r = kmeans(&data, 200, 6, 13, 6, 2);
        assert!(r.assign.iter().all(|&a| (a as usize) < 13));
    }
}
