//! Exact (flat) nearest-neighbor search — the ground-truth oracle used to
//! measure recall (paper Sec 2.2: R@K against exact neighbors).

/// Exact top-k nearest neighbors of `query` among `n` row-major vectors.
/// Returns (ids, squared distances), ascending by distance.
pub fn flat_search(data: &[f32], n: usize, d: usize, query: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
    assert_eq!(query.len(), d);
    assert!(k <= n);
    // Max-heap of (dist, id) keeping the k smallest.
    let mut heap: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
    for i in 0..n {
        let row = &data[i * d..(i + 1) * d];
        let mut dist = 0.0f32;
        for j in 0..d {
            let t = query[j] - row[j];
            dist += t * t;
        }
        if heap.len() < k {
            heap.push((dist, i as u32));
            if heap.len() == k {
                heap.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            }
        } else if dist < heap[0].0 {
            // Replace current max, restore descending order by insertion.
            heap[0] = (dist, i as u32);
            let mut j = 0;
            while j + 1 < heap.len() && heap[j].0 < heap[j + 1].0 {
                heap.swap(j, j + 1);
                j += 1;
            }
        }
    }
    heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let ids = heap.iter().map(|&(_, i)| i).collect();
    let dists = heap.iter().map(|&(d, _)| d).collect();
    (ids, dists)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn finds_planted_neighbor() {
        let mut rng = Rng::new(1);
        let (n, d) = (500, 16);
        let mut data = rng.normal_vec(n * d);
        let q = rng.normal_vec(d);
        // Plant an almost-exact copy of the query at id 123.
        for j in 0..d {
            data[123 * d + j] = q[j] + 1e-4;
        }
        let (ids, dists) = flat_search(&data, n, d, &q, 5);
        assert_eq!(ids[0], 123);
        assert!(dists[0] < 1e-4);
        assert!(dists.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn matches_naive_sort() {
        let mut rng = Rng::new(2);
        let (n, d, k) = (200, 8, 20);
        let data = rng.normal_vec(n * d);
        let q = rng.normal_vec(d);
        let (ids, _) = flat_search(&data, n, d, &q, k);
        // Naive: compute all distances, sort.
        let mut all: Vec<(f32, u32)> = (0..n)
            .map(|i| {
                let row = &data[i * d..(i + 1) * d];
                let dist: f32 =
                    q.iter().zip(row).map(|(a, b)| (a - b) * (a - b)).sum();
                (dist, i as u32)
            })
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let expect: Vec<u32> = all[..k].iter().map(|&(_, i)| i).collect();
        assert_eq!(ids, expect);
    }

    #[test]
    fn k_equals_n() {
        let mut rng = Rng::new(3);
        let data = rng.normal_vec(10 * 4);
        let q = rng.normal_vec(4);
        let (ids, _) = flat_search(&data, 10, 4, &q, 10);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<u32>>());
    }
}
