//! PQ codebook: training (k-means per sub-space), encoding vectors into
//! m-byte codes, and reconstruction (paper Fig 2, steps 1-3).

use super::kmeans::{kmeans, nearest};

/// Centroids per PQ sub-space (8-bit codes, paper Sec 2.2: M = 256).
pub const KSUB: usize = 256;

/// A trained product quantizer.
#[derive(Clone)]
pub struct PqCodebook {
    pub d: usize,
    pub m: usize,
    /// (m, 256, dsub) row-major centroid tensor.
    pub centroids: Vec<f32>,
}

impl PqCodebook {
    pub fn dsub(&self) -> usize {
        self.d / self.m
    }

    /// Train one k-means per sub-space over `n` training vectors.
    pub fn train(data: &[f32], n: usize, d: usize, m: usize, seed: u64) -> PqCodebook {
        assert_eq!(d % m, 0, "d={d} must divide into m={m} sub-spaces");
        assert!(n >= KSUB, "need >= {KSUB} training vectors, got {n}");
        let dsub = d / m;
        let mut centroids = vec![0.0f32; m * KSUB * dsub];
        // Per-sub-space training set is the sliced columns.
        let mut sub = vec![0.0f32; n * dsub];
        for i in 0..m {
            for v in 0..n {
                sub[v * dsub..(v + 1) * dsub]
                    .copy_from_slice(&data[v * d + i * dsub..v * d + (i + 1) * dsub]);
            }
            let r = kmeans(&sub, n, dsub, KSUB, 10, seed ^ (i as u64) << 32);
            centroids[i * KSUB * dsub..(i + 1) * KSUB * dsub]
                .copy_from_slice(&r.centroids);
        }
        PqCodebook { d, m, centroids }
    }

    /// Centroid sub-vector for (sub-space i, code c).
    #[inline]
    pub fn centroid(&self, i: usize, c: usize) -> &[f32] {
        let dsub = self.dsub();
        let off = (i * KSUB + c) * dsub;
        &self.centroids[off..off + dsub]
    }

    /// Encode one vector into m bytes.
    pub fn encode_one(&self, v: &[f32], out: &mut [u8]) {
        let dsub = self.dsub();
        for i in 0..self.m {
            let sub = &v[i * dsub..(i + 1) * dsub];
            let cents = &self.centroids[i * KSUB * dsub..(i + 1) * KSUB * dsub];
            let (best, _) = nearest(sub, cents, KSUB, dsub);
            out[i] = best as u8;
        }
    }

    /// Encode `n` vectors into an (n, m) code matrix.
    pub fn encode(&self, data: &[f32], n: usize) -> Vec<u8> {
        assert_eq!(data.len(), n * self.d);
        let mut codes = vec![0u8; n * self.m];
        for v in 0..n {
            let row = &data[v * self.d..(v + 1) * self.d];
            self.encode_one(row, &mut codes[v * self.m..(v + 1) * self.m]);
        }
        codes
    }

    /// Reconstruct the quantized vector c(y) from its code.
    pub fn decode_one(&self, code: &[u8], out: &mut [f32]) {
        let dsub = self.dsub();
        for i in 0..self.m {
            out[i * dsub..(i + 1) * dsub]
                .copy_from_slice(self.centroid(i, code[i] as usize));
        }
    }

    /// Mean squared reconstruction error over a sample (training QA).
    pub fn reconstruction_mse(&self, data: &[f32], n: usize) -> f32 {
        let mut code = vec![0u8; self.m];
        let mut rec = vec![0.0f32; self.d];
        let mut total = 0.0f64;
        for v in 0..n {
            let row = &data[v * self.d..(v + 1) * self.d];
            self.encode_one(row, &mut code);
            self.decode_one(&code, &mut rec);
            let e: f32 = row.iter().zip(&rec).map(|(a, b)| (a - b) * (a - b)).sum();
            total += e as f64;
        }
        (total / n as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn train_toy(seed: u64) -> (PqCodebook, Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let (n, d, m) = (1000, 32, 4);
        let data = rng.normal_vec(n * d);
        (PqCodebook::train(&data, n, d, m, 1), data, n)
    }

    #[test]
    fn shapes() {
        let (cb, _, _) = train_toy(1);
        assert_eq!(cb.dsub(), 8);
        assert_eq!(cb.centroids.len(), 4 * 256 * 8);
    }

    #[test]
    fn encode_decode_reduces_error_vs_zero() {
        let (cb, data, n) = train_toy(2);
        let mse = cb.reconstruction_mse(&data, n);
        // Zero reconstruction would give mse ~= d (unit variance): PQ must
        // be far better.
        assert!(mse < 32.0 * 0.5, "mse {mse}");
    }

    #[test]
    fn codes_cover_many_centroids() {
        let (cb, data, n) = train_toy(3);
        let codes = cb.encode(&data, n);
        let distinct: std::collections::HashSet<u8> =
            codes.iter().step_by(cb.m).cloned().collect();
        assert!(distinct.len() > 100, "only {} codes used", distinct.len());
    }

    #[test]
    fn encode_is_nearest_centroid() {
        let (cb, data, _) = train_toy(4);
        let mut code = vec![0u8; cb.m];
        let dsub = cb.dsub();
        cb.encode_one(&data[..cb.d], &mut code);
        for i in 0..cb.m {
            let sub = &data[i * dsub..(i + 1) * dsub];
            // The chosen centroid must not be beaten by any other.
            let chosen = cb.centroid(i, code[i] as usize);
            let chosen_d: f32 =
                sub.iter().zip(chosen).map(|(a, b)| (a - b) * (a - b)).sum();
            for c in 0..KSUB {
                let alt = cb.centroid(i, c);
                let alt_d: f32 =
                    sub.iter().zip(alt).map(|(a, b)| (a - b) * (a - b)).sum();
                assert!(alt_d >= chosen_d - 1e-5);
            }
        }
    }
}
