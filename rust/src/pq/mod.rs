//! Product quantization built from scratch (paper Sec 2.2).
//!
//! This is both a substrate (the paper assumes Faiss) and the CPU baseline
//! of Fig 9: [`scan`] implements the ADC loop whose per-code table lookups
//! and dependent accumulations are exactly the bottleneck the paper
//! measures at ~1 GB/s/core on Xeon.

pub mod codebook;
pub mod flat;
pub mod kmeans;
pub mod scan;
pub mod simd;

pub use codebook::PqCodebook;
pub use kmeans::kmeans;
pub use scan::{adc_scan, adc_scan_into, build_lut};
