//! `chameleon` — the leader CLI.
//!
//! Subcommands:
//!   demo                quickstart: search + one generated sequence
//!   search              vector search over a scaled dataset
//!   serve               generate sequences end-to-end (RALM inference)
//!   cluster             elastic retrieval tier report: replicated
//!                       dispatch, mid-run node death, failover/hedging
//!   chaos               seeded network-fault harness: nodes behind
//!                       flip/cut/stall proxies, a mid-run shard blackout
//!                       served as coverage-tagged partials, probation
//!                       rejoin back to bit-identical results
//!   loadgen             open-loop load harness: traced coordinator +
//!                       Poisson/bursty offered-load sweep, knee + fitted
//!                       capacity plan (BENCH_serve.json)
//!   top                 live dashboard over a running coordinator's
//!                       stats frames (per-tenant latency, SLO burn,
//!                       cluster health, flagged tail traces)
//!   report <id>         regenerate a paper table/figure
//!                       (fig7 fig8 fig9 fig10 fig11 fig12 fig13
//!                        table4 table5 recall retcache dispatch trace all)

use std::time::Duration;

use anyhow::{bail, Result};
use chameleon::chamlm::pool::WorkerPool;
use chameleon::chamvs::backend::ScanBackend;
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::cluster::{
    ClusterConfig, ClusterEngine, ClusterMap, ClusterNode, FailingBackend, HedgeConfig,
};
use chameleon::config::{self, SystemConfig};
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::engine::RalmEngine;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorClient, CoordinatorServer, ServeMode};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::client::RemoteNode;
use chameleon::report;
use chameleon::runtime::Runtime;
use chameleon::util::cli::Args;

fn main() {
    let args = Args::parse();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.flag("pin-workers") {
        // Funnel the flag through the env knob so every layer that
        // spawns scan workers (dispatcher pools, cluster engines) sees
        // it without threading a bool through each constructor.
        std::env::set_var("CHAM_PIN", "1");
    }
    match args.subcommand.as_deref() {
        Some("demo") => demo(args),
        Some("search") => search(args),
        Some("serve") => serve(args),
        Some("cluster") => cluster_cmd(args),
        Some("chaos") => chaos_cmd(args),
        Some("loadgen") => loadgen_cmd(args),
        Some("top") => top_cmd(args),
        Some("report") => report_cmd(args),
        Some(other) => bail!("unknown subcommand '{other}' (try --help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "chameleon — heterogeneous & disaggregated RALM serving (reproduction)\n\
         \n\
         USAGE: chameleon <subcommand> [options]\n\
         \n\
         demo                      quickstart search + generation\n\
         search [--dataset SIFT] [--queries 64] [--nodes 2] [--batch 1] [--pjrt]\n\
         serve  [--model dec_tiny] [--tokens 64] [--sequences 2]\n\
         serve --net [--clients 4] [--queries 32] [--sequential | --threaded]\n\
                [--poll-threads 2] [--interactive-queue 4096] [--batch-queue 1024]\n\
                [--batch-rate QPS] [--max-batch 16] [--max-wait-us 200] [--nodes 2]\n\
                [--replication R] [--hedge-quantile q] [--pin-workers]\n\
                [--remote host:port,host:port]   concurrent coordinator over\n\
                TCP; --remote uses running chamvs-node memory nodes;\n\
                --replication > 1 runs the elastic replicated tier;\n\
                --pin-workers NUMA-pins scan workers (also CHAM_PIN=1)\n\
         cluster [--nodes 4] [--replication 2] [--queries 32]\n\
                [--hedge-quantile 0.95] [--pin-workers]   elastic-tier\n\
                failover report (pinned CPUs appear in the stats line)\n\
         chaos  [--seed N] [--nodes 4] [--replication 2] [--queries 48]\n\
                [--min-coverage 0.0] [--deadline-ms 500] [--blackout-ms 400]\n\
                [--flips 2] [--cuts 1] [--stalls 1]   seeded network-fault\n\
                harness: memory nodes behind fault-injecting proxies, a\n\
                mid-run shard blackout served as coverage-tagged partials,\n\
                and post-heal probation back to bit-identical results\n\
         loadgen [--qps 200 | --sweep 100,200,400] [--requests 400]\n\
                [--conns 4] [--nodes 2] [--unique 64] [--zipf 0.99]\n\
                [--batch-fraction 0.2] [--burst-period-s P --burst-duty D]\n\
                [--remote host:port,...] [--out BENCH_serve.json]\n\
                [--deadline-us 0] [--retries 0]   per-request end-to-end\n\
                budget + shed-retry backoff (honors server retry_after_us)\n\
                [--trace-out spans.json]   open-loop offered-load sweep\n\
                against a traced coordinator; reports goodput, the latency\n\
                knee and an SLO capacity plan fitted from the trace\n\
                [--slo-ms 50 --slo-target 0.99 --batch-slo-ms 200]  SLO\n\
                objectives tracked live as multi-window burn rates\n\
                [--metrics-addr 127.0.0.1:0]  Prometheus-text scrape\n\
                endpoint over the run  [--scrape-linger-ms 0]  keep the\n\
                coordinator up after the sweep for external scrapes\n\
                [--json]  machine-readable report on stdout (chatter\n\
                moves to stderr; keys match BENCH_serve.json)\n\
         top    --remote host:port [--once] [--json] [--prefix coordinator.]\n\
                [--interval-ms 1000]   live dashboard scraped over the\n\
                stats protocol frames of any running coordinator\n\
         report <fig7|fig8|fig9|fig10|fig11|fig12|fig13|table4|table5|recall|retcache|dispatch|trace|all>\n\
                report trace [--trace spans.json] [--json]\n\
                [--slo-ms MS --slo-target 0.99]   aggregate a span dump\n\
                (default: a small in-process traced run); with an SLO,\n\
                append the burn implied by the dump's Total spans\n\
         \n\
         Common options: --n <scaled db size> --seed <u64> --artifacts <dir>\n\
         Scan kernels: runtime SIMD dispatch (see `perf-ab`); override with\n\
                CHAM_KERNEL=scalar|avx2|avx512|neon|auto or CHAM_FORCE_SCALAR=1"
    );
}

/// Build the standard retrieval stack for a dataset config.
fn build_retriever(
    ds: &'static config::DatasetConfig,
    n: usize,
    n_nodes: usize,
    k: usize,
    use_pjrt: bool,
    sys: &SystemConfig,
) -> Result<(Retriever, SyntheticDataset)> {
    let data = SyntheticDataset::generate_sized(ds, n, 256, sys.seed);
    let nlist = (n as f64).sqrt() as usize;
    eprintln!("[build] dataset {} n={n} d={} nlist={nlist}", ds.name, ds.d);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, sys.seed ^ 1);
    let nodes: Vec<MemoryNode> = if use_pjrt {
        let runtime = Runtime::new(&sys.artifacts_dir)?;
        (0..n_nodes)
            .map(|i| {
                MemoryNode::with_pjrt(
                    Shard::carve(&index, i, n_nodes),
                    &runtime,
                    k,
                    sys.seed,
                )
            })
            .collect::<Result<_>>()?
    } else {
        (0..n_nodes)
            .map(|i| {
                Ok(MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, k))
            })
            .collect::<Result<_>>()?
    };
    let dispatcher = Dispatcher::new(nodes, k);
    let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, sys.seed ^ 2);
    Ok((Retriever::new(ds, index, dispatcher, corpus), data))
}

fn demo(args: &Args) -> Result<()> {
    let sys = system_config(args);
    let ds = config::dataset_by_name("SIFT").unwrap();
    let (mut retriever, data) = build_retriever(ds, 4000, 2, 10, false, &sys)?;
    println!("== vector search demo ==");
    let r = retriever.retrieve(data.query(0))?;
    println!("top-10 ids: {:?}", r.ids);
    println!(
        "modeled paper-scale retrieval latency: {:.3} ms",
        r.modeled_s * 1e3
    );

    println!("\n== RALM generation demo (dec_tiny via PJRT) ==");
    let runtime = Runtime::new(&sys.artifacts_dir)?;
    let pool = WorkerPool::new(&runtime, &config::DEC_TINY, 1, sys.seed)?;
    let mut engine = RalmEngine::new(pool, retriever, &config::DEC_S);
    let stats = engine.generate(1, 32, sys.seed)?;
    println!("generated 32 tokens: {:?}...", &stats.tokens[..8]);
    println!(
        "measured {:.1} ms/token, modeled paper-scale {:.2} ms/token",
        stats.measured_total() / 32.0 * 1e3,
        stats.modeled_total() / 32.0 * 1e3
    );
    Ok(())
}

fn search(args: &Args) -> Result<()> {
    let sys = system_config(args);
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 20_000);
    let n_nodes = args.get_usize("nodes", 2);
    let n_queries = args.get_usize("queries", 64);
    let k = args.get_usize("k", 100);
    let batch = args.get_usize("batch", 1).max(1);
    let (mut retriever, data) =
        build_retriever(ds, n, n_nodes, k, args.flag("pjrt"), &sys)?;
    let mut modeled = Vec::new();
    let mut measured = Vec::new();
    let mut i = 0;
    while i < n_queries {
        let b = batch.min(n_queries - i);
        if b > 1 {
            // Batched path: one parallel dispatch round per B queries.
            let refs: Vec<&[f32]> =
                (0..b).map(|j| data.query((i + j) % data.n_queries)).collect();
            for r in retriever.retrieve_many(&refs)? {
                modeled.push(r.modeled_s);
                measured.push(r.measured_s);
            }
        } else {
            let r = retriever.retrieve(data.query(i % data.n_queries))?;
            modeled.push(r.modeled_s);
            measured.push(r.measured_s);
        }
        i += b;
    }
    use chameleon::util::stats::Summary;
    println!("{}", Summary::of(&modeled).render_ms("modeled paper-scale"));
    println!("{}", Summary::of(&measured).render_ms("measured (scaled, host)"));
    Ok(())
}

/// The coordinator's dynamic-batching policy from the CLI knobs.
fn batch_policy(args: &Args) -> BatchPolicy {
    BatchPolicy {
        max_batch: args.get_usize("max-batch", 16).max(1),
        max_wait: Duration::from_micros(args.get_u64("max-wait-us", 200)),
    }
}

fn serve(args: &Args) -> Result<()> {
    let policy = batch_policy(args);
    println!(
        "[serve] batch policy: max_batch={} max_wait={}us",
        policy.max_batch,
        policy.max_wait.as_micros()
    );
    if args.flag("net") {
        return serve_net(args, policy);
    }
    let sys = system_config(args);
    let model = match args.get_or("model", "dec_tiny") {
        "dec_tiny" => &config::DEC_TINY,
        "encdec_tiny" => &config::ENCDEC_TINY,
        other => bail!("serve supports dec_tiny|encdec_tiny (got {other})"),
    };
    let paper = if model.is_encdec() { &config::ENCDEC_S } else { &config::DEC_S };
    let ds = config::dataset_by_name("SIFT").unwrap();
    let n_tokens = args.get_usize("tokens", 64);
    let n_seq = args.get_usize("sequences", 2);
    let (retriever, _) = build_retriever(ds, 8000, 1, model.k, false, &sys)?;
    let runtime = Runtime::new(&sys.artifacts_dir)?;
    let pool = WorkerPool::new(&runtime, model, 1, sys.seed)?;
    let mut engine = RalmEngine::new(pool, retriever, paper);
    let prompts: Vec<u32> = (0..n_seq as u32).map(|i| i + 1).collect();
    let stats = engine.serve_batch(&prompts, n_tokens, sys.seed)?;
    println!(
        "served {} sequences x {} tokens: measured {:.2}s total, modeled paper-scale {:.1} tokens/s",
        stats.sequences,
        n_tokens,
        stats.measured_s,
        stats.modeled_tokens_per_s()
    );
    Ok(())
}

/// Networked serving: spawn the coordinator (nonblocking event loop by
/// default; `--threaded` for the thread-per-connection A/B baseline,
/// `--sequential` for the one-connection-at-a-time baseline) and drive it
/// with N in-process GPU clients. With `--remote a:p,b:p` the retrieval
/// tier is running `chamvs-node` processes; otherwise local in-process
/// memory nodes.
fn serve_net(args: &Args, policy: BatchPolicy) -> Result<()> {
    use chameleon::coordinator::admission::{QosConfig, TenantPolicy};
    use chameleon::trace::Tracer;

    let sys = system_config(args);
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 8000);
    let n_clients = args.get_usize("clients", 4).max(1);
    let per_client = args.get_usize("queries", 32).max(1);
    let k = args.get_usize("k", 10);
    let sequential = args.flag("sequential");
    let threaded = args.flag("threaded");
    let replication = args.get_usize("replication", 1).max(1);
    let hedge_quantile = args.get_f64("hedge-quantile", 0.0);
    let cluster_cfg = cluster_config(replication, hedge_quantile);
    if cluster_cfg.is_some() {
        println!(
            "[serve-net] elastic tier: replication={replication} hedge_quantile={hedge_quantile}"
        );
    }
    if chameleon::util::affinity::env_pin_requested() {
        println!(
            "[serve-net] worker pinning: on (affinity supported={}, cpus={})",
            chameleon::util::affinity::supported(),
            chameleon::util::affinity::allowed_cpus().len()
        );
    }

    let retriever = match args.get("remote") {
        Some(spec) => {
            build_remote_retriever(ds, n, k, sys.seed, spec, &cluster_cfg)?
        }
        None => match &cluster_cfg {
            Some(cfg) => build_local_clustered_retriever(
                ds,
                n,
                args.get_usize("nodes", 2 * replication),
                replication,
                k,
                *cfg,
                &sys,
            )?,
            None => {
                build_retriever(ds, n, args.get_usize("nodes", 2), k, false, &sys)?.0
            }
        },
    };
    let mode = if sequential {
        ServeMode::Sequential
    } else if threaded {
        ServeMode::Threaded(policy)
    } else {
        ServeMode::Concurrent(policy)
    };
    let mode_name = if sequential {
        "sequential"
    } else if threaded {
        "threaded"
    } else {
        "event-loop"
    };
    // Front-door QoS: generous defaults (single-tenant runs never shed);
    // the knobs exist so operators can tighten multi-tenant deployments.
    let base = QosConfig::default();
    let qos = QosConfig {
        poll_threads: args.get_usize("poll-threads", base.poll_threads).max(1),
        interactive: TenantPolicy {
            queue_cap: args
                .get_usize("interactive-queue", base.interactive.queue_cap)
                .max(1),
            ..base.interactive
        },
        batch: TenantPolicy {
            queue_cap: args.get_usize("batch-queue", base.batch.queue_cap).max(1),
            rate_qps: args.get_f64("batch-rate", base.batch.rate_qps),
            ..base.batch
        },
        ..base
    };
    let mut server =
        CoordinatorServer::spawn_qos(move || retriever, mode, qos, Tracer::off())?;
    let addr = server.addr;
    println!(
        "[serve-net] coordinator on {addr} ({mode_name} mode), \
         {n_clients} clients x {per_client} queries"
    );
    let mut metrics_srv = match args.get("metrics-addr") {
        Some(bind) => {
            let m = chameleon::telemetry::MetricsServer::spawn(bind, server.telemetry())?;
            println!("[serve-net] metrics on {}", m.addr);
            Some(m)
        }
        None => None,
    };

    // Deterministic query stream (tiny db, many queries — only the query
    // vectors are used).
    let qdata = SyntheticDataset::generate_sized(ds, 64, n_clients * per_client, sys.seed ^ 9);
    let failed = std::sync::Mutex::new(None::<anyhow::Error>);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let qdata = &qdata;
            let failed = &failed;
            s.spawn(move || {
                let run = || -> Result<()> {
                    let mut client = CoordinatorClient::connect(addr, c as u32)?;
                    for i in 0..per_client {
                        let q = qdata.query((c * per_client + i) % qdata.n_queries);
                        let resp = client.retrieve(q, &[], k, false)?;
                        anyhow::ensure!(
                            resp.dists.len() <= k,
                            "reply larger than k"
                        );
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    *failed.lock().unwrap() = Some(e);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    if let Some(e) = failed.into_inner().unwrap() {
        return Err(e.context("serve-net client failed"));
    }
    let total = (n_clients * per_client) as f64;
    let stats = server.stats();
    println!(
        "[serve-net] {total:.0} requests in {wall:.3}s -> {:.0} q/s",
        total / wall
    );
    println!(
        "[serve-net] rounds={} mean_batch={:.2} max_batch={} rounds_with_batch>=2: {}",
        stats.rounds(),
        total / stats.rounds().max(1) as f64,
        stats.max_batch(),
        stats.batches_ge2()
    );
    println!(
        "[serve-net] shed={} accept_drops={} nodelay_fallbacks={} shutdown_denied={}",
        stats.shed(),
        stats.accept_drops(),
        stats.nodelay_fallbacks(),
        stats.shutdown_denied()
    );
    if let Some(m) = metrics_srv.as_mut() {
        m.shutdown();
    }
    server.shutdown();
    Ok(())
}

/// `chameleon loadgen` — the open-loop load harness: spawn a traced
/// coordinator (or connect to running `chamvs-node` processes with
/// `--remote`), replay a deterministic Poisson/bursty request schedule at
/// one or more offered loads, and report goodput and latency-vs-load, the
/// measured saturation knee, the per-stage trace breakdown, and a
/// capacity plan fitted from the trace — all persisted to
/// `BENCH_serve.json`.
fn loadgen_cmd(args: &Args) -> Result<()> {
    use anyhow::Context as _;
    use chameleon::coordinator::admission::QosConfig;
    use chameleon::coordinator::SloObjective;
    use chameleon::hwmodel::{CapacityPlanner, StageTimes};
    use chameleon::loadgen::{self, Arrival, DriveOptions, LoadgenConfig, RetryPolicy};
    use chameleon::trace::{analyze, events_to_json, Tracer};
    use chameleon::util::json::{obj, Json};

    // With `--json` stdout carries exactly one JSON document (the same
    // object written to `--out`); all human chatter moves to stderr so
    // `chameleon loadgen --json | jq` works.
    let json_out = args.flag("json");
    macro_rules! say {
        ($($t:tt)*) => {
            if json_out { eprintln!($($t)*) } else { println!($($t)*) }
        };
    }

    let sys = system_config(args);
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 8000);
    let k = args.get_usize("k", 10);
    let n_nodes = args.get_usize("nodes", 2);
    let conns = args.get_usize("conns", 4).max(1);
    // Wide sweeps (hundreds of pipelined connections, each with a reader
    // clone) need more fds than the usual 1024 soft limit.
    let _ = chameleon::util::poll::raise_nofile((conns as u64 * 2 + 64).max(1024));
    let requests = args.get_usize("requests", 400).max(1);
    let n_unique = args.get_usize("unique", 64).max(1);
    let zipf_alpha = args.get_f64("zipf", 0.99);
    let batch_fraction = args.get_f64("batch-fraction", 0.2).clamp(0.0, 1.0);
    let policy = batch_policy(args);
    let out_path = args.get_or("out", "BENCH_serve.json");
    // Per-request end-to-end budget stamped on the wire (0 = unlimited)
    // and a client retry policy for shed replies that carry a
    // `retry_after_us` hint.
    let drive_opts = DriveOptions {
        deadline_us: args.get_u64("deadline-us", 0),
        retry: RetryPolicy {
            max_retries: args.get_u64("retries", 0) as u32,
            ..RetryPolicy::default()
        },
    };

    let arrival =
        if args.get("burst-period-s").is_some() || args.get("burst-duty").is_some() {
            Arrival::Bursty {
                period_s: args.get_f64("burst-period-s", 0.2).max(1e-3),
                duty: args.get_f64("burst-duty", 0.5).clamp(0.05, 1.0),
            }
        } else {
            Arrival::Poisson
        };
    let sweep: Vec<f64> = match args.get("sweep") {
        Some(s) => s
            .split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|p| {
                p.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad qps '{p}' in --sweep"))
            })
            .collect::<Result<_>>()?,
        None => vec![args.get_f64("qps", 200.0)],
    };
    anyhow::ensure!(
        !sweep.is_empty() && sweep.iter().all(|&q| q > 0.0),
        "offered loads must be positive"
    );

    // Fan-out the trace fit is observed at: the local node count, or the
    // number of remote addresses.
    let observed_nodes = match args.get("remote") {
        Some(spec) => spec.split(',').filter(|p| !p.trim().is_empty()).count().max(1),
        None => n_nodes,
    };
    let retriever = match args.get("remote") {
        Some(spec) => build_remote_retriever(ds, n, k, sys.seed, spec, &None)?,
        None => build_retriever(ds, n, n_nodes, k, false, &sys)?.0,
    };
    let tracer = Tracer::new(1 << 16);
    // Per-class SLO objectives, tracked live by the telemetry plane as
    // multi-window burn rates (scrapeable mid-run, reported at the end).
    let slo_ms = args.get_f64("slo-ms", 50.0);
    let slo_target = args.get_f64("slo-target", 0.99);
    let batch_slo_ms = args.get_f64("batch-slo-ms", slo_ms * 4.0);
    let qos = QosConfig {
        slo_interactive: Some(SloObjective {
            latency_us: (slo_ms * 1e3) as u64,
            target: slo_target,
            ..SloObjective::default()
        }),
        slo_batch: Some(SloObjective {
            latency_us: (batch_slo_ms * 1e3) as u64,
            target: slo_target,
            ..SloObjective::default()
        }),
        ..QosConfig::default()
    };
    let mut server = CoordinatorServer::spawn_qos(
        move || retriever,
        ServeMode::Concurrent(policy),
        qos,
        tracer.clone(),
    )?;
    let addr = server.addr;
    say!(
        "[loadgen] traced coordinator on {addr} ({observed_nodes} nodes, \
         {requests} reqs/point, {conns} conns)"
    );
    let mut metrics_srv = match args.get("metrics-addr") {
        Some(bind) => {
            let m = chameleon::telemetry::MetricsServer::spawn(bind, server.telemetry())?;
            say!("[loadgen] metrics on {}", m.addr);
            Some(m)
        }
        None => None,
    };

    // Query pool: `n_unique` vectors the Zipf stream indexes into.
    let qdata = SyntheticDataset::generate_sized(ds, 64, n_unique, sys.seed ^ 9);
    let queries: Vec<Vec<f32>> =
        (0..n_unique).map(|i| qdata.query(i % qdata.n_queries).to_vec()).collect();

    let mut points = Vec::new();
    let mut reports = Vec::new();
    for (pt, &qps) in sweep.iter().enumerate() {
        let cfg = LoadgenConfig {
            qps,
            n_requests: requests,
            arrival,
            zipf_alpha,
            n_unique,
            batch_fraction,
            seed: sys.seed.wrapping_add(pt as u64),
        };
        let sched = loadgen::schedule(&cfg);
        let deadline = Duration::from_secs_f64(sched.span_s() + 30.0);
        let rep =
            loadgen::drive_opts(addr, &queries, k, &sched, conns, deadline, &drive_opts)?;
        say!(
            "[loadgen] offered {:>6.0} q/s -> goodput {:>6.0} q/s  \
             p50 {:7.2} ms  p95 {:7.2} ms  p99 {:7.2} ms  ({}/{} replies, {} shed)",
            rep.offered_qps,
            rep.goodput_qps,
            rep.latency.p50 * 1e3,
            rep.latency.p95 * 1e3,
            rep.latency.p99 * 1e3,
            rep.received,
            rep.sent,
            rep.shed,
        );
        // Conservation line for smoke checks: every sent request must be
        // either answered (complete or partial) or explicitly shed —
        // lost=0 on a healthy server.
        say!(
            "[loadgen] accounting: sent={} complete={} partial={} shed={} lost={}",
            rep.sent,
            rep.complete(),
            rep.partial,
            rep.shed,
            rep.sent.saturating_sub(rep.received + rep.shed),
        );
        if rep.retries > 0 {
            say!(
                "[loadgen] retries: {} sent, {} recovered (retry-success rate {:.0}%)",
                rep.retries,
                rep.retry_success,
                rep.retry_success_rate() * 100.0,
            );
        }
        points.push(obj(vec![
            ("offered_qps", Json::Num(rep.offered_qps)),
            ("goodput_qps", Json::Num(rep.goodput_qps)),
            ("sent", Json::Num(rep.sent as f64)),
            ("received", Json::Num(rep.received as f64)),
            ("partial", Json::Num(rep.partial as f64)),
            ("shed", Json::Num(rep.shed as f64)),
            ("retries", Json::Num(rep.retries as f64)),
            ("retry_success", Json::Num(rep.retry_success as f64)),
            ("wall_s", Json::Num(rep.wall_s)),
            ("p50_ms", Json::Num(rep.latency.p50 * 1e3)),
            ("p95_ms", Json::Num(rep.latency.p95 * 1e3)),
            ("p99_ms", Json::Num(rep.latency.p99 * 1e3)),
            (
                "interactive_p99_ms",
                rep.interactive
                    .as_ref()
                    .map_or(Json::Null, |s| Json::Num(s.p99 * 1e3)),
            ),
            (
                "batch_p99_ms",
                rep.batch.as_ref().map_or(Json::Null, |s| Json::Num(s.p99 * 1e3)),
            ),
        ]));
        reports.push(rep);
    }
    let knee = loadgen::measured_knee_qps(&reports);
    say!("[loadgen] measured knee: {knee:.0} q/s");

    // Keep the coordinator (and metrics endpoint) alive after the sweep
    // so external scrapers / `chameleon top --remote` can read the final
    // counters before teardown.
    let linger_ms = args.get_u64("scrape-linger-ms", 0);
    if linger_ms > 0 {
        say!("[loadgen] lingering {linger_ms} ms for external scrapes");
        std::thread::sleep(Duration::from_millis(linger_ms));
    }

    // SLO burn reports straight off the live telemetry plane.
    let fin = |v: f64| if v.is_finite() { v } else { 1e9 };
    let burns = server.telemetry().burn_rates();
    for b in &burns {
        say!(
            "[loadgen] slo tenant={} class={} latency_burn {:.2}/{:.2} \
             availability_burn {:.2}/{:.2} p99 {:.2} ms ({} in window)",
            b.tenant,
            b.class,
            fin(b.latency.fast),
            fin(b.latency.slow),
            fin(b.availability.fast),
            fin(b.availability.slow),
            b.p99_us as f64 / 1e3,
            b.window_count,
        );
    }
    if let Some(m) = metrics_srv.as_mut() {
        m.shutdown();
    }
    server.shutdown();

    // Offline half: aggregate the spans the run left in the ring.
    let events = tracer.snapshot();
    let a = analyze(&events);
    let rendered = a.render();
    if json_out {
        eprint!("{rendered}");
    } else {
        print!("{rendered}");
    }
    let present: Vec<&str> = a.kinds_present().iter().map(|kind| kind.name()).collect();
    say!("TRACE_SPANS ok: {}", present.join(","));
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, events_to_json(&events).dump())
            .with_context(|| format!("writing trace dump '{path}'"))?;
        say!("[loadgen] wrote {path} ({} spans)", events.len());
    }

    // Fit the capacity model and compare its knee against the measured one.
    let st = StageTimes::from_analysis(&a, observed_nodes);
    let planner = CapacityPlanner::new(st, 4 * ds.d, 12 * k);
    let predicted_knee = planner.saturation_qps(observed_nodes);
    let plan = planner.render(knee.max(1.0), args.get_f64("p99-slo-ms", slo_ms) * 1e-3);
    if json_out {
        eprint!("{plan}");
    } else {
        print!("{plan}");
    }
    say!(
        "[loadgen] predicted knee at {observed_nodes} nodes: {predicted_knee:.0} q/s \
         (measured {knee:.0} q/s)"
    );

    let report = obj(vec![
        ("bench", Json::Str("serve_loadgen".to_string())),
        ("dataset", Json::Str(ds.name.to_string())),
        ("n", Json::Num(n as f64)),
        ("k", Json::Num(k as f64)),
        ("nodes", Json::Num(observed_nodes as f64)),
        ("conns", Json::Num(conns as f64)),
        ("requests_per_point", Json::Num(requests as f64)),
        ("seed", Json::Num(sys.seed as f64)),
        ("sweep", Json::Arr(points)),
        ("measured_knee_qps", Json::Num(knee)),
        ("predicted_knee_qps", Json::Num(predicted_knee)),
        ("slo", Json::Arr(burns.iter().map(|b| b.to_json()).collect())),
        (
            "stages",
            obj(vec![
                ("lut_s", Json::Num(st.lut_s)),
                ("scan_s", Json::Num(st.scan_s)),
                ("merge_s", Json::Num(st.merge_s)),
                ("reply_s", Json::Num(st.reply_s)),
                ("cache_probe_s", Json::Num(st.cache_probe_s)),
                ("spec_verify_s", Json::Num(st.spec_verify_s)),
            ]),
        ),
    ]);
    std::fs::write(out_path, report.dump())
        .with_context(|| format!("writing {out_path}"))?;
    say!("wrote {out_path}");
    if json_out {
        println!("{}", report.dump());
    }
    Ok(())
}

/// `chameleon top` — live dashboard over a running coordinator, scraped
/// through the `StatsRequest`/`StatsResponse` protocol frames (the same
/// wire the tenants use, so it works against any reachable coordinator,
/// no sidecar needed).
fn top_cmd(args: &Args) -> Result<()> {
    use chameleon::telemetry::render_dashboard;

    let addr: std::net::SocketAddr = args
        .get("remote")
        .ok_or_else(|| anyhow::anyhow!("top needs --remote host:port"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad --remote address: {e}"))?;
    let once = args.flag("once");
    let json = args.flag("json");
    let prefix = args.get_or("prefix", "");
    let interval =
        Duration::from_millis(args.get_u64("interval-ms", 1000).max(100));
    let mut client = CoordinatorClient::connect(addr, 0)?;
    loop {
        let doc = client.stats(prefix)?;
        if let Some(err) = doc.get("error").and_then(|e| e.as_str()) {
            bail!("coordinator refused stats: {err}");
        }
        if json {
            println!("{}", doc.dump());
        } else {
            if !once {
                // Clear + home between refreshes, full-screen style.
                print!("\x1b[2J\x1b[H");
            }
            print!("{}", render_dashboard(&doc));
        }
        if once {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// Elastic-tier config from the serve knobs: `Some` when replication or
/// hedging is requested, `None` for the flat legacy path.
fn cluster_config(replication: usize, hedge_quantile: f64) -> Option<ClusterConfig> {
    if replication <= 1 && hedge_quantile <= 0.0 {
        return None;
    }
    let mut cfg = ClusterConfig {
        pin_workers: chameleon::util::affinity::env_pin_requested(),
        ..Default::default()
    };
    if hedge_quantile > 0.0 {
        cfg.hedge = Some(HedgeConfig {
            quantile: hedge_quantile.min(0.999),
            ..Default::default()
        });
    }
    Some(cfg)
}

/// Retrieval stack over an in-process replicated cluster: the same index
/// carved into `n_nodes / replication` shards with `replication` replicas
/// each, dispatched through the cluster engine.
fn build_local_clustered_retriever(
    ds: &'static config::DatasetConfig,
    n: usize,
    n_nodes: usize,
    replication: usize,
    k: usize,
    cfg: ClusterConfig,
    sys: &SystemConfig,
) -> Result<Retriever> {
    let data = SyntheticDataset::generate_sized(ds, n, 256, sys.seed);
    let nlist = (n as f64).sqrt() as usize;
    eprintln!(
        "[build] clustered dataset {} n={n} nlist={nlist} nodes={n_nodes} \
         replication={replication}",
        ds.name
    );
    let index =
        IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, sys.seed ^ 1);
    let engine = ClusterEngine::local(&index, n_nodes, replication, k, cfg)?;
    let dispatcher = Dispatcher::clustered(engine, k);
    let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, sys.seed ^ 2);
    Ok(Retriever::new(ds, index, dispatcher, corpus))
}

/// Retrieval stack over running `chamvs-node` processes: mirror the node
/// binary's deterministic (dataset, n, seed) shard contract for the probe
/// index, and connect one `RemoteNode` backend per address. With an
/// elastic-tier config, nodes are placed into the cluster map by the
/// shard they declare in their Hello (replicated addresses declare the
/// same shard); otherwise the flat one-node-per-shard dispatcher is kept.
fn build_remote_retriever(
    ds: &'static config::DatasetConfig,
    n: usize,
    k: usize,
    seed: u64,
    spec: &str,
    cluster_cfg: &Option<ClusterConfig>,
) -> Result<Retriever> {
    let data = SyntheticDataset::generate_sized(ds, n, 16, seed);
    let nlist = (n as f64).sqrt() as usize;
    eprintln!("[serve-net] building probe index ({} n={n} nlist={nlist})", ds.name);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, seed ^ 1);
    let mut remotes: Vec<RemoteNode> = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let addr: std::net::SocketAddr = part
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad memory-node address '{part}'"))?;
        let node = RemoteNode::connect(addr, k)?;
        // The handshake carries the node's PQ geometry: fail fast on a
        // (dataset, n, seed) contract mismatch instead of silently
        // merging garbage distances.
        anyhow::ensure!(
            node.m() == ds.m,
            "memory node {} reports PQ width m={} but dataset {} uses m={} — \
             start chamvs-node with the same --dataset/--n/--seed",
            part.trim(),
            node.m(),
            ds.name,
            ds.m
        );
        eprintln!(
            "[serve-net] connected memory node {} (shard {}/{})",
            part.trim(),
            node.shard(),
            node.n_shards()
        );
        remotes.push(node);
    }
    anyhow::ensure!(!remotes.is_empty(), "--remote needs at least one address");
    let dispatcher = match cluster_cfg {
        Some(cfg) => {
            let n_shards = remotes[0].n_shards();
            anyhow::ensure!(
                remotes.iter().all(|r| r.n_shards() == n_shards),
                "memory nodes disagree on the shard count — restart them \
                 with one consistent --shards"
            );
            let nodes: Vec<ClusterNode> = remotes
                .into_iter()
                .enumerate()
                .map(|(i, r)| ClusterNode {
                    id: i as u32,
                    shard: r.shard(),
                    backend: Box::new(r) as Box<dyn ScanBackend>,
                })
                .collect();
            let engine = ClusterEngine::new(nodes, n_shards, *cfg)?;
            eprintln!(
                "[serve-net] cluster: {} shards, min replication {}",
                engine.n_shards(),
                engine.map().min_replication()
            );
            Dispatcher::clustered(engine, k)
        }
        None => Dispatcher::over(
            remotes
                .into_iter()
                .map(|r| Box::new(r) as Box<dyn ScanBackend>)
                .collect(),
            k,
        ),
    };
    let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, seed ^ 2);
    Ok(Retriever::new(ds, index, dispatcher, corpus))
}

/// `chameleon cluster` — build an in-process replicated cluster, kill one
/// replica mid-workload, and report the elastic tier's behaviour:
/// assignment map, per-node health, failover/hedge counters, and whether
/// every post-failure result stayed bit-identical to a flat reference.
fn cluster_cmd(args: &Args) -> Result<()> {
    let sys = system_config(args);
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 8000);
    let n_nodes = args.get_usize("nodes", 4);
    let replication = args.get_usize("replication", 2).max(1);
    let n_queries = args.get_usize("queries", 32).max(2);
    let hedge_quantile = args.get_f64("hedge-quantile", 0.0);
    let k = args.get_usize("k", 10);

    anyhow::ensure!(
        n_nodes % replication == 0,
        "--nodes {n_nodes} must be a multiple of --replication {replication}"
    );
    let n_shards = n_nodes / replication;
    let data = SyntheticDataset::generate_sized(ds, n, n_queries, sys.seed);
    let nlist = (n as f64).sqrt() as usize;
    eprintln!(
        "[cluster] building index ({} n={n} nlist={nlist}), {n_shards} shards x \
         {replication} replicas",
        ds.name
    );
    let index =
        IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, sys.seed ^ 1);

    let mut cfg = cluster_config(replication, hedge_quantile)
        .unwrap_or_default();
    cfg.pin_workers = chameleon::util::affinity::env_pin_requested();
    // Survive a dead replica without waiting out long socket deadlines,
    // and pin the victim as its shard's primary so the demo's mid-run
    // death deterministically happens (health-aware selection is sticky
    // and could starve the victim of scans).
    cfg.attempt_timeout = Duration::from_secs(5);
    cfg.select = chameleon::cluster::SelectPolicy::Static;
    let plan = ClusterMap::carve_plan(n_nodes, replication)?;
    let kill_at = (n_queries / 4).max(1);
    let victim: u32 = 0;
    let nodes: Vec<ClusterNode> = plan
        .into_iter()
        .map(|(id, shard)| {
            let backend: Box<dyn ScanBackend> = Box::new(MemoryNode::new(
                Shard::carve(&index, shard, n_shards),
                ScanEngine::Native,
                k,
            ));
            let backend = if id == victim && replication > 1 {
                Box::new(FailingBackend::new(backend, kill_at))
                    as Box<dyn ScanBackend>
            } else {
                backend
            };
            ClusterNode { id, shard, backend }
        })
        .collect();
    let engine = ClusterEngine::new(nodes, n_shards, cfg)?;
    let mut clustered = Dispatcher::clustered(engine, k);

    // Flat reference: one node per shard over the same carve.
    let flat_nodes: Vec<MemoryNode> = (0..n_shards)
        .map(|s| {
            MemoryNode::new(
                Shard::carve(&index, s, n_shards),
                ScanEngine::Native,
                k,
            )
        })
        .collect();
    let mut flat = Dispatcher::new(flat_nodes, k);

    if replication > 1 {
        println!(
            "[cluster] node {victim} dies after query {kill_at} (of {n_queries})"
        );
    }
    let mut identical = 0usize;
    for qi in 0..n_queries {
        let q = data.query(qi % data.n_queries);
        let lists = index.probe(q, ds.nprobe);
        let want = flat.search(q, &index.pq.centroids, &lists, ds.nprobe)?;
        let got = clustered.search(q, &index.pq.centroids, &lists, ds.nprobe)?;
        if got.topk == want.topk {
            identical += 1;
        }
    }
    println!(
        "[cluster] {identical}/{n_queries} queries bit-identical to the flat \
         reference (zero failed)"
    );
    let engine = clustered.cluster().expect("clustered dispatcher");
    println!("{}", engine.render_report());
    anyhow::ensure!(
        identical == n_queries,
        "cluster results diverged from the flat reference"
    );
    Ok(())
}

/// `chameleon chaos` — seeded end-to-end fault-injection harness. Real
/// memory-node servers sit behind deterministic chaos proxies (bit flips,
/// connection cuts, stalls, all derived from `--seed`); mid-run every
/// replica of shard 0 blacks out, and the cluster must keep answering as
/// coverage-tagged partial results with zero hard failures. After the
/// blackout the healed replicas must pass half-open probation and return
/// the tier to results bit-identical to a fault-free flat reference.
fn chaos_cmd(args: &Args) -> Result<()> {
    use chameleon::cluster::{DegradedPolicy, RoundOptions, SelectPolicy};
    use chameleon::net::fault::{ChaosProxy, FaultProfile};
    use chameleon::net::server::NodeServer;
    use std::time::Instant;

    let sys = system_config(args);
    let ds = config::dataset_by_name(args.get_or("dataset", "SIFT"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 4000);
    let n_nodes = args.get_usize("nodes", 4);
    let replication = args.get_usize("replication", 2).max(1);
    let n_queries = args.get_usize("queries", 48).max(6);
    let k = args.get_usize("k", 10);
    let min_coverage = args.get_f64("min-coverage", 0.0).clamp(0.0, 1.0);
    let deadline = Duration::from_millis(args.get_u64("deadline-ms", 500));
    let blackout = Duration::from_millis(args.get_u64("blackout-ms", 400));
    let profile = FaultProfile {
        flips: args.get_usize("flips", 2),
        cuts: args.get_usize("cuts", 1),
        stalls: args.get_usize("stalls", 1),
        ..FaultProfile::default()
    };
    anyhow::ensure!(
        n_nodes % replication == 0,
        "--nodes {n_nodes} must be a multiple of --replication {replication}"
    );
    anyhow::ensure!(
        replication > 1,
        "--replication must be >= 2: the blackout darkens every replica of \
         one shard, and with r=1 that is the whole dataset"
    );
    let n_shards = n_nodes / replication;

    let data = SyntheticDataset::generate_sized(ds, n, n_queries, sys.seed);
    let nlist = (n as f64).sqrt() as usize;
    eprintln!(
        "[chaos] seed {}: {n_shards} shards x {replication} replicas behind \
         fault proxies ({} flips / {} cuts / {} stalls per connection)",
        sys.seed, profile.flips, profile.cuts, profile.stalls
    );
    let index =
        IvfPqIndex::build(&data.data, data.n, data.d, ds.m, nlist, sys.seed ^ 1);

    // One real node server per replica, each rebuilding its carve from the
    // same deterministic (dataset, n, seed) contract, each reachable only
    // through its own seeded chaos proxy.
    let plan = ClusterMap::carve_plan(n_nodes, replication)?;
    let mut servers: Vec<NodeServer> = Vec::new();
    let mut proxies: Vec<ChaosProxy> = Vec::new();
    let mut proxy_shards: Vec<usize> = Vec::new();
    let mut nodes: Vec<ClusterNode> = Vec::new();
    for (id, shard) in plan {
        let (seed, nq) = (sys.seed, n_queries);
        let cb = index.pq.centroids.clone();
        let server = NodeServer::spawn_with(
            move || {
                let d = SyntheticDataset::generate_sized(ds, n, nq, seed);
                let idx =
                    IvfPqIndex::build(&d.data, d.n, d.d, ds.m, nlist, seed ^ 1);
                MemoryNode::new(
                    Shard::carve(&idx, shard, n_shards),
                    ScanEngine::Native,
                    k,
                )
            },
            cb,
            ds.nprobe,
        )?;
        let proxy = ChaosProxy::spawn(
            server.addr,
            sys.seed ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
            profile,
        )?;
        // A seeded flip can land inside the very first Hello exchange;
        // each retry opens a fresh proxied connection with a new schedule.
        let mut remote = None;
        for _ in 0..5 {
            match RemoteNode::connect(proxy.addr, k) {
                Ok(r) => {
                    remote = Some(r);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        }
        let remote = remote
            .ok_or_else(|| anyhow::anyhow!("node {id} unreachable through its proxy"))?;
        nodes.push(ClusterNode { id, shard, backend: Box::new(remote) });
        proxy_shards.push(shard);
        proxies.push(proxy);
        servers.push(server);
    }

    let cfg = ClusterConfig {
        select: SelectPolicy::Static,
        attempt_timeout: Duration::from_secs(2),
        ..Default::default()
    };
    let mut engine = ClusterEngine::new(nodes, n_shards, cfg)?;
    // Short probation backoff so healed replicas re-probe within the run.
    engine.health_mut().breaker_backoff = Duration::from_millis(100);
    let mut clustered = Dispatcher::clustered(engine, k);

    // Fault-free flat reference over the same carve.
    let flat_nodes: Vec<MemoryNode> = (0..n_shards)
        .map(|s| {
            MemoryNode::new(
                Shard::carve(&index, s, n_shards),
                ScanEngine::Native,
                k,
            )
        })
        .collect();
    let mut flat = Dispatcher::new(flat_nodes, k);

    let opts = RoundOptions {
        degraded: DegradedPolicy::ServePartial { min_coverage },
        deadline: None,
    };
    let kill_at = n_queries / 3;
    let (mut complete, mut partial, mut failed, mut mismatched) =
        (0usize, 0usize, 0usize, 0usize);
    for qi in 0..n_queries {
        if qi == kill_at {
            println!(
                "[chaos] blackout: every replica of shard 0 dark for {blackout:?} \
                 (after query {kill_at} of {n_queries})"
            );
            for (p, &shard) in proxies.iter().zip(&proxy_shards) {
                if shard == 0 {
                    p.blackout(blackout);
                }
            }
        }
        let q = data.query(qi % data.n_queries);
        let lists = index.probe(q, ds.nprobe);
        let want = flat.search(q, &index.pq.centroids, &lists, ds.nprobe)?;
        let per_query =
            RoundOptions { deadline: Some(Instant::now() + deadline), ..opts };
        match clustered.search_opts(
            q,
            &index.pq.centroids,
            &lists,
            ds.nprobe,
            qi as u64,
            &per_query,
        ) {
            Ok(got) if got.is_partial() => partial += 1,
            Ok(got) => {
                complete += 1;
                if got.topk != want.topk {
                    mismatched += 1;
                }
            }
            Err(e) => {
                failed += 1;
                eprintln!("[chaos] query {qi} hard-failed: {e:#}");
            }
        }
        std::thread::sleep(Duration::from_millis(10));
    }

    // Recovery: keep probing with one reference query until the healed
    // replicas clear half-open probation and the answer is complete and
    // bit-identical again.
    let q = data.query(0);
    let lists = index.probe(q, ds.nprobe);
    let want = flat.search(q, &index.pq.centroids, &lists, ds.nprobe)?;
    let t0 = Instant::now();
    let mut recovered = false;
    while t0.elapsed() < Duration::from_secs(15) {
        if let Ok(got) =
            clustered.search_opts(q, &index.pq.centroids, &lists, ds.nprobe, 0, &opts)
        {
            if !got.is_partial() && got.topk == want.topk {
                recovered = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }

    let engine = clustered.cluster().expect("clustered dispatcher");
    println!("{}", engine.render_report());
    anyhow::ensure!(
        failed == 0,
        "{failed} hard failures — ServePartial must absorb a dark shard"
    );
    anyhow::ensure!(
        mismatched == 0,
        "{mismatched} complete results diverged from the flat reference \
         (corruption slipped past the frame checksums)"
    );
    anyhow::ensure!(
        complete + partial == n_queries,
        "accounting hole: complete {complete} + partial {partial} != sent {n_queries}"
    );
    anyhow::ensure!(
        partial >= 1,
        "blackout produced no partial results — the degraded path never ran"
    );
    anyhow::ensure!(
        recovered,
        "tier never returned to complete, bit-identical service after the blackout"
    );
    println!(
        "CHAOS ok: sent={n_queries} complete={complete} partial={partial} \
         failed=0 recovered=yes"
    );
    for p in &mut proxies {
        p.stop();
    }
    for s in &mut servers {
        s.shutdown();
    }
    Ok(())
}

fn report_cmd(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let n = args.get_usize("n", 20_000);
    let q = args.get_usize("queries", 64);
    let seed = args.get_u64("seed", 42);
    let run_one = |id: &str| -> Result<()> {
        let text = match id {
            "fig7" => report::fig7_probability(),
            "fig8" => report::fig8_resources(),
            "fig9" => report::fig9_search_latency(n, q, seed),
            "fig10" => report::fig10_scalability(n, q, seed),
            "fig11" => report::fig11_latency(512),
            "fig12" => report::fig12_throughput(512),
            "fig13" => report::fig13_ratio(),
            "table4" => report::table4_resources(),
            "table5" => report::table5_energy(),
            "recall" => report::recall_report(n.min(20_000), q.min(32), seed),
            "retcache" => report::retcache_report(n.min(20_000), seed),
            "dispatch" => report::dispatch_report(n.min(20_000), q, seed),
            "trace" => {
                let slo = args
                    .get("slo-ms")
                    .map(|_| {
                        (args.get_f64("slo-ms", 50.0), args.get_f64("slo-target", 0.99))
                    });
                if args.flag("json") {
                    report::trace_report_json(
                        args.get("trace"),
                        n.min(8000),
                        q.min(16),
                        seed,
                        slo,
                    )?
                } else {
                    report::trace_report(
                        args.get("trace"),
                        n.min(8000),
                        q.min(16),
                        seed,
                        slo,
                    )?
                }
            }
            other => bail!("unknown report '{other}'"),
        };
        println!("{text}");
        Ok(())
    };
    if which == "all" {
        for id in [
            "fig7", "fig8", "table4", "table5", "fig9", "fig10", "fig11", "fig12",
            "fig13", "recall", "retcache", "dispatch", "trace",
        ] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn system_config(args: &Args) -> SystemConfig {
    let mut sys = SystemConfig::default();
    if let Some(dir) = args.get("artifacts") {
        sys.artifacts_dir = dir.to_string();
    }
    sys.seed = args.get_u64("seed", sys.seed);
    sys
}
