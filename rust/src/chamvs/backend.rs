//! Scan execution backends, at two altitudes:
//!
//! * [`ScanBackend`] — the unit of dispatch: anything that can execute a
//!   round of [`ScanJob`]s and return one node-local top-K per job. The
//!   in-process [`MemoryNode`](super::node::MemoryNode) and the remote
//!   [`RemoteNode`](crate::net::client::RemoteNode) (one TCP connection to
//!   a `chamvs-node` server) both implement it, so the
//!   [`Dispatcher`](super::dispatcher::Dispatcher)'s batched rounds run
//!   identically over either — the unified network path of the serving
//!   core.
//! * [`SearchBackend`] — the four system configurations of Fig 9:
//!   CPU (monolithic), CPU-GPU (GPU index scan, CPU PQ scan), FPGA-CPU
//!   (CPU index scan, FPGA PQ scan over the network), FPGA-GPU (GPU index
//!   scan, FPGA PQ scan — the ChamVS design point).
//!
//! Numerics always run for real (native rust or PJRT artifacts); the
//! *latency* of each hardware stage comes from the hwmodel module,
//! composed per configuration exactly as the paper composes its systems.

use anyhow::Result;

use super::dispatcher::{BatchQuery, Dispatcher, SearchResult};
use super::node::NodeResult;
use crate::config::DatasetConfig;
use crate::hwmodel::fpga::FpgaModel;
use crate::hwmodel::{CpuModel, GpuModel};
use crate::ivf::index::IvfPqIndex;

/// One scan job of a dispatch round: the query, its probed lists, and the
/// per-query (m, 256) ADC table shared by every local node. `lut` borrows
/// a slice of the round's reusable LUT arena (zero per-job allocation)
/// and is left empty when no backend in the round wants one (remote nodes
/// build their own server-side; see [`ScanBackend::wants_lut`]).
pub struct ScanJob<'a> {
    /// Full D-dim query vector.
    pub query: &'a [f32],
    /// Probed IVF list ids (from ChamVS.idx).
    pub lists: &'a [u32],
    /// Prebuilt (m, 256) distance LUT slice, or empty (remote-only rounds).
    pub lut: &'a [f32],
    /// Probe width (drives the per-node FPGA latency model).
    pub nprobe: usize,
}

/// A scan execution target the dispatcher can fan a round out to: one
/// disaggregated memory node, in-process or behind a socket. Implementors
/// must be `Send` — the dispatcher's scoped thread pool moves `&mut`
/// chunks of the node set across worker threads.
pub trait ScanBackend: Send {
    /// PQ width of the shard behind this backend (all nodes of one
    /// dispatcher share it; used for LUT construction and dim checks).
    fn m(&self) -> usize;

    /// The FPGA cycle model pricing scans on this node (paper-scale
    /// latency attribution; remote nodes carry the same default model).
    fn fpga(&self) -> &FpgaModel;

    /// Whether this backend consumes the dispatcher-prebuilt LUT. Remote
    /// nodes return false: the node server derives its own table, so the
    /// coordinator skips the per-query LUT build for remote-only rounds.
    fn wants_lut(&self) -> bool {
        true
    }

    /// Execute every job of a dispatch round on this backend, in order,
    /// returning one node-local [`NodeResult`] per job. This is the unit
    /// of work one dispatcher pool thread runs — and, for a remote node,
    /// exactly one network round trip regardless of the batch size.
    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>>;

    /// Ask the backend to shut down (no-op for in-process nodes).
    fn shutdown(&mut self) {}

    /// Ask the backend to retire gracefully: stop taking new work and
    /// exit once idle. No-op for in-process nodes; a remote node forwards
    /// a `Drain` frame so the `chamvs-node` process exits when its
    /// connection closes (the cluster's live node-retirement path).
    fn drain(&mut self) {}
}

/// Which Fig 9 system configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Cpu,
    CpuGpu,
    FpgaCpu,
    FpgaGpu,
}

impl BackendKind {
    pub const ALL: [BackendKind; 4] =
        [BackendKind::Cpu, BackendKind::CpuGpu, BackendKind::FpgaCpu, BackendKind::FpgaGpu];

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Cpu => "CPU",
            BackendKind::CpuGpu => "CPU-GPU",
            BackendKind::FpgaCpu => "FPGA-CPU",
            BackendKind::FpgaGpu => "FPGA-GPU",
        }
    }

    pub fn uses_fpga_scan(&self) -> bool {
        matches!(self, BackendKind::FpgaCpu | BackendKind::FpgaGpu)
    }

    pub fn uses_gpu_index(&self) -> bool {
        matches!(self, BackendKind::CpuGpu | BackendKind::FpgaGpu)
    }
}

/// Per-query latency decomposition for one backend (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyBreakdown {
    pub index_scan_s: f64,
    pub lut_s: f64,
    pub pq_scan_s: f64,
    pub network_s: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.index_scan_s + self.lut_s + self.pq_scan_s + self.network_s
    }
}

/// A runnable vector-search system in one of the Fig 9 configurations.
pub struct SearchBackend {
    pub kind: BackendKind,
    pub ds: &'static DatasetConfig,
    pub cpu: CpuModel,
    pub gpu: GpuModel,
    /// Execution engine: dispatcher over the (possibly single-node)
    /// memory-node set. Backends without FPGAs still execute through it —
    /// only the latency attribution differs.
    pub dispatcher: Dispatcher,
    /// Scale factor from our scaled dataset to paper-scale latencies:
    /// modeled stages use paper-scale vector counts directly.
    pub paper_scale: bool,
}

impl SearchBackend {
    pub fn new(
        kind: BackendKind,
        ds: &'static DatasetConfig,
        dispatcher: Dispatcher,
        paper_scale: bool,
    ) -> SearchBackend {
        SearchBackend {
            kind,
            ds,
            cpu: CpuModel::default(),
            gpu: GpuModel::default(),
            dispatcher,
            paper_scale,
        }
    }

    fn nlist(&self) -> usize {
        if self.paper_scale {
            self.ds.nlist_paper
        } else {
            self.ds.nlist_scaled
        }
    }

    /// Run one query end-to-end: real numerics via the dispatcher, latency
    /// composed from the stage models for this backend.
    ///
    /// With `paper_scale`, the query's scanned-code count is projected to
    /// paper scale by *relative probe mass*: this query's scan size vs the
    /// scaled index's expected size, times the paper's expected size —
    /// preserving per-query variation (the Fig 9 violin spread) across
    /// the scale change.
    pub fn search(
        &mut self,
        index: &IvfPqIndex,
        query: &[f32],
        k: usize,
    ) -> Result<(SearchResult, LatencyBreakdown)> {
        let nprobe = self.ds.nprobe;
        let lists = index.probe(query, nprobe);
        let result =
            self.dispatcher.search(query, &index.pq.centroids, &lists, nprobe)?;
        let _ = k;
        let n_codes = self.project_n_codes(index, result.n_scanned as f64);
        let lat = self.latency_model(n_codes);
        Ok((result, lat))
    }

    /// Scanned-code count at the modeled scale: with `paper_scale`, the
    /// scaled count is projected by *relative probe mass* (this query's
    /// scan size vs the scaled index's expected size, times the paper's
    /// expected size), preserving per-query variation across the scale
    /// change; otherwise the raw count. Takes f64 so batch means project
    /// without integer truncation.
    fn project_n_codes(&self, index: &IvfPqIndex, n_scanned: f64) -> usize {
        if self.paper_scale {
            let nprobe = self.ds.nprobe;
            let expected =
                index.len() as f64 * nprobe as f64 / index.nlist as f64;
            let rel = n_scanned / expected.max(1.0);
            (rel * self.ds.n_paper as f64 * nprobe as f64
                / self.ds.nlist_paper as f64) as usize
        } else {
            n_scanned.round() as usize
        }
    }

    /// Run a batch of queries end-to-end in ONE parallel dispatch round
    /// (real numerics via [`Dispatcher::search_batch`]; per-node work
    /// queues, k-way merge per query), plus the modeled batched latency
    /// for this backend at the mean projected scan size.
    pub fn search_many(
        &mut self,
        index: &IvfPqIndex,
        queries: &[&[f32]],
    ) -> Result<(Vec<SearchResult>, f64)> {
        anyhow::ensure!(!queries.is_empty(), "empty query batch");
        let nprobe = self.ds.nprobe;
        let lists: Vec<Vec<u32>> =
            queries.iter().map(|q| index.probe(q, nprobe)).collect();
        let batch: Vec<BatchQuery> = queries
            .iter()
            .zip(&lists)
            .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
            .collect();
        let results =
            self.dispatcher.search_batch(&batch, &index.pq.centroids, nprobe)?;
        // Mean in f64: integer division truncated up to B-1 codes per
        // query before the paper-scale projection amplified the error.
        let mean_scanned = results.iter().map(|r| r.n_scanned).sum::<usize>() as f64
            / results.len() as f64;
        let n_codes = self.project_n_codes(index, mean_scanned);
        let modeled = self.batch_latency_model(queries.len(), n_codes);
        Ok((results, modeled))
    }

    /// Latency model for a query scanning `n_codes` PQ codes (already at
    /// the modeled scale).
    pub fn latency_model(&self, n_codes: usize) -> LatencyBreakdown {
        let ds = self.ds;
        let nlist = self.nlist();
        let n_nodes = self.dispatcher.fan_out().max(1);
        let mut lat = LatencyBreakdown::default();

        // Stage 1: IVF index scan.
        lat.index_scan_s = if self.kind.uses_gpu_index() {
            self.gpu.index_scan_latency(nlist, ds.d, 1)
        } else {
            self.cpu.index_scan_latency(nlist, ds.d)
        };

        // Stage 2+3: LUT construction + PQ scan.
        if self.kind.uses_fpga_scan() {
            let fpga = self.dispatcher.fpga();
            let per_node = n_codes / n_nodes;
            let s = fpga.query_latency(per_node, ds.m, ds.nprobe, self.dispatcher.k);
            lat.lut_s = s.lut_s;
            lat.pq_scan_s = s.scan_s + s.kselect_drain_s;
            // Stage 4: network (disaggregated backends only).
            let query_bytes = 4 * ds.d + 4 * ds.nprobe;
            lat.network_s = self
                .dispatcher
                .net
                .query_roundtrip(n_nodes, query_bytes, 12 * self.dispatcher.k);
        } else {
            lat.lut_s = self.cpu.lut_latency(ds.m, ds.dsub(), ds.nprobe);
            lat.pq_scan_s = self.cpu.scan_latency(n_codes, ds.m);
            lat.network_s = 0.0; // monolithic server
        }
        lat
    }

    /// Batched-query latency (batch members pipeline through each stage).
    pub fn batch_latency_model(&self, b: usize, n_codes: usize) -> f64 {
        let one = self.latency_model(n_codes);
        if self.kind.uses_fpga_scan() {
            // Accelerator pipelines queries; stages overlap.
            one.network_s
                + one.index_scan_s
                + one.lut_s
                + b as f64 * one.pq_scan_s.max(one.lut_s)
        } else {
            // CPU batch model (limited intra-query parallelism; see
            // CpuModel::query_latency). GPU-index variants still pay the
            // scan on CPU, so the same model applies with the index stage
            // swapped.
            let ds = self.ds;
            let scan_and_lut = self.cpu.query_latency(
                b,
                n_codes,
                ds.m,
                ds.dsub(),
                self.nlist(),
                ds.nprobe,
            ) - self.cpu.index_scan_latency(self.nlist(), ds.d)
                * (b as f64 / self.cpu.n_cores as f64).ceil();
            let idx = if self.kind.uses_gpu_index() {
                self.gpu.index_scan_latency(self.nlist(), ds.d, b)
            } else {
                self.cpu.index_scan_latency(self.nlist(), ds.d)
                    * (b as f64 / self.cpu.n_cores as f64).ceil()
            };
            idx + scan_and_lut
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::node::{MemoryNode, ScanEngine};
    use crate::config::SIFT;
    use crate::ivf::shard::Shard;
    use crate::util::rng::Rng;

    fn toy_backend(kind: BackendKind) -> (SearchBackend, IvfPqIndex, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (2000, 128, 16, 32);
        let data = rng.normal_vec(n * d);
        let idx = IvfPqIndex::build(&data, n, d, m, nlist, 3);
        let nodes =
            vec![MemoryNode::new(Shard::carve(&idx, 0, 1), ScanEngine::Native, 10)];
        let disp = Dispatcher::new(nodes, 10);
        (SearchBackend::new(kind, &SIFT, disp, true), idx, d)
    }

    #[test]
    fn fig9_ordering_fpga_gpu_fastest() {
        // Paper-scale modeled latencies must order: FPGA-GPU < FPGA-CPU
        // < CPU, and CPU-GPU ~ CPU (scan-dominated).
        let scanned = 1_000_000;
        let lat = |kind| {
            let (b, _, _) = toy_backend(kind);
            b.latency_model(scanned).total()
        };
        let cpu = lat(BackendKind::Cpu);
        let cpu_gpu = lat(BackendKind::CpuGpu);
        let fpga_cpu = lat(BackendKind::FpgaCpu);
        let fpga_gpu = lat(BackendKind::FpgaGpu);
        assert!(fpga_gpu < fpga_cpu, "{fpga_gpu} vs {fpga_cpu}");
        assert!(fpga_cpu < cpu, "{fpga_cpu} vs {cpu}");
        assert!(cpu_gpu < cpu * 1.05, "{cpu_gpu} vs {cpu}");
        // Speedup bands of Fig 9 at SIFT scale.
        let speedup = cpu / fpga_gpu;
        assert!(speedup > 2.0 && speedup < 30.0, "speedup {speedup}");
    }

    #[test]
    fn search_returns_numerics_and_latency() {
        let (mut b, idx, d) = toy_backend(BackendKind::FpgaGpu);
        let mut rng = Rng::new(5);
        let q = rng.normal_vec(d);
        let (res, lat) = b.search(&idx, &q, 10).unwrap();
        assert_eq!(res.topk.len(), 10);
        assert!(lat.total() > 0.0);
        assert!(lat.network_s > 0.0);
    }

    #[test]
    fn search_many_matches_sequential() {
        let (mut b, idx, d) = toy_backend(BackendKind::FpgaGpu);
        let mut rng = Rng::new(9);
        let queries: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d)).collect();
        let want: Vec<Vec<(f32, u64)>> = queries
            .iter()
            .map(|q| b.search(&idx, q, 10).unwrap().0.topk)
            .collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let (got, modeled) = b.search_many(&idx, &refs).unwrap();
        assert!(modeled > 0.0);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.topk, w);
        }
    }

    #[test]
    fn cpu_backend_has_no_network() {
        let (b, _, _) = toy_backend(BackendKind::Cpu);
        assert_eq!(b.latency_model(1000).network_s, 0.0);
    }

    #[test]
    fn batching_amortizes_on_fpga_more_than_cpu() {
        let scanned = 1_000_000;
        let (f, _, _) = toy_backend(BackendKind::FpgaGpu);
        let (c, _, _) = toy_backend(BackendKind::Cpu);
        let f_gain = f.batch_latency_model(16, scanned)
            / (16.0 * f.latency_model(scanned).total());
        let c_gain = c.batch_latency_model(16, scanned)
            / (16.0 * c.latency_model(scanned).total());
        assert!(f_gain < c_gain, "{f_gain} vs {c_gain}");
    }
}
