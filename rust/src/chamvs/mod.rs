//! ChamVS: the distributed, accelerated vector search engine
//! (paper Sec 3-4).
//!
//! * [`node`] — a disaggregated memory node: a vector-sharded slice of the
//!   database plus a near-memory scan engine (native rust ADC or the
//!   AOT-compiled Pallas pipeline via PJRT).
//! * [`dispatcher`] — query broadcast + per-node top-K aggregation
//!   (the coordinator-side half of the workflow, steps 4-8 of Sec 3).
//! * [`backend`] — the [`ScanBackend`] dispatch-target trait (in-process
//!   node or remote connection) plus the four system configurations of
//!   Fig 9 (CPU, CPU-GPU, FPGA-CPU, FPGA-GPU) with composed latency
//!   models.

pub mod backend;
pub mod dispatcher;
pub mod node;

pub use backend::{BackendKind, ScanBackend, ScanJob, SearchBackend};
pub use dispatcher::{BatchQuery, Dispatcher, SearchResult, Ticket};
pub use node::{MemoryNode, NodeResult, ScanEngine};
