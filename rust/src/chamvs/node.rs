//! A ChamVS.mem disaggregated memory node (paper Sec 3, Fig 4): one shard
//! of PQ codes + vector ids, a near-memory scan engine, and the FPGA cycle
//! model that prices each scan.

use std::time::Instant;

use anyhow::Result;

use super::backend::{ScanBackend, ScanJob};
use crate::hwmodel::fpga::FpgaModel;
use crate::ivf::shard::Shard;
use crate::kselect::{ApproxHierarchicalQueue, HierarchicalConfig};
use crate::pq::scan::adc_scan_into;
use crate::runtime::{Executor, HostTensor, Runtime};

// The dispatcher fans nodes out across scoped worker threads, so every
// engine variant must stay `Send` (the vendored PJRT substrate's handles
// are plain host-side data). This fails the build — rather than silently
// serializing dispatch — if a future engine breaks that.
const _: fn() = || {
    fn assert_send<T: Send>() {}
    assert_send::<MemoryNode>();
};

/// How a node evaluates distances.
pub enum ScanEngine {
    /// Native rust ADC scan + hierarchical queue simulator — the software
    /// model of the FPGA pipeline (bit-exact distances, same K-selection
    /// semantics).
    Native,
    /// The AOT-compiled Pallas pipeline (LUT -> one-hot ADC -> approximate
    /// hierarchical top-K) executed through PJRT — the accelerator
    /// numerics path. Holds one executor per node.
    Pjrt(Box<Executor>),
}

/// Result of one scan request on one node.
#[derive(Clone, Debug)]
pub struct NodeResult {
    /// (distance, global vector id), ascending, length <= k.
    pub topk: Vec<(f32, u64)>,
    /// Wall-clock seconds actually spent (host execution).
    pub measured_s: f64,
    /// Modeled near-memory accelerator latency (FPGA cycle model).
    pub modeled_s: f64,
    /// PQ codes scanned (drives distributions + energy).
    pub n_scanned: usize,
}

/// One disaggregated memory node.
pub struct MemoryNode {
    pub shard: Shard,
    pub engine: ScanEngine,
    pub fpga: FpgaModel,
    pub k: usize,
    pub kcfg: HierarchicalConfig,
    /// Scratch distance buffer (hot path: no per-query allocation).
    scratch: Vec<f32>,
}

impl MemoryNode {
    pub fn new(shard: Shard, engine: ScanEngine, k: usize) -> MemoryNode {
        let fpga = FpgaModel::default();
        let lanes = 2 * fpga.n_decoding_units(shard.m);
        MemoryNode {
            shard,
            engine,
            fpga,
            k,
            kcfg: HierarchicalConfig::approximate(k, lanes, 0.99),
            scratch: Vec::new(),
        }
    }

    /// Build a node whose engine is the AOT Pallas pipeline.
    pub fn with_pjrt(shard: Shard, runtime: &Runtime, k: usize, seed: u64) -> Result<MemoryNode> {
        let artifact = format!("chamvs_scan_m{}", shard.m);
        let exe = runtime.executor(&artifact, seed)?;
        Ok(MemoryNode::new(shard, ScanEngine::Pjrt(Box::new(exe)), k))
    }

    /// Serve one scan request: probe `lists`, return the node-local top-K.
    ///
    /// `lut` is the (m, 256) distance table already built for this query
    /// (native path), `query_sub`/`codebook` feed the PJRT path which
    /// builds its own LUT on-accelerator.
    pub fn scan(
        &mut self,
        lut: &[f32],
        query_sub: &[f32],
        codebook: &[f32],
        lists: &[u32],
        nprobe: usize,
    ) -> Result<NodeResult> {
        let t0 = Instant::now();
        let (codes, ids) = self.shard.gather(lists);
        let n = ids.len();
        let m = self.shard.m;
        let topk = match &mut self.engine {
            ScanEngine::Native => {
                self.scratch.resize(n, 0.0);
                adc_scan_into(&codes, n, m, lut, &mut self.scratch);
                let mut q = ApproxHierarchicalQueue::new(self.kcfg);
                for (i, &d) in self.scratch[..n].iter().enumerate() {
                    q.push(d, i as u64);
                }
                q.finalize()
                    .into_iter()
                    .map(|(d, local)| (d, ids[local as usize]))
                    .collect()
            }
            ScanEngine::Pjrt(exe) => {
                let spec = &exe.spec;
                let n_codes = spec.static_usize("n_codes").unwrap();
                let dsub = spec.static_usize("dsub").unwrap();
                anyhow::ensure!(
                    n <= n_codes,
                    "shard scan of {n} codes exceeds artifact tile {n_codes}"
                );
                // Pad codes up to the artifact's fixed shape.
                let mut padded = vec![0i32; n_codes * m];
                for (i, &c) in codes.iter().enumerate() {
                    padded[i] = c as i32;
                }
                let args = [
                    HostTensor::f32(&[m, dsub], query_sub.to_vec()),
                    HostTensor::f32(&[m, 256, dsub], codebook.to_vec()),
                    HostTensor::i32(&[n_codes, m], padded),
                    HostTensor::i32(&[1], vec![n as i32]),
                ];
                let outs = exe.call(&args)?;
                let dists = outs[0].as_f32()?;
                let idxs = outs[1].as_i32()?;
                // The artifact returns its static k; keep this node's k
                // (padding sentinels are filtered by the n_valid mask).
                dists
                    .iter()
                    .zip(idxs)
                    .filter(|&(_, &i)| (i as usize) < n)
                    .take(self.k)
                    .map(|(&d, &i)| (d, ids[i as usize]))
                    .collect()
            }
        };
        let measured_s = t0.elapsed().as_secs_f64();
        let modeled_s = self
            .fpga
            .query_latency(n, m, nprobe, self.k)
            .total();
        Ok(NodeResult { topk, measured_s, modeled_s, n_scanned: n })
    }
}

impl ScanBackend for MemoryNode {
    fn m(&self) -> usize {
        self.shard.m
    }

    fn fpga(&self) -> &FpgaModel {
        &self.fpga
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>> {
        jobs.iter()
            .map(|j| self.scan(&j.lut, j.query, codebook, j.lists, j.nprobe))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivf::index::IvfPqIndex;
    use crate::pq::scan::build_lut;
    use crate::util::rng::Rng;

    fn setup() -> (IvfPqIndex, Vec<f32>, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (3000, 32, 8, 32);
        let data = rng.normal_vec(n * d);
        (IvfPqIndex::build(&data, n, d, m, nlist, 3), data, d)
    }

    #[test]
    fn native_node_matches_monolithic_search() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let lut = build_lut(&idx.pq, &q);

        // Single node over the whole index == monolithic search.
        let shard = Shard::carve(&idx, 0, 1);
        let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
        // Exact queues for a strict comparison.
        node.kcfg = HierarchicalConfig::exact(10, node.kcfg.num_lanes);
        let r = node.scan(&lut, &q, &idx.pq.centroids, &lists, 8).unwrap();
        let (ids, dists) = {
            let lut2 = build_lut(&idx.pq, &q);
            let mut best: Vec<(f32, u64)> = Vec::new();
            for &l in &lists {
                let codes = &idx.list_codes[l as usize];
                let lids = &idx.list_ids[l as usize];
                let ds = crate::pq::scan::adc_scan(codes, lids.len(), idx.m, &lut2);
                for (i, &dd) in ds.iter().enumerate() {
                    best.push((dd, lids[i]));
                }
            }
            best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            best.truncate(10);
            (
                best.iter().map(|&(_, i)| i).collect::<Vec<_>>(),
                best.iter().map(|&(dd, _)| dd).collect::<Vec<_>>(),
            )
        };
        assert_eq!(r.topk.len(), 10);
        for (i, &(dd, _id)) in r.topk.iter().enumerate() {
            assert!((dd - dists[i]).abs() < 1e-5, "rank {i}");
        }
        let got_ids: Vec<u64> = r.topk.iter().map(|&(_, i)| i).collect();
        assert_eq!(got_ids, ids);
    }

    #[test]
    fn node_reports_latencies() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let lut = build_lut(&idx.pq, &q);
        let shard = Shard::carve(&idx, 0, 1);
        let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
        let r = node.scan(&lut, &q, &idx.pq.centroids, &lists, 4).unwrap();
        assert!(r.measured_s > 0.0);
        assert!(r.modeled_s > 0.0);
        assert_eq!(r.n_scanned, idx.scan_count(&lists));
    }

    #[test]
    fn sharded_nodes_cover_all_results() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let lut = build_lut(&idx.pq, &q);
        let mut all: Vec<(f32, u64)> = Vec::new();
        for node_id in 0..3 {
            let shard = Shard::carve(&idx, node_id, 3);
            let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
            node.kcfg = HierarchicalConfig::exact(10, node.kcfg.num_lanes);
            let r = node.scan(&lut, &q, &idx.pq.centroids, &lists, 8).unwrap();
            all.extend(r.topk);
        }
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        all.truncate(10);
        // Merged node results == monolithic top-10 distances.
        let (_, exact) = idx.search(&q, 8, 10);
        for (got, want) in all.iter().zip(&exact) {
            assert!((got.0 - want).abs() < 1e-5);
        }
    }
}
