//! A ChamVS.mem disaggregated memory node (paper Sec 3, Fig 4): one shard
//! of PQ codes + vector ids, a near-memory scan engine, and the FPGA cycle
//! model that prices each scan.
//!
//! The native scan is the zero-copy fused pipeline (EXPERIMENTS.md §Perf):
//! every probed list is scanned *in place* from the shard's flat storage
//! (no gather copy), distances stream straight into the K-selector (no
//! materialized distance buffer), and a batched round is *list-major* —
//! each probed list's code block is streamed once and scored against all
//! B ADC tables of the round, so the round's code traffic is O(codes)
//! instead of O(B · codes). Scratch, selector pool and round maps are
//! owned by the node and reused: steady-state rounds allocate nothing
//! beyond their result vectors.
//!
//! K-selection is switchable per node ([`SelectMode`]): the fused exact
//! selector is the serving default; the cycle-accurate (approximate)
//! hierarchical queue stays available as the hardware-fidelity path and
//! keeps the single-query gather-order push schedule the FPGA model
//! defines.

use std::time::Instant;

use anyhow::Result;

use super::backend::{ScanBackend, ScanJob};
use crate::hwmodel::fpga::FpgaModel;
use crate::ivf::shard::Shard;
use crate::kselect::{
    ApproxHierarchicalQueue, FusedSelector, HierarchicalConfig, SelectMode,
};
use crate::pq::codebook::KSUB;
use crate::pq::scan::scan_list_into_sink;
use crate::runtime::{Executor, HostTensor, Runtime};

// The dispatcher fans nodes out across scoped worker threads, so every
// engine variant must stay `Send` (the vendored PJRT substrate's handles
// are plain host-side data). This fails the build — rather than silently
// serializing dispatch — if a future engine breaks that.
const _: fn() = || {
    fn assert_send<T: Send>() {}
    assert_send::<MemoryNode>();
};

/// How a node evaluates distances.
pub enum ScanEngine {
    /// Native rust fused ADC scan+select over the flat shard — the
    /// software model of the FPGA pipeline.
    Native,
    /// The AOT-compiled Pallas pipeline (LUT -> one-hot ADC -> approximate
    /// hierarchical top-K) executed through PJRT — the accelerator
    /// numerics path. Holds one executor per node.
    Pjrt(Box<Executor>),
}

/// Result of one scan request on one node.
#[derive(Clone, Debug)]
pub struct NodeResult {
    /// (distance, global vector id), ascending, length <= k.
    pub topk: Vec<(f32, u64)>,
    /// Wall-clock seconds actually spent (host execution). In a list-major
    /// batched round the round's wall is attributed to its jobs
    /// proportionally to their scanned-code counts, so per-job values sum
    /// to the node's true round wall.
    pub measured_s: f64,
    /// Modeled near-memory accelerator latency (FPGA cycle model).
    pub modeled_s: f64,
    /// PQ codes scanned (drives distributions + energy).
    pub n_scanned: usize,
    /// Node-side ADC lookup-table build seconds attributed to this job.
    /// 0.0 when the caller supplied prebuilt tables (the in-process
    /// dispatcher's arena path) or when a remote peer omits the optional
    /// timing tail; remote nodes report their own build share here.
    pub lut_s: f64,
}

/// One disaggregated memory node.
pub struct MemoryNode {
    pub shard: Shard,
    pub engine: ScanEngine,
    pub fpga: FpgaModel,
    pub k: usize,
    /// Sizing of the hierarchical queue (used when `select` is
    /// [`SelectMode::Hierarchical`]; also feeds the FPGA resource model).
    pub kcfg: HierarchicalConfig,
    /// K-selection mode: fused exact (default) or hardware-fidelity
    /// hierarchical.
    pub select: SelectMode,
    /// Reusable distance tile for the fused scan (hot path: no per-query
    /// allocation).
    scratch: Vec<f32>,
    /// Per-job selector pool for list-major rounds (reused; grown once).
    selectors: Vec<FusedSelector>,
    /// Round map: list id -> (job index, job's gather-order base) for
    /// every job probing that list. Cleared via `touched` after each
    /// round, so steady state allocates nothing.
    list_jobs: Vec<Vec<(u32, u64)>>,
    /// Lists touched by the current round (the dirty set of `list_jobs`).
    touched: Vec<u32>,
    /// Per-job scanned-code counts of the current round.
    job_scanned: Vec<usize>,
    /// Reusable PJRT staging tile (recovered from the call arguments
    /// after each execution, so steady-state rounds don't reallocate it).
    pjrt_padded: Vec<i32>,
}

impl MemoryNode {
    pub fn new(shard: Shard, engine: ScanEngine, k: usize) -> MemoryNode {
        let fpga = FpgaModel::default();
        let lanes = 2 * fpga.n_decoding_units(shard.m);
        MemoryNode {
            shard,
            engine,
            fpga,
            k,
            kcfg: HierarchicalConfig::approximate(k, lanes, 0.99),
            select: SelectMode::default(),
            scratch: Vec::new(),
            selectors: Vec::new(),
            list_jobs: Vec::new(),
            touched: Vec::new(),
            job_scanned: Vec::new(),
            pjrt_padded: Vec::new(),
        }
    }

    /// Build a node whose engine is the AOT Pallas pipeline.
    pub fn with_pjrt(shard: Shard, runtime: &Runtime, k: usize, seed: u64) -> Result<MemoryNode> {
        let artifact = format!("chamvs_scan_m{}", shard.m);
        let exe = runtime.executor(&artifact, seed)?;
        Ok(MemoryNode::new(shard, ScanEngine::Pjrt(Box::new(exe)), k))
    }

    /// Serve one scan request: probe `lists`, return the node-local top-K.
    ///
    /// `lut` is the (m, 256) distance table already built for this query
    /// (native path), `query_sub`/`codebook` feed the PJRT path which
    /// builds its own LUT on-accelerator.
    pub fn scan(
        &mut self,
        lut: &[f32],
        query_sub: &[f32],
        codebook: &[f32],
        lists: &[u32],
        nprobe: usize,
    ) -> Result<NodeResult> {
        let jobs = [ScanJob { query: query_sub, lists, lut, nprobe }];
        let mut out = self.scan_jobs(&jobs, codebook)?;
        Ok(out.pop().expect("one result per job"))
    }

    /// List-major fused round (native engine, [`SelectMode::Exact`]):
    /// stream each probed list's code block once and score it against
    /// every job of the round that probes it. Selection keys on
    /// `(distance, gather order)`, so results are bit-identical to a
    /// query-major scan — and to the flat-scan reference.
    fn round_fused(&mut self, jobs: &[ScanJob<'_>]) -> Result<Vec<NodeResult>> {
        let m = self.shard.m;
        for job in jobs {
            anyhow::ensure!(
                job.lut.len() == m * KSUB,
                "scan job is missing its (m, 256) ADC table"
            );
        }
        let t0 = Instant::now();
        let nlist = self.shard.n_lists();
        if self.selectors.len() < jobs.len() {
            self.selectors.resize_with(jobs.len(), || FusedSelector::new(1));
        }
        for sel in &mut self.selectors[..jobs.len()] {
            sel.reset(self.k);
        }
        if self.list_jobs.len() < nlist {
            self.list_jobs.resize_with(nlist, Vec::new);
        }
        self.job_scanned.clear();
        self.job_scanned.resize(jobs.len(), 0);

        // Build the round's list -> jobs map (empty lists contribute
        // nothing, matching the gather semantics; list ids were validated
        // in `scan_jobs`).
        for (j, job) in jobs.iter().enumerate() {
            let mut base = 0u64;
            for &l in job.lists {
                let l = l as usize;
                let len = self.shard.list_len(l);
                if len == 0 {
                    continue;
                }
                if self.list_jobs[l].is_empty() {
                    self.touched.push(l as u32);
                }
                self.list_jobs[l].push((j as u32, base));
                base += len as u64;
            }
            self.job_scanned[j] = base as usize;
        }

        // Scan phase: one pass over each touched list's code block, inner
        // loop over the jobs probing it (the block stays cache-resident
        // across the round's B ADC tables).
        {
            let shard = &self.shard;
            let scratch = &mut self.scratch;
            let selectors = &mut self.selectors;
            let list_jobs = &self.list_jobs;
            for &l in &self.touched {
                let l = l as usize;
                let codes = shard.list_codes(l);
                let ids = shard.list_ids(l);
                for &(j, base) in &list_jobs[l] {
                    scan_list_into_sink(
                        codes,
                        m,
                        jobs[j as usize].lut,
                        ids,
                        base,
                        scratch,
                        &mut selectors[j as usize],
                    );
                }
            }
        }
        for &l in &self.touched {
            self.list_jobs[l as usize].clear();
        }
        self.touched.clear();

        let mut topks: Vec<Vec<(f32, u64)>> = Vec::with_capacity(jobs.len());
        for sel in &mut self.selectors[..jobs.len()] {
            let mut topk = Vec::with_capacity(self.k);
            sel.emit_into(&mut topk);
            topks.push(topk);
        }
        let wall = t0.elapsed().as_secs_f64();
        let total: usize = self.job_scanned.iter().sum();
        Ok(topks
            .into_iter()
            .enumerate()
            .map(|(j, topk)| {
                let n = self.job_scanned[j];
                let share = if total > 0 {
                    wall * n as f64 / total as f64
                } else {
                    wall / jobs.len() as f64
                };
                NodeResult {
                    topk,
                    measured_s: share,
                    modeled_s: self.fpga.query_latency(n, m, jobs[j].nprobe, self.k).total(),
                    n_scanned: n,
                    lut_s: 0.0,
                }
            })
            .collect())
    }

    /// Hardware-fidelity round ([`SelectMode::Hierarchical`]): per job, in
    /// the job's own probe order, stream each list in place into the
    /// cycle-accurate hierarchical queue (gather-order lane round-robin —
    /// exactly the FPGA push schedule, still without the gather copy).
    fn round_hierarchical(&mut self, jobs: &[ScanJob<'_>]) -> Result<Vec<NodeResult>> {
        let m = self.shard.m;
        let mut results = Vec::with_capacity(jobs.len());
        for job in jobs {
            anyhow::ensure!(
                job.lut.len() == m * KSUB,
                "scan job is missing its (m, 256) ADC table"
            );
            let t0 = Instant::now();
            let mut q = ApproxHierarchicalQueue::new(self.kcfg);
            let mut scanned = 0usize;
            {
                let shard = &self.shard;
                let scratch = &mut self.scratch;
                for &l in job.lists {
                    let l = l as usize;
                    let ids = shard.list_ids(l);
                    if ids.is_empty() {
                        continue;
                    }
                    scan_list_into_sink(
                        shard.list_codes(l),
                        m,
                        job.lut,
                        ids,
                        scanned as u64,
                        scratch,
                        &mut q,
                    );
                    scanned += ids.len();
                }
            }
            let topk = q.finalize();
            results.push(NodeResult {
                topk,
                measured_s: t0.elapsed().as_secs_f64(),
                modeled_s: self.fpga.query_latency(scanned, m, job.nprobe, self.k).total(),
                n_scanned: scanned,
                lut_s: 0.0,
            });
        }
        Ok(results)
    }

    /// PJRT round: one artifact call per job, staging the padded code
    /// tile straight from the shard's flat storage (no intermediate
    /// gather vectors; result rows map back through the per-list bases).
    fn round_pjrt(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>> {
        let mut results = Vec::with_capacity(jobs.len());
        for job in jobs {
            results.push(self.scan_pjrt_one(job, codebook)?);
        }
        Ok(results)
    }

    fn scan_pjrt_one(&mut self, job: &ScanJob<'_>, codebook: &[f32]) -> Result<NodeResult> {
        let t0 = Instant::now();
        let m = self.shard.m;
        let n = self.shard.scan_count(job.lists);
        let exe = match &mut self.engine {
            ScanEngine::Pjrt(exe) => exe,
            ScanEngine::Native => unreachable!("native jobs never reach the PJRT round"),
        };
        let spec = &exe.spec;
        let n_codes = spec.static_usize("n_codes").unwrap();
        let dsub = spec.static_usize("dsub").unwrap();
        anyhow::ensure!(
            n <= n_codes,
            "shard scan of {n} codes exceeds artifact tile {n_codes}"
        );
        // Stage codes up to the artifact's fixed shape, straight from the
        // flat shard buffer into the reusable tile (re-zeroed in place;
        // no per-job allocation); remember each list's row base for the
        // result-index mapping.
        let mut padded = std::mem::take(&mut self.pjrt_padded);
        padded.clear();
        padded.resize(n_codes * m, 0);
        let mut bases: Vec<(usize, u32)> = Vec::with_capacity(job.lists.len());
        let mut row = 0usize;
        for &l in job.lists {
            let codes = self.shard.list_codes(l as usize);
            for (i, &c) in codes.iter().enumerate() {
                padded[row * m + i] = c as i32;
            }
            bases.push((row, l));
            row += codes.len() / m;
        }
        let mut args = [
            HostTensor::f32(&[m, dsub], job.query.to_vec()),
            HostTensor::f32(&[m, 256, dsub], codebook.to_vec()),
            HostTensor::i32(&[n_codes, m], padded),
            HostTensor::i32(&[1], vec![n as i32]),
        ];
        let outs = exe.call(&args)?;
        // Recover the staging tile for the next job (the error path above
        // just drops it — it regrows on the next call).
        if let HostTensor::I32 { data, .. } =
            std::mem::replace(&mut args[2], HostTensor::i32(&[0], Vec::new()))
        {
            self.pjrt_padded = data;
        }
        let dists = outs[0].as_f32()?;
        let idxs = outs[1].as_i32()?;
        // The artifact returns its static k; keep this node's k (padding
        // sentinels are filtered by the n_valid mask). A result row maps
        // to (list, offset) via the last base at or below it.
        let topk = dists
            .iter()
            .zip(idxs)
            .filter(|&(_, &i)| (i as usize) < n)
            .take(self.k)
            .map(|(&d, &i)| {
                let i = i as usize;
                let p = bases.partition_point(|&(b, _)| b <= i) - 1;
                let (b, l) = bases[p];
                (d, self.shard.list_ids(l as usize)[i - b])
            })
            .collect();
        let measured_s = t0.elapsed().as_secs_f64();
        let modeled_s = self.fpga.query_latency(n, m, job.nprobe, self.k).total();
        Ok(NodeResult { topk, measured_s, modeled_s, n_scanned: n, lut_s: 0.0 })
    }
}

impl ScanBackend for MemoryNode {
    fn m(&self) -> usize {
        self.shard.m
    }

    fn fpga(&self) -> &FpgaModel {
        &self.fpga
    }

    fn scan_jobs(&mut self, jobs: &[ScanJob<'_>], codebook: &[f32]) -> Result<Vec<NodeResult>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        // A probed list outside this shard is a coordinator bug: fail the
        // round loudly (and identically on every engine) instead of
        // silently scanning a subset or panicking. The networked server
        // filters ids before they get here.
        let nlist = self.shard.n_lists();
        for job in jobs {
            anyhow::ensure!(
                job.lists.iter().all(|&l| (l as usize) < nlist),
                "scan job probes a list outside this shard (nlist={nlist})"
            );
        }
        if matches!(self.engine, ScanEngine::Pjrt(_)) {
            return self.round_pjrt(jobs, codebook);
        }
        match self.select {
            SelectMode::Exact => self.round_fused(jobs),
            SelectMode::Hierarchical => self.round_hierarchical(jobs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivf::index::IvfPqIndex;
    use crate::pq::scan::build_lut;
    use crate::util::rng::Rng;

    fn setup() -> (IvfPqIndex, Vec<f32>, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (3000, 32, 8, 32);
        let data = rng.normal_vec(n * d);
        (IvfPqIndex::build(&data, n, d, m, nlist, 3), data, d)
    }

    fn flat_reference(idx: &IvfPqIndex, q: &[f32], lists: &[u32], k: usize) -> Vec<(f32, u64)> {
        let lut = build_lut(&idx.pq, q);
        let mut best: Vec<(f32, u64)> = Vec::new();
        for &l in lists {
            let codes = &idx.list_codes[l as usize];
            let lids = &idx.list_ids[l as usize];
            let ds = crate::pq::scan::adc_scan(codes, lids.len(), idx.m, &lut);
            for (i, &dd) in ds.iter().enumerate() {
                best.push((dd, lids[i]));
            }
        }
        best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        best.truncate(k);
        best
    }

    #[test]
    fn native_node_matches_monolithic_search() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let lut = build_lut(&idx.pq, &q);
        let want = flat_reference(&idx, &q, &lists, 10);

        // Single node over the whole index == monolithic search, in both
        // selection modes (exact queues for the hierarchical comparison).
        for select in [SelectMode::Exact, SelectMode::Hierarchical] {
            let shard = Shard::carve(&idx, 0, 1);
            let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
            node.select = select;
            node.kcfg = HierarchicalConfig::exact(10, node.kcfg.num_lanes);
            let r = node.scan(&lut, &q, &idx.pq.centroids, &lists, 8).unwrap();
            assert_eq!(r.topk.len(), 10, "{select:?}");
            for (i, (got, wanted)) in r.topk.iter().zip(&want).enumerate() {
                assert_eq!(
                    got.0.to_bits(),
                    wanted.0.to_bits(),
                    "{select:?} rank {i}"
                );
            }
            if select == SelectMode::Exact {
                // The fused selector's (dist, order) key pins ids too.
                let got_ids: Vec<u64> = r.topk.iter().map(|&(_, i)| i).collect();
                let want_ids: Vec<u64> = want.iter().map(|&(_, i)| i).collect();
                assert_eq!(got_ids, want_ids);
            }
        }
    }

    #[test]
    fn list_major_batch_matches_per_job_scans() {
        // One batched scan_jobs round must be bit-identical to scanning
        // its jobs one at a time, in both selection modes.
        let (idx, _, d) = setup();
        let mut rng = Rng::new(7);
        let queries: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(d)).collect();
        let lists: Vec<Vec<u32>> = queries.iter().map(|q| idx.probe(q, 6)).collect();
        let luts: Vec<Vec<f32>> =
            queries.iter().map(|q| build_lut(&idx.pq, q)).collect();
        for select in [SelectMode::Exact, SelectMode::Hierarchical] {
            let shard = Shard::carve(&idx, 0, 2);
            let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
            node.select = select;
            let jobs: Vec<ScanJob> = queries
                .iter()
                .zip(&lists)
                .zip(&luts)
                .map(|((q, l), lut)| ScanJob { query: q, lists: l, lut, nprobe: 6 })
                .collect();
            let batched = node.scan_jobs(&jobs, &idx.pq.centroids).unwrap();
            assert_eq!(batched.len(), jobs.len());
            for (job, batch_r) in jobs.iter().zip(&batched) {
                let single = node
                    .scan(job.lut, job.query, &idx.pq.centroids, job.lists, 6)
                    .unwrap();
                assert_eq!(batch_r.topk, single.topk, "{select:?}");
                assert_eq!(batch_r.n_scanned, single.n_scanned);
            }
        }
    }

    #[test]
    fn node_reports_latencies() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let lut = build_lut(&idx.pq, &q);
        let shard = Shard::carve(&idx, 0, 1);
        let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
        let r = node.scan(&lut, &q, &idx.pq.centroids, &lists, 4).unwrap();
        assert!(r.measured_s > 0.0);
        assert!(r.modeled_s > 0.0);
        assert_eq!(r.n_scanned, idx.scan_count(&lists));
    }

    #[test]
    fn batched_round_wall_attribution_sums_to_round() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(5);
        let queries: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(d)).collect();
        let lists: Vec<Vec<u32>> = queries.iter().map(|q| idx.probe(q, 5)).collect();
        let luts: Vec<Vec<f32>> =
            queries.iter().map(|q| build_lut(&idx.pq, q)).collect();
        let jobs: Vec<ScanJob> = queries
            .iter()
            .zip(&lists)
            .zip(&luts)
            .map(|((q, l), lut)| ScanJob { query: q, lists: l, lut, nprobe: 5 })
            .collect();
        let mut node = MemoryNode::new(Shard::carve(&idx, 0, 1), ScanEngine::Native, 10);
        let rs = node.scan_jobs(&jobs, &idx.pq.centroids).unwrap();
        assert!(rs.iter().all(|r| r.measured_s > 0.0));
        // Proportional attribution: bigger scans get bigger shares.
        for w in rs.windows(2) {
            if w[0].n_scanned > w[1].n_scanned {
                assert!(w[0].measured_s >= w[1].measured_s);
            }
        }
    }

    #[test]
    fn sharded_nodes_cover_all_results() {
        let (idx, _, d) = setup();
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let lut = build_lut(&idx.pq, &q);
        let mut all: Vec<(f32, u64)> = Vec::new();
        for node_id in 0..3 {
            let shard = Shard::carve(&idx, node_id, 3);
            let mut node = MemoryNode::new(shard, ScanEngine::Native, 10);
            let r = node.scan(&lut, &q, &idx.pq.centroids, &lists, 8).unwrap();
            all.extend(r.topk);
        }
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        all.truncate(10);
        // Merged node results == monolithic top-10 distances.
        let (_, exact) = idx.search(&q, 8, 10);
        for (got, want) in all.iter().zip(&exact) {
            assert!((got.0 - want).abs() < 1e-5);
        }
    }
}
