//! Query broadcast + result aggregation across memory nodes — the FPGA
//! coordination process of the paper's workflow (Sec 3 steps 4-8): the
//! coordinator broadcasts (query, list IDs) to every node, each node
//! returns its local top-K, and a k-way merge produces the global top-K.

use anyhow::Result;

use super::node::{MemoryNode, NodeResult};
use crate::hwmodel::loggp::LogGp;
use crate::pq::scan::build_lut;

/// Aggregated search result for one query.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// (distance, global id) ascending, length <= k.
    pub topk: Vec<(f32, u64)>,
    /// Max modeled accelerator latency across nodes (they run in
    /// parallel; the slowest node gates the response).
    pub accel_s: f64,
    /// Modeled network round trip (LogGP broadcast + reduce).
    pub network_s: f64,
    /// Sum of host wall-clock across nodes (sequential in-process here).
    pub measured_s: f64,
    /// Total codes scanned across nodes.
    pub n_scanned: usize,
}

impl SearchResult {
    /// Modeled end-to-end retrieval latency (paper's FPGA-side total).
    pub fn modeled_total(&self) -> f64 {
        self.accel_s + self.network_s
    }
}

/// Handle for an in-flight speculative query (see [`Dispatcher::submit`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket(pub u64);

/// A submitted-but-not-yet-collected scan request.
struct PendingScan {
    id: u64,
    query: Vec<f32>,
    lists: Vec<u32>,
    nprobe: usize,
}

/// In-process dispatcher over a set of memory nodes.
pub struct Dispatcher {
    pub nodes: Vec<MemoryNode>,
    pub net: LogGp,
    pub k: usize,
    next_ticket: u64,
    pending: Vec<PendingScan>,
}

impl Dispatcher {
    pub fn new(nodes: Vec<MemoryNode>, k: usize) -> Dispatcher {
        Dispatcher {
            nodes,
            net: LogGp::default(),
            k,
            next_ticket: 0,
            pending: Vec::new(),
        }
    }

    /// Broadcast one query's scan request to all nodes and merge results.
    ///
    /// `query` is the full D-dim query; each node re-derives sub-vectors
    /// for its PQ width. `lists` are the probed IVF list ids (from
    /// ChamVS.idx). `codebook` is the shared PQ centroid tensor.
    pub fn search(
        &mut self,
        query: &[f32],
        codebook: &[f32],
        lists: &[u32],
        nprobe: usize,
    ) -> Result<SearchResult> {
        anyhow::ensure!(!self.nodes.is_empty(), "no memory nodes");
        let m = self.nodes[0].shard.m;
        let d = query.len();
        let dsub = d / m;
        // LUT once per query (the paper builds it on-node; cost identical,
        // the native engine shares it across nodes for efficiency).
        let lut = {
            // Native path needs the trained PQ codebook in PqCodebook form;
            // nodes hold raw centroid tensors, so build via the free fn.
            build_lut_from_raw(codebook, query, m, dsub)
        };
        let results: Vec<NodeResult> = self
            .nodes
            .iter_mut()
            .map(|n| n.scan(&lut, query, codebook, lists, nprobe))
            .collect::<Result<Vec<_>>>()?;

        let topk = merge_topk(&results, self.k);
        let accel_s = results.iter().map(|r| r.modeled_s).fold(0.0, f64::max);
        let query_bytes = 4 * d + 4 * lists.len();
        let result_bytes = 12 * self.k; // f32 dist + u64 id
        let network_s =
            self.net.query_roundtrip(self.nodes.len(), query_bytes, result_bytes);
        Ok(SearchResult {
            topk,
            accel_s,
            network_s,
            measured_s: results.iter().map(|r| r.measured_s).sum(),
            n_scanned: results.iter().map(|r| r.n_scanned).sum(),
        })
    }

    /// Enqueue a scan request without blocking on its result — the
    /// coordinator-side half of speculative retrieval: the query is
    /// considered "in flight on the memory nodes" while the GPU keeps
    /// decoding, and is collected later with [`poll`](Self::poll).
    ///
    /// The in-process dispatcher has no background threads (PJRT node
    /// engines are not `Send`), so the scan itself executes lazily at poll
    /// time; the *modeled* latencies in the returned [`SearchResult`] are
    /// identical either way, and the overlap accounting happens in the
    /// serving layer (`retcache`), which charges only the residual of the
    /// retrieval latency not hidden behind decode steps.
    pub fn submit(&mut self, query: &[f32], lists: &[u32], nprobe: usize) -> Ticket {
        let id = self.next_ticket;
        self.next_ticket += 1;
        self.pending.push(PendingScan {
            id,
            query: query.to_vec(),
            lists: lists.to_vec(),
            nprobe,
        });
        Ticket(id)
    }

    /// Collect the result of a submitted query. Returns `None` for an
    /// unknown (or already collected / cancelled) ticket. `codebook` is the
    /// same raw PQ centroid tensor [`search`](Self::search) takes.
    pub fn poll(&mut self, ticket: Ticket, codebook: &[f32]) -> Option<Result<SearchResult>> {
        let i = self.pending.iter().position(|p| p.id == ticket.0)?;
        let p = self.pending.swap_remove(i);
        Some(self.search(&p.query, codebook, &p.lists, p.nprobe))
    }

    /// Drop an in-flight query without collecting it (mis-speculation).
    /// Returns whether the ticket was actually pending.
    pub fn cancel(&mut self, ticket: Ticket) -> bool {
        let i = self.pending.iter().position(|p| p.id == ticket.0);
        match i {
            Some(i) => {
                self.pending.swap_remove(i);
                true
            }
            None => false,
        }
    }

    /// Number of submitted-but-uncollected queries.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }
}

/// K-way merge of per-node ascending top-K lists (paper step 8).
pub fn merge_topk(results: &[NodeResult], k: usize) -> Vec<(f32, u64)> {
    // Nodes return <= k each; a linear merge with a cursor per node is
    // O(k * nodes) and allocation-light.
    let mut cursors = vec![0usize; results.len()];
    let mut out = Vec::with_capacity(k);
    while out.len() < k {
        let mut best: Option<(usize, f32)> = None;
        for (n, r) in results.iter().enumerate() {
            if let Some(&(d, _)) = r.topk.get(cursors[n]) {
                if best.map(|(_, bd)| d < bd).unwrap_or(true) {
                    best = Some((n, d));
                }
            }
        }
        match best {
            Some((n, _)) => {
                out.push(results[n].topk[cursors[n]]);
                cursors[n] += 1;
            }
            None => break, // all exhausted
        }
    }
    out
}

/// Build an (m, 256) LUT from a raw (m, 256, dsub) centroid tensor.
pub fn build_lut_from_raw(centroids: &[f32], query: &[f32], m: usize, dsub: usize) -> Vec<f32> {
    use crate::pq::codebook::PqCodebook;
    let cb = PqCodebook { d: m * dsub, m, centroids: centroids.to_vec() };
    build_lut(&cb, query)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::node::ScanEngine;
    use crate::ivf::index::IvfPqIndex;
    use crate::ivf::shard::Shard;
    use crate::kselect::HierarchicalConfig;
    use crate::util::rng::Rng;

    fn build_dispatcher(n_nodes: usize, exact: bool) -> (Dispatcher, IvfPqIndex, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (3000, 32, 8, 32);
        let data = rng.normal_vec(n * d);
        let idx = IvfPqIndex::build(&data, n, d, m, nlist, 3);
        let nodes = (0..n_nodes)
            .map(|i| {
                let mut node = MemoryNode::new(
                    Shard::carve(&idx, i, n_nodes),
                    ScanEngine::Native,
                    10,
                );
                if exact {
                    node.kcfg = HierarchicalConfig::exact(10, node.kcfg.num_lanes);
                }
                node
            })
            .collect();
        (Dispatcher::new(nodes, 10), idx, d)
    }

    #[test]
    fn distributed_equals_monolithic() {
        let (mut disp, idx, d) = build_dispatcher(4, true);
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let q = rng.normal_vec(d);
            let lists = idx.probe(&q, 8);
            let r = disp
                .search(&q, &idx.pq.centroids, &lists, 8)
                .unwrap();
            let (_, exact_d) = idx.search(&q, 8, 10);
            assert_eq!(r.topk.len(), 10);
            for (got, want) in r.topk.iter().zip(&exact_d) {
                assert!((got.0 - want).abs() < 1e-5, "{} vs {}", got.0, want);
            }
        }
    }

    #[test]
    fn merge_topk_interleaves() {
        let mk = |v: Vec<(f32, u64)>| NodeResult {
            topk: v,
            measured_s: 0.0,
            modeled_s: 0.0,
            n_scanned: 0,
        };
        let a = mk(vec![(1.0, 10), (4.0, 11)]);
        let b = mk(vec![(2.0, 20), (3.0, 21)]);
        let merged = merge_topk(&[a, b], 3);
        assert_eq!(merged, vec![(1.0, 10), (2.0, 20), (3.0, 21)]);
    }

    #[test]
    fn merge_handles_short_lists() {
        let mk = |v: Vec<(f32, u64)>| NodeResult {
            topk: v,
            measured_s: 0.0,
            modeled_s: 0.0,
            n_scanned: 0,
        };
        let merged = merge_topk(&[mk(vec![(1.0, 1)]), mk(vec![])], 5);
        assert_eq!(merged.len(), 1);
    }

    #[test]
    fn prop_merge_equals_global_sort() {
        use crate::util::prop;
        prop::check(
            "merge-equals-sort",
            |rng| {
                let n_nodes = 1 + rng.below(6);
                let k = 1 + rng.below(20);
                let nodes: Vec<NodeResult> = (0..n_nodes)
                    .map(|nid| {
                        let mut v: Vec<(f32, u64)> = (0..rng.below(2 * k + 1))
                            .map(|j| (rng.f32(), (nid * 1000 + j) as u64))
                            .collect();
                        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                        NodeResult {
                            topk: v,
                            measured_s: 0.0,
                            modeled_s: 0.0,
                            n_scanned: 0,
                        }
                    })
                    .collect();
                (k, nodes)
            },
            |(k, nodes)| {
                let merged = merge_topk(nodes, *k);
                let mut all: Vec<(f32, u64)> =
                    nodes.iter().flat_map(|n| n.topk.iter().cloned()).collect();
                all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                all.truncate(*k);
                assert_eq!(merged.len(), all.len());
                for (m, a) in merged.iter().zip(&all) {
                    assert_eq!(m.0, a.0);
                }
            },
        );
    }

    #[test]
    fn submit_poll_matches_blocking_search() {
        let (mut disp, idx, d) = build_dispatcher(2, true);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let want = disp.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
        let t = disp.submit(&q, &lists, 8);
        assert_eq!(disp.in_flight(), 1);
        let got = disp.poll(t, &idx.pq.centroids).unwrap().unwrap();
        assert_eq!(disp.in_flight(), 0);
        assert_eq!(got.topk, want.topk);
        // Collected tickets are gone.
        assert!(disp.poll(t, &idx.pq.centroids).is_none());
    }

    #[test]
    fn cancel_drops_pending_query() {
        let (mut disp, idx, d) = build_dispatcher(1, false);
        let mut rng = Rng::new(12);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let a = disp.submit(&q, &lists, 4);
        let b = disp.submit(&q, &lists, 4);
        assert_ne!(a, b);
        assert_eq!(disp.in_flight(), 2);
        assert!(disp.cancel(a));
        assert!(!disp.cancel(a), "double cancel");
        assert_eq!(disp.in_flight(), 1);
        assert!(disp.poll(a, &idx.pq.centroids).is_none());
        assert!(disp.poll(b, &idx.pq.centroids).unwrap().is_ok());
    }

    #[test]
    fn latency_fields_populated() {
        let (mut disp, idx, d) = build_dispatcher(2, false);
        let mut rng = Rng::new(8);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let r = disp.search(&q, &idx.pq.centroids, &lists, 4).unwrap();
        assert!(r.accel_s > 0.0);
        assert!(r.network_s > 0.0);
        assert!(r.modeled_total() > r.accel_s);
        assert_eq!(r.n_scanned, idx.scan_count(&lists));
    }
}
