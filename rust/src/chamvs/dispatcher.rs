//! Query broadcast + result aggregation across memory nodes — the FPGA
//! coordination process of the paper's workflow (Sec 3 steps 4-8): the
//! coordinator broadcasts (query, list IDs) to every node, each node
//! returns its local top-K, and a k-way merge produces the global top-K.
//!
//! Dispatch is truly concurrent: every round fans its scan jobs out over
//! the memory nodes on a scoped thread pool (`n_threads` workers, each
//! owning a balanced contiguous chunk of nodes), so host wall-clock
//! behaves like the paper's disaggregated system — the slowest worker
//! gates the response. [`SearchResult`] therefore reports both
//! `measured_wall_s` (max across workers of their nodes' scan-time sums —
//! the honest parallel number at the configured width, reducing to the
//! slowest node at full fan-out) and `measured_cpu_s` (sum across nodes,
//! the total host work).
//!
//! Two request shapes share the pool:
//! * [`Dispatcher::search`] — one query, broadcast to all nodes.
//! * [`Dispatcher::search_batch`] — B queries per round with per-node
//!   work queues: each worker thread runs *all* queries of the round
//!   against its nodes (node-major), and results are k-way merged per
//!   query as they land.
//!
//! The node set is a vector of [`ScanBackend`] trait objects, so the same
//! dispatcher (and the same merge) drives in-process `MemoryNode` slices
//! and remote `chamvs-node` connections — a batched round over remote
//! backends ships each node its whole job queue in one network round trip.
//!
//! A dispatcher may instead run over a replicated
//! [`ClusterEngine`](crate::cluster::engine::ClusterEngine)
//! ([`Dispatcher::clustered`]): rounds then fan out per *shard* with
//! replica selection, retry-on-replica failover and optional hedging, and
//! the per-shard winners feed the same k-way merge — results stay
//! bit-identical to the flat node set as long as one replica per shard
//! survives. Everything above this layer (speculation tickets, batched
//! rounds, the retriever, the coordinator server) is oblivious to which
//! engine runs the round.
//!
//! Speculative traffic ([`Dispatcher::submit`]) rides the same pool:
//! queued tickets execute alongside the next batched round (or fan out in
//! parallel on demand at [`Dispatcher::poll`]) and their results are
//! parked until collected; single-query `search` leaves them queued so a
//! blocking retrieval's measured wall-clock never absorbs another
//! stream's speculative work. Tickets are tagged with a *slot* (one lane per GPU source;
//! see `coordinator::server`), so submit/poll/cancel on one slot never
//! disturbs another's in-flight work.

use anyhow::Result;

use super::backend::{ScanBackend, ScanJob};
use super::node::{MemoryNode, NodeResult};
use crate::cluster::engine::{ClusterEngine, RoundOptions};
use crate::hwmodel::fpga::FpgaModel;
use crate::hwmodel::loggp::LogGp;
use crate::pq::codebook::KSUB;
use crate::pq::scan::build_lut_raw_into;
use crate::trace::{SpanKind, Tracer};

/// Aggregated search result for one query.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// (distance, global id) ascending, length <= k.
    pub topk: Vec<(f32, u64)>,
    /// Max modeled accelerator latency across nodes (they run in
    /// parallel; the slowest node gates the response).
    pub accel_s: f64,
    /// Modeled network round trip (LogGP broadcast + reduce).
    pub network_s: f64,
    /// Honest parallel-dispatch wall-clock at the configured fan-out:
    /// max across pool workers of the sum of their nodes' scan times.
    /// With one worker per node this is the slowest node (the paper's
    /// disaggregated bound); with one thread it equals `measured_cpu_s`
    /// (a sequential scan is reported as sequential, never as parallel).
    pub measured_wall_s: f64,
    /// Sum of host wall-clock across nodes: total CPU work of the scan.
    pub measured_cpu_s: f64,
    /// Total codes scanned across nodes.
    pub n_scanned: usize,
    /// Shards that contributed to this result (cluster mode under a
    /// [`DegradedPolicy::ServePartial`](crate::cluster::engine::DegradedPolicy)
    /// round). `0/0` means flat dispatch — by construction complete.
    pub shards_answered: u32,
    /// Total shards the round fanned out to (`0` = flat dispatch).
    pub n_shards: u32,
}

impl SearchResult {
    /// Modeled end-to-end retrieval latency (paper's FPGA-side total).
    pub fn modeled_total(&self) -> f64 {
        self.accel_s + self.network_s
    }

    /// Fraction of shards that contributed (`1.0` = complete; flat
    /// dispatch is always complete).
    pub fn coverage(&self) -> f64 {
        if self.n_shards == 0 {
            1.0
        } else {
            self.shards_answered as f64 / self.n_shards as f64
        }
    }

    /// Whether some shard's results are missing from the merged top-k.
    pub fn is_partial(&self) -> bool {
        self.n_shards != 0 && self.shards_answered < self.n_shards
    }
}

/// Handle for an in-flight speculative query (see [`Dispatcher::submit`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket(pub u64);

/// One query of a batched dispatch round (borrowed request payload).
#[derive(Clone, Copy, Debug)]
pub struct BatchQuery<'a> {
    /// Full D-dim query vector.
    pub query: &'a [f32],
    /// Probed IVF list ids (from ChamVS.idx).
    pub lists: &'a [u32],
    /// End-to-end trace id allocated by the coordinator (0 = untraced;
    /// per-stage spans are recorded under this id when the dispatcher's
    /// tracer is enabled).
    pub trace_id: u64,
}

/// A submitted-but-not-yet-collected scan request.
struct PendingScan {
    id: u64,
    /// Ticket lane (one per GPU source); isolation boundary for
    /// `cancel_slot` and the per-slot in-flight accounting.
    slot: usize,
    state: PendingState,
}

enum PendingState {
    /// Not yet executed: will run with the next dispatch round (or at
    /// poll time, whichever comes first).
    Queued { query: Vec<f32>, lists: Vec<u32>, nprobe: usize },
    /// Executed alongside an earlier round; parked until polled.
    Done(SearchResult),
}

/// Dispatcher over a set of scan backends — in-process memory nodes,
/// remote `chamvs-node` connections, or a mix (see
/// [`ScanBackend`](super::backend::ScanBackend)).
pub struct Dispatcher {
    pub nodes: Vec<Box<dyn ScanBackend>>,
    /// Replicated-tier engine; when set, rounds run through it instead of
    /// `nodes` (which stays empty) — see [`Dispatcher::clustered`].
    cluster: Option<ClusterEngine>,
    pub net: LogGp,
    pub k: usize,
    /// Worker threads for node fan-out. 0 (the default) means one worker
    /// per node; values are clamped to the node count. 1 runs inline on
    /// the calling thread (the sequential baseline, no spawn overhead).
    /// Ignored in cluster mode (the engine owns one worker per member).
    pub n_threads: usize,
    /// Pin each pool worker of a fan-out round to a planned CPU
    /// (`util::affinity::worker_cpus`: round-robin across NUMA nodes) so
    /// memory-bound scans stay near their shard's arena. Defaults to the
    /// `CHAM_PIN` env knob (the CLI's `--pin-workers` sets it); no-op
    /// where affinity is unsupported, and never applied to the inline
    /// single-chunk path (pinning the caller would leak past the round).
    /// Cluster mode pins via
    /// [`crate::cluster::engine::ClusterConfig::pin_workers`] instead.
    pub pin_workers: bool,
    next_ticket: u64,
    pending: Vec<PendingScan>,
    /// Reusable per-round LUT arena: one (m, 256) table per job, built in
    /// place each round (steady state allocates nothing).
    lut_arena: Vec<f32>,
    /// Latency-model fallback when no backend is reachable directly
    /// (cluster mode owns its backends inside worker threads).
    fallback_fpga: FpgaModel,
    /// Span sink for per-query stage attribution (off by default: every
    /// record call is a single branch). See [`crate::trace`].
    pub tracer: Tracer,
}

impl Dispatcher {
    /// Dispatcher over in-process memory nodes (the common construction).
    pub fn new(nodes: Vec<MemoryNode>, k: usize) -> Dispatcher {
        Dispatcher::over(
            nodes
                .into_iter()
                .map(|n| Box::new(n) as Box<dyn ScanBackend>)
                .collect(),
            k,
        )
    }

    /// Dispatcher over arbitrary scan backends (e.g. remote nodes — the
    /// networked twin is the same dispatcher, not a parallel code path).
    pub fn over(nodes: Vec<Box<dyn ScanBackend>>, k: usize) -> Dispatcher {
        Dispatcher {
            nodes,
            cluster: None,
            net: LogGp::default(),
            k,
            n_threads: 0,
            pin_workers: crate::util::affinity::env_pin_requested(),
            next_ticket: 0,
            pending: Vec::new(),
            lut_arena: Vec::new(),
            fallback_fpga: FpgaModel::default(),
            tracer: Tracer::off(),
        }
    }

    /// Dispatcher over a replicated cluster engine: rounds fan out per
    /// shard with replica failover and optional hedging (see
    /// [`crate::cluster`]). Results are bit-identical to a flat
    /// [`Dispatcher::new`] over one node per shard while at least one
    /// replica per shard survives.
    pub fn clustered(engine: ClusterEngine, k: usize) -> Dispatcher {
        let mut d = Dispatcher::over(Vec::new(), k);
        d.cluster = Some(engine);
        d
    }

    /// Builder: enable/disable NUMA pinning of pool workers (see
    /// [`Dispatcher::pin_workers`]) without going through `CHAM_PIN`.
    pub fn with_pinning(mut self, pin: bool) -> Dispatcher {
        self.pin_workers = pin;
        self
    }

    /// The cluster engine, if this dispatcher runs the replicated tier.
    pub fn cluster(&self) -> Option<&ClusterEngine> {
        self.cluster.as_ref()
    }

    /// Mutable cluster engine (membership transitions between rounds).
    pub fn cluster_mut(&mut self) -> Option<&mut ClusterEngine> {
        self.cluster.as_mut()
    }

    pub fn is_clustered(&self) -> bool {
        self.cluster.is_some()
    }

    /// How many scan targets one round fans out to: shards in cluster
    /// mode, nodes otherwise.
    pub fn fan_out(&self) -> usize {
        match &self.cluster {
            Some(c) => c.n_shards(),
            None => self.nodes.len(),
        }
    }

    /// The FPGA cycle model pricing scans on this tier (first node's
    /// model in flat mode; the shared default in cluster mode, matching
    /// what remote nodes carry).
    pub fn fpga(&self) -> &FpgaModel {
        if let Some(c) = &self.cluster {
            return c.fpga();
        }
        match self.nodes.first() {
            Some(n) => n.fpga(),
            None => &self.fallback_fpga,
        }
    }

    /// Builder-style worker-thread override (`0` = one per node).
    pub fn with_threads(mut self, n_threads: usize) -> Dispatcher {
        self.n_threads = n_threads;
        self
    }

    /// Effective fan-out width for the current node set.
    pub fn effective_threads(&self) -> usize {
        let n = self.nodes.len().max(1);
        if self.n_threads == 0 {
            n
        } else {
            self.n_threads.min(n)
        }
    }

    /// Broadcast one query's scan request to all nodes (in parallel on the
    /// thread pool) and merge results.
    ///
    /// Queued speculative tickets are deliberately NOT drained here: their
    /// scans would be charged to this query's host wall-clock (the serving
    /// layer times `retrieve` end-to-end). They execute in parallel at
    /// [`poll`](Self::poll) time, or ride along with the next
    /// [`search_batch`](Self::search_batch) round, whose per-query
    /// measured fields are per-job and immune to that distortion.
    ///
    /// `query` is the full D-dim query; each node re-derives sub-vectors
    /// for its PQ width. `lists` are the probed IVF list ids (from
    /// ChamVS.idx). `codebook` is the shared PQ centroid tensor.
    pub fn search(
        &mut self,
        query: &[f32],
        codebook: &[f32],
        lists: &[u32],
        nprobe: usize,
    ) -> Result<SearchResult> {
        self.search_traced(query, codebook, lists, nprobe, 0)
    }

    /// [`search`](Self::search) carrying an end-to-end trace id: the
    /// round's `lut_build`/`node_scan`/`merge` spans are recorded under
    /// `trace_id` when the tracer is enabled (`0` = untraced).
    pub fn search_traced(
        &mut self,
        query: &[f32],
        codebook: &[f32],
        lists: &[u32],
        nprobe: usize,
        trace_id: u64,
    ) -> Result<SearchResult> {
        self.search_opts(query, codebook, lists, nprobe, trace_id, &RoundOptions::default())
    }

    /// [`search_traced`](Self::search_traced) with per-round options: an
    /// end-to-end deadline and a degraded-mode policy, honored by the
    /// cluster engine (flat dispatch has no replicas to degrade over and
    /// runs the round as usual; budget enforcement for the flat path
    /// happens at admission).
    pub fn search_opts(
        &mut self,
        query: &[f32],
        codebook: &[f32],
        lists: &[u32],
        nprobe: usize,
        trace_id: u64,
        opts: &RoundOptions,
    ) -> Result<SearchResult> {
        let mut out = self.dispatch_round(
            &[BatchQuery { query, lists, trace_id }],
            codebook,
            nprobe,
            false,
            opts,
        )?;
        Ok(out.pop().expect("one result per query"))
    }

    /// Dispatch B queries in one round with per-node work queues: each
    /// pool worker runs every query of the round against its chunk of
    /// nodes (node-major), then each query's per-node top-K lists are
    /// k-way merged. Queued speculative tickets execute in the same round.
    ///
    /// Results are bit-identical to B sequential [`search`](Self::search)
    /// calls; only the measured wall-clock differs (queries share the
    /// fan-out round instead of paying it B times).
    pub fn search_batch(
        &mut self,
        batch: &[BatchQuery],
        codebook: &[f32],
        nprobe: usize,
    ) -> Result<Vec<SearchResult>> {
        self.dispatch_round(batch, codebook, nprobe, true, &RoundOptions::default())
    }

    /// [`search_batch`](Self::search_batch) with per-round options (see
    /// [`search_opts`](Self::search_opts)); the round's single deadline
    /// should be the tightest of its queries' budgets.
    pub fn search_batch_opts(
        &mut self,
        batch: &[BatchQuery],
        codebook: &[f32],
        nprobe: usize,
        opts: &RoundOptions,
    ) -> Result<Vec<SearchResult>> {
        self.dispatch_round(batch, codebook, nprobe, true, opts)
    }

    /// Run one parallel round over `batch` (+ optionally the queued
    /// speculative scans), returning the batch's results in order and
    /// parking speculative results in their pending entries.
    fn dispatch_round(
        &mut self,
        batch: &[BatchQuery],
        codebook: &[f32],
        nprobe: usize,
        drain_speculative: bool,
        opts: &RoundOptions,
    ) -> Result<Vec<SearchResult>> {
        let tracing = self.tracer.enabled();
        // Hedge activity is engine-global, not per-query: diff the
        // cluster's counters around the round and log the deltas as
        // trace-id-0 events (tag = count).
        let pre_hedge = if tracing {
            self.cluster.as_ref().map(|c| c.stats())
        } else {
            None
        };
        let (m, need_lut) = match &self.cluster {
            Some(c) => (c.m(), c.wants_lut()),
            None => {
                anyhow::ensure!(!self.nodes.is_empty(), "no memory nodes");
                let m = self.nodes[0].m();
                anyhow::ensure!(
                    self.nodes.iter().all(|n| n.m() == m),
                    "memory nodes disagree on PQ width m"
                );
                (m, self.nodes.iter().any(|n| n.wants_lut()))
            }
        };

        // The query geometry a LUT-building round accepts: when this
        // round builds ADC tables, the query must match the codebook's
        // (m, dsub) exactly — checked here as an error, never as a panic
        // inside the LUT kernel (mirrors net::server::scan_round). Rounds
        // without local LUTs (remote-only) defer to the node server's own
        // geometry check.
        let lut_len = m * KSUB;
        let dim_ok = |len: usize| {
            if need_lut {
                len == codebook.len() / lut_len * m && codebook.len() % lut_len == 0
            } else {
                len % m == 0
            }
        };

        // Snapshot queued speculative requests (owned copies) so the round
        // can run against `&mut self.nodes` and park results afterwards.
        // A malformed ticket (query dim mismatching the geometry) is left
        // Queued rather than failing this round: the error then surfaces
        // at the owner's `poll` — which runs the ticket as a batch job
        // and hits the dim check below — not in innocent callers' rounds.
        let spec: Vec<(u64, Vec<f32>, Vec<u32>, usize)> = if drain_speculative {
            self.pending
                .iter()
                .filter_map(|p| match &p.state {
                    PendingState::Queued { query, lists, nprobe }
                        if dim_ok(query.len()) =>
                    {
                        Some((p.id, query.clone(), lists.clone(), *nprobe))
                    }
                    _ => None,
                })
                .collect()
        } else {
            Vec::new()
        };

        // Validate blocking queries up front (a malformed query fails the
        // round before any arena work).
        for q in batch {
            anyhow::ensure!(
                dim_ok(q.query.len()),
                "query dim {} does not match the index geometry (m={m})",
                q.query.len()
            );
        }

        // Fill the reusable LUT arena: one (m, 256) table per job, built
        // in place straight from the raw centroid tensor — no per-job
        // allocation and no codebook copy.
        let mut arena = std::mem::take(&mut self.lut_arena);
        arena.clear();
        let t_arena = std::time::Instant::now();
        if need_lut {
            let queries = batch
                .iter()
                .map(|q| q.query)
                .chain(spec.iter().map(|(_, q, ..)| q.as_slice()));
            for query in queries {
                let start = arena.len();
                arena.resize(start + lut_len, 0.0);
                build_lut_raw_into(codebook, query, m, query.len() / m, &mut arena[start..]);
            }
        }
        // Per-job share of the coordinator-side table-build wall; remote
        // rounds add the node-side share carried in the response tail.
        let arena_share_s = if tracing && need_lut {
            t_arena.elapsed().as_secs_f64() / (batch.len() + spec.len()).max(1) as f64
        } else {
            0.0
        };

        // Assemble the round's job list: the blocking batch first, then
        // the queued speculative tickets, each borrowing its arena slice.
        let luts: Vec<&[f32]> = if need_lut {
            arena.chunks_exact(lut_len).collect()
        } else {
            vec![&[] as &[f32]; batch.len() + spec.len()]
        };
        let mut jobs: Vec<ScanJob> = Vec::with_capacity(batch.len() + spec.len());
        for (q, lut) in batch.iter().zip(luts.iter().copied()) {
            jobs.push(ScanJob { query: q.query, lists: q.lists, lut, nprobe });
        }
        let spec_luts = luts[batch.len()..].iter().copied();
        for ((_, query, lists, sp_nprobe), lut) in spec.iter().zip(spec_luts) {
            jobs.push(ScanJob { query, lists, lut, nprobe: *sp_nprobe });
        }

        // Cluster coverage of this round: (answered, total) shards; None
        // for flat dispatch (by construction complete).
        let mut round_coverage: Option<(u32, u32)> = None;
        let (chunks, round) = match self.cluster.as_mut() {
            Some(engine) => {
                // Cluster mode: one replica answers per shard, each on
                // its own worker — the wall partition is one chunk per
                // *answered* shard (a degraded round contributes fewer
                // rows per job).
                match engine.run_round_opts(&jobs, codebook, opts) {
                    Ok(out) => {
                        round_coverage = Some((out.shards_answered, out.n_shards));
                        (vec![1usize; out.shards_answered as usize], Ok(out.per_job))
                    }
                    Err(e) => (Vec::new(), Err(e)),
                }
            }
            None => {
                let threads = self.effective_threads();
                let chunks = chunk_sizes(self.nodes.len(), threads);
                let round = run_jobs(
                    &mut self.nodes,
                    &chunks,
                    &jobs,
                    codebook,
                    self.pin_workers,
                );
                (chunks, round)
            }
        };
        // Network pricing fans out to every shard the round *broadcast*
        // to, answered or not.
        let fan_out: usize = match round_coverage {
            Some((_, total)) => total as usize,
            None => chunks.iter().sum(),
        };
        let per_job = match round {
            Ok(r) => r,
            Err(e) => {
                drop(jobs);
                self.lut_arena = arena;
                return Err(e);
            }
        };
        let mut results: Vec<SearchResult> = Vec::with_capacity(per_job.len());
        for (i, (node_results, job)) in per_job.iter().zip(&jobs).enumerate() {
            let trace_id = if i < batch.len() { batch[i].trace_id } else { 0 };
            if tracing && trace_id != 0 {
                let lut_s = arena_share_s
                    + node_results.iter().map(|r| r.lut_s).sum::<f64>();
                self.tracer.record(trace_id, SpanKind::LutBuild, 0, lut_s);
                for (n, r) in node_results.iter().enumerate() {
                    self.tracer.record(
                        trace_id,
                        SpanKind::NodeScan,
                        n as u32,
                        r.measured_s,
                    );
                }
                let t_merge = std::time::Instant::now();
                let merged = self.aggregate(node_results, job, &chunks, fan_out);
                self.tracer.record(
                    trace_id,
                    SpanKind::Merge,
                    0,
                    t_merge.elapsed().as_secs_f64(),
                );
                results.push(merged);
            } else {
                results.push(self.aggregate(node_results, job, &chunks, fan_out));
            }
        }
        // Stamp the round's coverage onto every result (blocking and
        // speculative alike — a ticket collected later still reports how
        // much of the cluster its round saw).
        if let Some((answered, total)) = round_coverage {
            for r in results.iter_mut() {
                r.shards_answered = answered;
                r.n_shards = total;
            }
        }
        drop(jobs);
        self.lut_arena = arena;
        if let Some(pre) = pre_hedge {
            if let Some(c) = self.cluster.as_ref() {
                let post = c.stats();
                let fired = post.hedges.saturating_sub(pre.hedges);
                let won = post.hedge_wins.saturating_sub(pre.hedge_wins);
                if fired > 0 {
                    self.tracer.record(0, SpanKind::HedgeFired, fired as u32, 0.0);
                }
                if won > 0 {
                    self.tracer.record(0, SpanKind::HedgeWon, won as u32, 0.0);
                }
            }
        }

        // Park speculative results on their pending entries (the tail of
        // `results` matches `spec` in order).
        for ((id, ..), result) in spec.iter().zip(results.drain(batch.len()..)) {
            if let Some(p) = self.pending.iter_mut().find(|p| p.id == *id) {
                p.state = PendingState::Done(result);
            }
        }
        Ok(results)
    }

    /// Merge one job's per-node results into a [`SearchResult`].
    /// `chunks` is the pool's node partition: the honest wall is the max
    /// across workers of the sum of their nodes' scan times (nodes within
    /// one chunk run serially on that worker). `fan_out` is the number of
    /// scan targets the round broadcast to (nodes, or shards in cluster
    /// mode), which prices the modeled network round trip.
    fn aggregate(
        &self,
        results: &[NodeResult],
        job: &ScanJob,
        chunks: &[usize],
        fan_out: usize,
    ) -> SearchResult {
        let topk = merge_topk(results, self.k);
        let accel_s = results.iter().map(|r| r.modeled_s).fold(0.0, f64::max);
        let query_bytes = 4 * job.query.len() + 4 * job.lists.len();
        let result_bytes = 12 * self.k; // f32 dist + u64 id
        let network_s =
            self.net.query_roundtrip(fan_out, query_bytes, result_bytes);
        let mut wall = 0.0f64;
        let mut start = 0usize;
        for &c in chunks {
            let worker: f64 =
                results[start..start + c].iter().map(|r| r.measured_s).sum();
            wall = wall.max(worker);
            start += c;
        }
        SearchResult {
            topk,
            accel_s,
            network_s,
            measured_wall_s: wall,
            measured_cpu_s: results.iter().map(|r| r.measured_s).sum(),
            n_scanned: results.iter().map(|r| r.n_scanned).sum(),
            shards_answered: 0,
            n_shards: 0,
        }
    }

    /// Enqueue a scan request without blocking on its result — the
    /// coordinator-side half of speculative retrieval: the query is
    /// considered "in flight on the memory nodes" while the GPU keeps
    /// decoding, and is collected later with [`poll`](Self::poll).
    ///
    /// Queued tickets execute on the thread pool alongside the next
    /// [`search_batch`](Self::search_batch) round, or in parallel at poll
    /// time if no batched round ran first; either way the result is
    /// identical to a blocking `search` of the same request, and the
    /// overlap accounting happens in the serving layer (`retcache`),
    /// which charges only the residual of the retrieval latency not
    /// hidden behind decode steps.
    pub fn submit(&mut self, query: &[f32], lists: &[u32], nprobe: usize) -> Ticket {
        self.submit_for(0, query, lists, nprobe)
    }

    /// [`submit`](Self::submit) on an explicit ticket lane. Each GPU
    /// source owns one slot; cancellation and in-flight accounting never
    /// cross slots.
    pub fn submit_for(
        &mut self,
        slot: usize,
        query: &[f32],
        lists: &[u32],
        nprobe: usize,
    ) -> Ticket {
        let id = self.next_ticket;
        self.next_ticket += 1;
        self.pending.push(PendingScan {
            id,
            slot,
            state: PendingState::Queued {
                query: query.to_vec(),
                lists: lists.to_vec(),
                nprobe,
            },
        });
        Ticket(id)
    }

    /// Collect the result of a submitted query. Returns `None` for an
    /// unknown (or already collected / cancelled) ticket. `codebook` is the
    /// same raw PQ centroid tensor [`search`](Self::search) takes.
    pub fn poll(&mut self, ticket: Ticket, codebook: &[f32]) -> Option<Result<SearchResult>> {
        let i = self.pending.iter().position(|p| p.id == ticket.0)?;
        let p = self.pending.swap_remove(i);
        match p.state {
            PendingState::Done(result) => Some(Ok(result)),
            PendingState::Queued { query, lists, nprobe } => {
                // Not yet piggybacked on a round: run it now (parallel),
                // without draining other slots' queued tickets.
                Some(
                    self.dispatch_round(
                        &[BatchQuery { query: &query, lists: &lists, trace_id: 0 }],
                        codebook,
                        nprobe,
                        false,
                        &RoundOptions::default(),
                    )
                    .map(|mut v| v.pop().expect("one result per query")),
                )
            }
        }
    }

    /// Drop an in-flight query without collecting it (mis-speculation).
    /// Returns whether the ticket was actually pending; cancelling an
    /// already-collected or already-cancelled ticket is a clean no-op.
    pub fn cancel(&mut self, ticket: Ticket) -> bool {
        let i = self.pending.iter().position(|p| p.id == ticket.0);
        match i {
            Some(i) => {
                self.pending.swap_remove(i);
                true
            }
            None => false,
        }
    }

    /// Drop every in-flight query on one slot (GPU teardown / sequence
    /// boundary). Returns how many tickets were cancelled.
    pub fn cancel_slot(&mut self, slot: usize) -> usize {
        let before = self.pending.len();
        self.pending.retain(|p| p.slot != slot);
        before - self.pending.len()
    }

    /// Number of submitted-but-uncollected queries (all slots).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Number of submitted-but-uncollected queries on one slot.
    pub fn in_flight_for(&self, slot: usize) -> usize {
        self.pending.iter().filter(|p| p.slot == slot).count()
    }

    /// The slot a pending ticket belongs to (`None` once collected or
    /// cancelled).
    pub fn ticket_slot(&self, ticket: Ticket) -> Option<usize> {
        self.pending.iter().find(|p| p.id == ticket.0).map(|p| p.slot)
    }
}

/// Balanced node partition for `threads` pool workers: one chunk per
/// worker, sizes differing by at most one (the first `n % t` workers take
/// the extra node), covering all nodes in order. The chunk count always
/// equals `min(threads, n_nodes)`, so the fan-out width a caller
/// configures is the width that actually runs.
fn chunk_sizes(n_nodes: usize, threads: usize) -> Vec<usize> {
    let t = threads.clamp(1, n_nodes.max(1));
    let base = n_nodes / t;
    let rem = n_nodes % t;
    (0..t).map(|i| base + usize::from(i < rem)).collect()
}

/// Execute every job against every node, fanning nodes out over one
/// scoped worker per entry of `chunks` (each worker owns a contiguous
/// node chunk and processes the full job queue node-major). Returns
/// results indexed `[job][node]` with node order preserved, so merges are
/// deterministic regardless of thread count.
fn run_jobs(
    nodes: &mut [Box<dyn ScanBackend>],
    chunks: &[usize],
    jobs: &[ScanJob],
    codebook: &[f32],
    pin: bool,
) -> Result<Vec<Vec<NodeResult>>> {
    let n_nodes = nodes.len();
    let per_node: Vec<Vec<NodeResult>> = if chunks.len() <= 1 {
        // Inline on the caller — never pinned (a lingering affinity mask
        // on the dispatcher thread would outlive the round).
        scan_chunk(nodes, jobs, codebook)?
    } else {
        // One planned CPU per pool worker, interleaved across NUMA nodes
        // so co-scheduled chunks spread over sockets.
        let plan = if pin {
            crate::util::affinity::worker_cpus(chunks.len())
        } else {
            Vec::new()
        };
        let joined = std::thread::scope(|s| {
            let mut rest = nodes;
            let mut handles = Vec::with_capacity(chunks.len());
            for (w, &c) in chunks.iter().enumerate() {
                // `take` moves the tail out of `rest` so the split halves
                // keep the full outer lifetime the spawned thread needs.
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(c);
                rest = tail;
                let pin_cpu = plan.get(w).copied();
                handles.push(s.spawn(move || {
                    if let Some(cpu) = pin_cpu {
                        let _ = crate::util::affinity::pin_to_cpu(cpu);
                    }
                    scan_chunk(chunk, jobs, codebook)
                }));
            }
            handles.into_iter().map(|h| h.join()).collect::<Vec<_>>()
        });
        let mut collected: Vec<Vec<NodeResult>> = Vec::with_capacity(n_nodes);
        for r in joined {
            match r {
                Ok(chunk) => collected.extend(chunk?),
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        collected
    };

    // Transpose [node][job] -> [job][node].
    let mut per_job: Vec<Vec<NodeResult>> = (0..jobs.len())
        .map(|_| Vec::with_capacity(n_nodes))
        .collect();
    for node_results in per_node {
        for (job_i, r) in node_results.into_iter().enumerate() {
            per_job[job_i].push(r);
        }
    }
    Ok(per_job)
}

/// Sequential scan of one node chunk over the full job queue (the unit of
/// work one pool thread executes). Returns results `[node-in-chunk][job]`.
/// Each backend runs the whole queue in one [`ScanBackend::scan_jobs`]
/// call — for a remote node that is one network round trip per round.
fn scan_chunk(
    chunk: &mut [Box<dyn ScanBackend>],
    jobs: &[ScanJob],
    codebook: &[f32],
) -> Result<Vec<Vec<NodeResult>>> {
    chunk.iter_mut().map(|node| node.scan_jobs(jobs, codebook)).collect()
}

/// K-way merge of per-node ascending top-K lists (paper step 8).
pub fn merge_topk(results: &[NodeResult], k: usize) -> Vec<(f32, u64)> {
    // Nodes return <= k each; a linear merge with a cursor per node is
    // O(k * nodes) and allocation-light.
    let mut cursors = vec![0usize; results.len()];
    let mut out = Vec::with_capacity(k);
    while out.len() < k {
        let mut best: Option<(usize, f32)> = None;
        for (n, r) in results.iter().enumerate() {
            if let Some(&(d, _)) = r.topk.get(cursors[n]) {
                if best.map(|(_, bd)| d < bd).unwrap_or(true) {
                    best = Some((n, d));
                }
            }
        }
        match best {
            Some((n, _)) => {
                out.push(results[n].topk[cursors[n]]);
                cursors[n] += 1;
            }
            None => break, // all exhausted
        }
    }
    out
}

/// Build an (m, 256) LUT from a raw (m, 256, dsub) centroid tensor
/// (allocating convenience wrapper over
/// [`build_lut_raw_into`](crate::pq::scan::build_lut_raw_into) — no
/// centroid copy).
pub fn build_lut_from_raw(centroids: &[f32], query: &[f32], m: usize, dsub: usize) -> Vec<f32> {
    let mut lut = vec![0.0f32; m * KSUB];
    build_lut_raw_into(centroids, query, m, dsub, &mut lut);
    lut
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::node::ScanEngine;
    use crate::ivf::index::IvfPqIndex;
    use crate::ivf::shard::Shard;
    use crate::kselect::HierarchicalConfig;
    use crate::util::rng::Rng;

    fn build_dispatcher(n_nodes: usize, exact: bool) -> (Dispatcher, IvfPqIndex, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (3000, 32, 8, 32);
        let data = rng.normal_vec(n * d);
        let idx = IvfPqIndex::build(&data, n, d, m, nlist, 3);
        let nodes = (0..n_nodes)
            .map(|i| {
                let mut node = MemoryNode::new(
                    Shard::carve(&idx, i, n_nodes),
                    ScanEngine::Native,
                    10,
                );
                if exact {
                    node.kcfg = HierarchicalConfig::exact(10, node.kcfg.num_lanes);
                }
                node
            })
            .collect();
        (Dispatcher::new(nodes, 10), idx, d)
    }

    #[test]
    fn distributed_equals_monolithic() {
        let (mut disp, idx, d) = build_dispatcher(4, true);
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let q = rng.normal_vec(d);
            let lists = idx.probe(&q, 8);
            let r = disp
                .search(&q, &idx.pq.centroids, &lists, 8)
                .unwrap();
            let (_, exact_d) = idx.search(&q, 8, 10);
            assert_eq!(r.topk.len(), 10);
            for (got, want) in r.topk.iter().zip(&exact_d) {
                assert!((got.0 - want).abs() < 1e-5, "{} vs {}", got.0, want);
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(21);
        let (_, idx, d) = build_dispatcher(1, true);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let mut want: Option<Vec<(f32, u64)>> = None;
        for threads in [1usize, 2, 3, 8] {
            let (mut disp, _, _) = build_dispatcher(4, true);
            disp.n_threads = threads;
            let r = disp.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
            match &want {
                None => want = Some(r.topk.clone()),
                Some(w) => assert_eq!(&r.topk, w, "threads={threads}"),
            }
            assert!(r.measured_wall_s > 0.0);
            assert!(r.measured_cpu_s >= r.measured_wall_s);
        }
    }

    #[test]
    fn search_batch_matches_sequential_searches() {
        let (mut disp, idx, d) = build_dispatcher(3, true);
        let mut rng = Rng::new(15);
        let queries: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(d)).collect();
        let lists: Vec<Vec<u32>> = queries.iter().map(|q| idx.probe(q, 8)).collect();
        let want: Vec<Vec<(f32, u64)>> = queries
            .iter()
            .zip(&lists)
            .map(|(q, l)| disp.search(q, &idx.pq.centroids, l, 8).unwrap().topk)
            .collect();
        let batch: Vec<BatchQuery> = queries
            .iter()
            .zip(&lists)
            .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
            .collect();
        let got = disp.search_batch(&batch, &idx.pq.centroids, 8).unwrap();
        assert_eq!(got.len(), queries.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.topk, w);
        }
    }

    #[test]
    fn merge_topk_interleaves() {
        let mk = |v: Vec<(f32, u64)>| NodeResult {
            topk: v,
            measured_s: 0.0,
            modeled_s: 0.0,
            n_scanned: 0,
            lut_s: 0.0,
        };
        let a = mk(vec![(1.0, 10), (4.0, 11)]);
        let b = mk(vec![(2.0, 20), (3.0, 21)]);
        let merged = merge_topk(&[a, b], 3);
        assert_eq!(merged, vec![(1.0, 10), (2.0, 20), (3.0, 21)]);
    }

    #[test]
    fn merge_handles_short_lists() {
        let mk = |v: Vec<(f32, u64)>| NodeResult {
            topk: v,
            measured_s: 0.0,
            modeled_s: 0.0,
            n_scanned: 0,
            lut_s: 0.0,
        };
        let merged = merge_topk(&[mk(vec![(1.0, 1)]), mk(vec![])], 5);
        assert_eq!(merged.len(), 1);
    }

    #[test]
    fn prop_merge_equals_global_sort() {
        use crate::util::prop;
        prop::check(
            "merge-equals-sort",
            |rng| {
                let n_nodes = 1 + rng.below(6);
                let k = 1 + rng.below(20);
                let nodes: Vec<NodeResult> = (0..n_nodes)
                    .map(|nid| {
                        let mut v: Vec<(f32, u64)> = (0..rng.below(2 * k + 1))
                            .map(|j| (rng.f32(), (nid * 1000 + j) as u64))
                            .collect();
                        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                        NodeResult {
                            topk: v,
                            measured_s: 0.0,
                            modeled_s: 0.0,
                            n_scanned: 0,
                            lut_s: 0.0,
                        }
                    })
                    .collect();
                (k, nodes)
            },
            |(k, nodes)| {
                let merged = merge_topk(nodes, *k);
                let mut all: Vec<(f32, u64)> =
                    nodes.iter().flat_map(|n| n.topk.iter().cloned()).collect();
                all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                all.truncate(*k);
                assert_eq!(merged.len(), all.len());
                for (m, a) in merged.iter().zip(&all) {
                    assert_eq!(m.0, a.0);
                }
            },
        );
    }

    #[test]
    fn submit_poll_matches_blocking_search() {
        let (mut disp, idx, d) = build_dispatcher(2, true);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let want = disp.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
        let t = disp.submit(&q, &lists, 8);
        assert_eq!(disp.in_flight(), 1);
        let got = disp.poll(t, &idx.pq.centroids).unwrap().unwrap();
        assert_eq!(disp.in_flight(), 0);
        assert_eq!(got.topk, want.topk);
        // Collected tickets are gone.
        assert!(disp.poll(t, &idx.pq.centroids).is_none());
    }

    #[test]
    fn queued_ticket_executes_with_next_batched_round() {
        let (mut disp, idx, d) = build_dispatcher(2, true);
        let mut rng = Rng::new(19);
        let spec_q = rng.normal_vec(d);
        let spec_lists = idx.probe(&spec_q, 8);
        let want = disp.search(&spec_q, &idx.pq.centroids, &spec_lists, 8).unwrap();
        let t = disp.submit(&spec_q, &spec_lists, 8);
        // A single-query search leaves the ticket queued (its wall-clock
        // must not absorb speculative work) ...
        let other = rng.normal_vec(d);
        let other_lists = idx.probe(&other, 8);
        disp.search(&other, &idx.pq.centroids, &other_lists, 8).unwrap();
        // ... but a batched round drains it in the same parallel fan-out.
        let batch =
            [BatchQuery { query: &other, lists: &other_lists, trace_id: 0 }];
        disp.search_batch(&batch, &idx.pq.centroids, 8).unwrap();
        assert_eq!(disp.in_flight(), 1, "still pending until polled");
        let got = disp.poll(t, &idx.pq.centroids).unwrap().unwrap();
        assert_eq!(got.topk, want.topk);
    }

    #[test]
    fn wall_time_tracks_fan_out_width() {
        // At 1 thread the honest wall IS the cpu sum; at full fan-out it
        // is the slowest node; in between it is the max worker-chunk sum.
        let (mut disp, idx, d) = build_dispatcher(4, false);
        let mut rng = Rng::new(23);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        disp.n_threads = 1;
        let r = disp.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
        assert!((r.measured_wall_s - r.measured_cpu_s).abs() < 1e-12,
            "sequential dispatch must report sequential wall");
        disp.n_threads = 0; // one worker per node
        let r = disp.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
        assert!(r.measured_wall_s <= r.measured_cpu_s);
    }

    #[test]
    fn malformed_ticket_does_not_poison_rounds() {
        let (mut disp, idx, d) = build_dispatcher(2, false);
        let mut rng = Rng::new(31);
        let bad = rng.normal_vec(d + 1); // dim not divisible by m
        let good = rng.normal_vec(d);
        let lists = idx.probe(&good, 4);
        let t = disp.submit(&bad, &lists, 4);
        // Blocking and batched rounds still succeed: the malformed ticket
        // is left queued instead of failing the shared round.
        assert!(disp.search(&good, &idx.pq.centroids, &lists, 4).is_ok());
        let batch = [BatchQuery { query: &good, lists: &lists, trace_id: 0 }];
        assert!(disp.search_batch(&batch, &idx.pq.centroids, 4).is_ok());
        assert_eq!(disp.in_flight(), 1);
        // The dim error surfaces at the owner's poll, and the ticket is
        // consumed by it.
        assert!(disp.poll(t, &idx.pq.centroids).unwrap().is_err());
        assert_eq!(disp.in_flight(), 0);
    }

    #[test]
    fn cancel_drops_pending_query() {
        let (mut disp, idx, d) = build_dispatcher(1, false);
        let mut rng = Rng::new(12);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let a = disp.submit(&q, &lists, 4);
        let b = disp.submit(&q, &lists, 4);
        assert_ne!(a, b);
        assert_eq!(disp.in_flight(), 2);
        assert!(disp.cancel(a));
        assert!(!disp.cancel(a), "double cancel");
        assert_eq!(disp.in_flight(), 1);
        assert!(disp.poll(a, &idx.pq.centroids).is_none());
        assert!(disp.poll(b, &idx.pq.centroids).unwrap().is_ok());
    }

    #[test]
    fn slots_isolate_submit_poll_cancel() {
        let (mut disp, idx, d) = build_dispatcher(2, false);
        let mut rng = Rng::new(13);
        let q0 = rng.normal_vec(d);
        let q1 = rng.normal_vec(d);
        let l0 = idx.probe(&q0, 4);
        let l1 = idx.probe(&q1, 4);
        let t0 = disp.submit_for(0, &q0, &l0, 4);
        let t1 = disp.submit_for(1, &q1, &l1, 4);
        assert_eq!(disp.in_flight_for(0), 1);
        assert_eq!(disp.in_flight_for(1), 1);
        assert_eq!(disp.ticket_slot(t0), Some(0));
        assert_eq!(disp.ticket_slot(t1), Some(1));
        // Cancelling slot 0 leaves slot 1's ticket untouched.
        assert_eq!(disp.cancel_slot(0), 1);
        assert_eq!(disp.in_flight_for(0), 0);
        assert_eq!(disp.in_flight_for(1), 1);
        assert!(disp.poll(t0, &idx.pq.centroids).is_none());
        assert!(disp.poll(t1, &idx.pq.centroids).unwrap().is_ok());
        assert_eq!(disp.in_flight(), 0);
        // Cancel-after-complete is a clean no-op.
        assert!(!disp.cancel(t1));
        assert_eq!(disp.cancel_slot(1), 0);
    }

    #[test]
    fn cluster_partial_round_reports_coverage() {
        use crate::cluster::engine::{
            ClusterConfig, ClusterNode, DegradedPolicy, SelectPolicy,
        };
        use crate::cluster::fault::FailingBackend;
        let mut rng = Rng::new(41);
        let (n, d, m, nlist) = (2400, 32, 8, 24);
        let data = rng.normal_vec(n * d);
        let idx = IvfPqIndex::build(&data, n, d, m, nlist, 3);
        let n_shards = 2;
        let mk = |shard: usize| {
            Box::new(MemoryNode::new(
                Shard::carve(&idx, shard, n_shards),
                ScanEngine::Native,
                10,
            )) as Box<dyn ScanBackend>
        };
        // Shard 0's only replica is dead; shard 1 is healthy.
        let nodes = vec![
            ClusterNode { id: 0, shard: 0, backend: Box::new(FailingBackend::new(mk(0), 0)) },
            ClusterNode { id: 1, shard: 1, backend: mk(1) },
        ];
        let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
        let engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
        let mut disp = Dispatcher::clustered(engine, 10);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 6);
        // The default (fail-fast) round errors ...
        assert!(disp.search(&q, &idx.pq.centroids, &lists, 6).is_err());
        // ... ServePartial returns the live shard's half, tagged.
        let opts = RoundOptions {
            degraded: DegradedPolicy::ServePartial { min_coverage: 0.0 },
            deadline: None,
        };
        let r = disp.search_opts(&q, &idx.pq.centroids, &lists, 6, 0, &opts).unwrap();
        assert!(r.is_partial());
        assert!((r.coverage() - 0.5).abs() < 1e-9);
        assert!(!r.topk.is_empty(), "the live shard still contributes");
        // Flat dispatch always reports complete coverage.
        let (mut flat, idx2, d2) = build_dispatcher(2, false);
        let q2 = rng.normal_vec(d2);
        let l2 = idx2.probe(&q2, 4);
        let r2 = flat.search(&q2, &idx2.pq.centroids, &l2, 4).unwrap();
        assert!(!r2.is_partial());
        assert!((r2.coverage() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_fields_populated() {
        let (mut disp, idx, d) = build_dispatcher(2, false);
        let mut rng = Rng::new(8);
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 4);
        let r = disp.search(&q, &idx.pq.centroids, &lists, 4).unwrap();
        assert!(r.accel_s > 0.0);
        assert!(r.network_s > 0.0);
        assert!(r.modeled_total() > r.accel_s);
        assert!(r.measured_wall_s > 0.0);
        assert!(r.measured_cpu_s >= r.measured_wall_s);
        assert_eq!(r.n_scanned, idx.scan_count(&lists));
    }
}
