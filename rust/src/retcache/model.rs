//! A worker-free serving model: real retrieval numerics through a
//! [`Retriever`] + modeled GPU decode latencies, with and without the
//! retcache fast paths. This is what the `retrieval_cache` bench, the
//! `report retcache` command and the deterministic end-to-end tests
//! drive — no PJRT artifacts required.
//!
//! Per retrieval interval the modeled cost is
//! `interval * decode + charged_retrieval (+ encode for EncDec)`, where
//! the charged retrieval follows [`super::charged_latency`]: full round
//! trip on a miss (the seed synchronous engine), the lookup constant on a
//! cache hit, and only the non-overlapped residual on a verified
//! speculative prefetch — i.e. the step pays
//! `max(decode_window, retrieval)`-shaped time instead of the sum.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::coordinator::retriever::Retriever;
use crate::hwmodel::gpu::GpuModel;

/// Outcome of one modeled serving run.
#[derive(Clone, Debug)]
pub struct ModeledServe {
    pub tokens: usize,
    pub retrievals: usize,
    /// Cache-aware modeled wall time.
    pub modeled_s: f64,
    /// The same workload on the seed synchronous path (every retrieval
    /// charged in full) — the speedup denominator.
    pub sync_modeled_s: f64,
    pub misses: u64,
    pub cache_hits: u64,
    pub spec_hits: u64,
}

impl ModeledServe {
    pub fn modeled_tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.modeled_s.max(1e-12)
    }

    pub fn sync_tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.sync_modeled_s.max(1e-12)
    }

    /// Modeled throughput gain of the cached path over the seed
    /// synchronous path on this workload.
    pub fn speedup(&self) -> f64 {
        self.sync_modeled_s / self.modeled_s.max(1e-12)
    }

    pub fn hit_rate(&self) -> f64 {
        if self.retrievals == 0 {
            0.0
        } else {
            (self.cache_hits + self.spec_hits) as f64 / self.retrievals as f64
        }
    }
}

/// Serving simulator over a paper-scale model's decode/encode latencies.
pub struct ServeModel {
    pub model: &'static ModelConfig,
    pub gpu: GpuModel,
}

impl ServeModel {
    pub fn new(model: &'static ModelConfig) -> ServeModel {
        ServeModel { model, gpu: GpuModel::default() }
    }

    /// Modeled single-sequence decode step.
    pub fn decode_step_s(&self) -> f64 {
        self.gpu.decode_step_latency(self.model, 1)
    }

    /// Serve a stream of retrieval queries: each entry is the query of one
    /// retrieval interval (`interval` decode steps + one retrieval).
    /// Uses the retriever's cache/speculation when enabled, and always
    /// tracks the synchronous-equivalent cost alongside.
    pub fn run(&self, retriever: &mut Retriever, queries: &[Vec<f32>]) -> Result<ModeledServe> {
        let interval = self.model.interval.max(1);
        let decode_s = self.decode_step_s();
        let encode_s = self.gpu.encode_latency(self.model, 1);
        let cached = retriever.retcache_enabled();
        let before = retriever.rstats;

        let mut modeled_s = 0.0;
        let mut sync_s = 0.0;
        for q in queries {
            let block = interval as f64 * decode_s + encode_s;
            let (full, charged) = if cached {
                let cr = retriever.retrieve_cached(q)?;
                let charged = retriever.charge_retrieval(&cr, decode_s, interval);
                (cr.result.modeled_s, charged)
            } else {
                let r = retriever.retrieve(q)?;
                (r.modeled_s, r.modeled_s)
            };
            modeled_s += block + charged;
            sync_s += block + full;
        }
        let d = retriever.rstats.delta_since(&before);
        Ok(ModeledServe {
            tokens: queries.len() * interval,
            retrievals: queries.len(),
            modeled_s,
            sync_modeled_s: sync_s,
            misses: d.misses,
            cache_hits: d.cache_hits,
            spec_hits: d.spec_hits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::dispatcher::Dispatcher;
    use crate::chamvs::node::{MemoryNode, ScanEngine};
    use crate::config::{DEC_S, SIFT};
    use crate::data::corpus::Corpus;
    use crate::data::synthetic::SyntheticDataset;
    use crate::ivf::index::IvfPqIndex;
    use crate::ivf::shard::Shard;
    use crate::retcache::{zipf_stream, CacheConfig, SpecConfig};

    fn toy_stack() -> (Retriever, SyntheticDataset) {
        let data = SyntheticDataset::generate_sized(&SIFT, 2000, 64, 1);
        let index = IvfPqIndex::build(&data.data, data.n, data.d, SIFT.m, 32, 2);
        let nodes =
            vec![MemoryNode::new(Shard::carve(&index, 0, 1), ScanEngine::Native, 10)];
        let dispatcher = Dispatcher::new(nodes, 10);
        let corpus = Corpus::generate(2000, 2048, 8, 3);
        (Retriever::new(&SIFT, index, dispatcher, corpus), data)
    }

    fn workload(data: &SyntheticDataset, n_unique: usize, len: usize) -> Vec<Vec<f32>> {
        zipf_stream(n_unique, 1.1, len, 17)
            .into_iter()
            .map(|i| data.query(i % data.n_queries).to_vec())
            .collect()
    }

    #[test]
    fn cached_serve_at_least_as_fast_and_1_3x_on_zipf() {
        let (mut retriever, data) = toy_stack();
        let queries = workload(&data, 32, 200);
        let sm = ServeModel::new(&DEC_S);

        retriever.enable_cache(CacheConfig::default());
        retriever.enable_speculation(SpecConfig::default());
        let out = sm.run(&mut retriever, &queries).unwrap();

        assert_eq!(out.retrievals, 200);
        assert_eq!(
            out.misses + out.cache_hits + out.spec_hits,
            200,
            "every retrieval attributed"
        );
        assert!(out.cache_hits > 0, "repeated queries must hit");
        // Acceptance: cached serve >= uncached tokens/s, and >= 1.3x on a
        // Zipf-skewed repeated-query workload.
        assert!(
            out.modeled_tokens_per_s() >= out.sync_tokens_per_s(),
            "{} < {}",
            out.modeled_tokens_per_s(),
            out.sync_tokens_per_s()
        );
        assert!(out.speedup() >= 1.3, "speedup {}", out.speedup());
        assert!(out.hit_rate() > 0.5, "hit rate {}", out.hit_rate());
        assert!(retriever.rstats.saved_modeled_s > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let (mut a, data) = toy_stack();
        let queries = workload(&data, 16, 60);
        let sm = ServeModel::new(&DEC_S);
        a.enable_cache(CacheConfig::default());
        let ra = sm.run(&mut a, &queries).unwrap();

        let (mut b, _) = toy_stack();
        b.enable_cache(CacheConfig::default());
        let rb = sm.run(&mut b, &queries).unwrap();
        assert_eq!(ra.cache_hits, rb.cache_hits);
        assert!((ra.modeled_s - rb.modeled_s).abs() < 1e-12);
    }

    #[test]
    fn uncached_run_matches_sync_baseline() {
        let (mut r, data) = toy_stack();
        let queries = workload(&data, 8, 30);
        let sm = ServeModel::new(&DEC_S);
        let out = sm.run(&mut r, &queries).unwrap();
        assert_eq!(out.modeled_s, out.sync_modeled_s);
        assert_eq!(out.speedup(), 1.0);
        assert_eq!(out.cache_hits + out.spec_hits, 0);
    }
}
