//! Deterministic serving workloads for the cache/speculation benches: a
//! Zipf-skewed stream of query indices (production retrieval traffic is
//! heavily skewed — the same few queries/prefixes recur), plus helpers to
//! measure how repetitive a stream actually is.

use crate::util::rng::Rng;

/// A Zipf(alpha) stream of `len` indices over `0..n_unique`.
/// `alpha = 0` is uniform; larger alpha concentrates mass on low ranks.
pub fn zipf_stream(n_unique: usize, alpha: f64, len: usize, seed: u64) -> Vec<usize> {
    assert!(n_unique > 0);
    let weights: Vec<f64> = (1..=n_unique).map(|r| 1.0 / (r as f64).powf(alpha)).collect();
    let mut cdf = Vec::with_capacity(n_unique);
    let mut acc = 0.0;
    for w in &weights {
        acc += w;
        cdf.push(acc);
    }
    let total = acc;
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| {
            let x = rng.f64() * total;
            // Binary search for the first cdf entry >= x.
            match cdf.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(n_unique - 1),
            }
        })
        .collect()
}

/// Fraction of stream positions that repeat an index seen earlier —
/// the "query-repeat ratio" axis of the cache bench.
pub fn repeat_fraction(stream: &[usize]) -> f64 {
    if stream.is_empty() {
        return 0.0;
    }
    let mut seen = std::collections::HashSet::new();
    let mut repeats = 0usize;
    for &i in stream {
        if !seen.insert(i) {
            repeats += 1;
        }
    }
    repeats as f64 / stream.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(zipf_stream(32, 1.1, 200, 9), zipf_stream(32, 1.1, 200, 9));
        assert_ne!(zipf_stream(32, 1.1, 200, 9), zipf_stream(32, 1.1, 200, 10));
    }

    #[test]
    fn indices_in_range() {
        for &alpha in &[0.0, 0.8, 2.5] {
            let s = zipf_stream(17, alpha, 500, 3);
            assert_eq!(s.len(), 500);
            assert!(s.iter().all(|&i| i < 17));
        }
    }

    #[test]
    fn skew_concentrates_on_low_ranks() {
        let s = zipf_stream(64, 1.5, 4000, 5);
        let head = s.iter().filter(|&&i| i < 4).count() as f64 / s.len() as f64;
        assert!(head > 0.5, "top-4 mass {head}");
        // Uniform stream spreads out.
        let u = zipf_stream(64, 0.0, 4000, 5);
        let uhead = u.iter().filter(|&&i| i < 4).count() as f64 / u.len() as f64;
        assert!(uhead < 0.15, "uniform top-4 mass {uhead}");
    }

    #[test]
    fn higher_alpha_repeats_more() {
        let lo = repeat_fraction(&zipf_stream(256, 0.2, 512, 7));
        let hi = repeat_fraction(&zipf_stream(256, 1.8, 512, 7));
        assert!(hi > lo, "{hi} !> {lo}");
    }

    #[test]
    fn repeat_fraction_edges() {
        assert_eq!(repeat_fraction(&[]), 0.0);
        assert_eq!(repeat_fraction(&[1, 2, 3]), 0.0);
        assert!((repeat_fraction(&[1, 1, 1, 1]) - 0.75).abs() < 1e-12);
    }
}
