//! The retrieval cache: query embedding -> cached ChamVS result, with a
//! byte budget (not an entry count — entries carry K ids + K distances and
//! K varies 10..100 across models) and pluggable eviction.
//!
//! Eviction policies:
//! * **LRU** — classic recency order.
//! * **Cost-aware** — evict the entry with the lowest *saved modeled
//!   latency per byte* (a cheap-to-recompute result occupying many bytes
//!   goes first), with recency as tie-break. This matters once datasets
//!   mix: a SYN-1024 retrieval costs ~4x a SIFT one at the same footprint.

use std::collections::HashMap;

use super::key::{CacheKey, KeyPolicy};

/// Modeled coordinator-side cost of a cache hit (hash + copy of the K
/// result rows) — charged instead of the full ChamVS round trip.
pub const CACHE_LOOKUP_S: f64 = 2e-6;

/// Fixed per-entry bookkeeping overhead charged to the byte budget.
const ENTRY_OVERHEAD_BYTES: usize = 64;

/// Which entry goes first when the byte budget is exceeded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    Lru,
    CostAware,
}

/// Cache sizing + keying knobs.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Byte budget over keys + payloads + per-entry overhead.
    pub capacity_bytes: usize,
    pub policy: EvictionPolicy,
    pub key: KeyPolicy,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity_bytes: 4 << 20,
            policy: EvictionPolicy::Lru,
            key: KeyPolicy::Quantized(0.05),
        }
    }
}

/// One cached retrieval outcome.
#[derive(Clone, Debug)]
pub struct CachedEntry {
    pub ids: Vec<u64>,
    pub dists: Vec<f32>,
    /// Modeled paper-scale latency of the retrieval this entry replaces —
    /// the latency a hit saves, and the cost-aware eviction numerator.
    pub modeled_s: f64,
}

impl CachedEntry {
    fn payload_bytes(&self) -> usize {
        8 * self.ids.len() + 4 * self.dists.len()
    }
}

struct Slot {
    entry: CachedEntry,
    bytes: usize,
    /// Monotonic recency stamp (larger = more recently used).
    tick: u64,
}

/// Byte-budgeted retrieval cache.
pub struct RetrievalCache {
    pub cfg: CacheConfig,
    map: HashMap<CacheKey, Slot>,
    bytes: usize,
    tick: u64,
    // Lifetime counters (exported via retcache::stats; saved-latency
    // accounting lives in RetrievalStats via Retriever::charge_retrieval).
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl RetrievalCache {
    pub fn new(cfg: CacheConfig) -> RetrievalCache {
        RetrievalCache {
            cfg,
            map: HashMap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Bytes currently charged against the budget.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Look up a query; a hit refreshes recency and updates counters.
    pub fn get(&mut self, query: &[f32]) -> Option<&CachedEntry> {
        let key = self.cfg.key.key(query);
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&key) {
            Some(slot) => {
                slot.tick = tick;
                self.hits += 1;
                Some(&slot.entry)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a query's retrieval result, evicting under the
    /// configured policy until it fits. An entry larger than the whole
    /// budget is rejected rather than flushing the cache for nothing.
    pub fn insert(&mut self, query: &[f32], entry: CachedEntry) {
        let key = self.cfg.key.key(query);
        let new_bytes = key.bytes() + entry.payload_bytes() + ENTRY_OVERHEAD_BYTES;
        if new_bytes > self.cfg.capacity_bytes {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.bytes;
        }
        while self.bytes + new_bytes > self.cfg.capacity_bytes {
            if !self.evict_one() {
                break;
            }
        }
        self.tick += 1;
        self.bytes += new_bytes;
        self.insertions += 1;
        self.map.insert(key, Slot { entry, bytes: new_bytes, tick: self.tick });
    }

    /// Evict one entry per the policy; false if the cache is empty.
    ///
    /// O(n) scan per eviction — acceptable at in-process entry counts
    /// (a few thousand under the default budget) and only paid on
    /// miss-inserts under byte pressure; a tick-ordered secondary index
    /// is the upgrade path when multi-tenant budgets raise entry counts.
    fn evict_one(&mut self) -> bool {
        let victim = match self.cfg.policy {
            EvictionPolicy::Lru => self
                .map
                .iter()
                .min_by_key(|(_, s)| s.tick)
                .map(|(k, _)| k.clone()),
            EvictionPolicy::CostAware => self
                .map
                .iter()
                .min_by(|(_, a), (_, b)| {
                    let sa = a.entry.modeled_s / a.bytes as f64;
                    let sb = b.entry.modeled_s / b.bytes as f64;
                    sa.partial_cmp(&sb)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.tick.cmp(&b.tick))
                })
                .map(|(k, _)| k.clone()),
        };
        match victim {
            Some(k) => {
                let slot = self.map.remove(&k).unwrap();
                self.bytes -= slot.bytes;
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Lifetime hit rate in [0, 1] (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Whether a query would currently hit, without touching recency or
    /// counters (used by the speculation layer to decide what to prefetch).
    pub fn would_hit(&self, query: &[f32]) -> bool {
        self.map.contains_key(&self.cfg.key.key(query))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(k: usize, modeled_s: f64) -> CachedEntry {
        CachedEntry {
            ids: (0..k as u64).collect(),
            dists: vec![0.5; k],
            modeled_s,
        }
    }

    fn cfg(capacity: usize, policy: EvictionPolicy) -> CacheConfig {
        CacheConfig { capacity_bytes: capacity, policy, key: KeyPolicy::Exact }
    }

    fn q(i: usize) -> Vec<f32> {
        vec![i as f32; 8]
    }

    // Entry size with KeyPolicy::Exact, d=8, k=10:
    // key 32 + ids 80 + dists 40 + overhead 64 = 216 bytes.
    const E: usize = 216;

    #[test]
    fn hit_returns_payload_and_counts() {
        let mut c = RetrievalCache::new(cfg(10 * E, EvictionPolicy::Lru));
        assert!(c.get(&q(1)).is_none());
        c.insert(&q(1), entry(10, 1e-3));
        let e = c.get(&q(1)).expect("hit");
        assert_eq!(e.ids.len(), 10);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        // Room for exactly 2 entries.
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::Lru));
        c.insert(&q(1), entry(10, 1e-3));
        c.insert(&q(2), entry(10, 1e-3));
        // Touch 1 so 2 becomes LRU, then insert 3.
        assert!(c.get(&q(1)).is_some());
        c.insert(&q(3), entry(10, 1e-3));
        assert_eq!(c.evictions, 1);
        assert!(c.would_hit(&q(1)), "recently used survives");
        assert!(!c.would_hit(&q(2)), "LRU evicted");
        assert!(c.would_hit(&q(3)));
    }

    #[test]
    fn cost_aware_evicts_cheapest_per_byte() {
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::CostAware));
        c.insert(&q(1), entry(10, 5e-3)); // expensive to recompute
        c.insert(&q(2), entry(10, 1e-4)); // cheap
        // Make the cheap entry the most recent; cost-aware must still pick it.
        assert!(c.get(&q(2)).is_some());
        c.insert(&q(3), entry(10, 2e-3));
        assert!(c.would_hit(&q(1)), "expensive entry survives");
        assert!(!c.would_hit(&q(2)), "cheap entry evicted despite recency");
    }

    #[test]
    fn byte_budget_enforced() {
        let cap = 5 * E + E / 2; // room for 5, not 6
        let mut c = RetrievalCache::new(cfg(cap, EvictionPolicy::Lru));
        for i in 0..50 {
            c.insert(&q(i), entry(10, 1e-3));
            assert!(c.bytes() <= cap, "over budget: {} > {cap}", c.bytes());
        }
        assert_eq!(c.len(), 5);
        assert_eq!(c.bytes(), 5 * E);
        assert_eq!(c.evictions, 45);
    }

    #[test]
    fn oversized_entry_rejected_without_flushing() {
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::Lru));
        c.insert(&q(1), entry(10, 1e-3));
        c.insert(&q(2), entry(1000, 1e-3)); // > whole budget
        assert!(c.would_hit(&q(1)), "existing entries untouched");
        assert!(!c.would_hit(&q(2)));
        assert_eq!(c.evictions, 0);
    }

    #[test]
    fn reinsert_same_key_replaces_in_place() {
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::Lru));
        c.insert(&q(1), entry(10, 1e-3));
        c.insert(&q(1), entry(10, 9e-3));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), E);
        let e = c.get(&q(1)).unwrap();
        assert!((e.modeled_s - 9e-3).abs() < 1e-12);
    }

    #[test]
    fn quantized_policy_hits_on_jittered_queries() {
        let mut c = RetrievalCache::new(CacheConfig {
            capacity_bytes: 1 << 20,
            policy: EvictionPolicy::Lru,
            key: KeyPolicy::Quantized(0.1),
        });
        c.insert(&q(1), entry(10, 1e-3));
        let mut jq = q(1);
        jq[0] += 0.01;
        assert!(c.get(&jq).is_some(), "near-identical query hits");
    }
}
