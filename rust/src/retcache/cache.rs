//! The retrieval cache: query embedding -> cached ChamVS result, with a
//! byte budget (not an entry count — entries carry K ids + K distances and
//! K varies 10..100 across models) and pluggable eviction.
//!
//! Eviction policies:
//! * **LRU** — classic recency order.
//! * **Cost-aware** — evict the entry with the lowest *saved modeled
//!   latency per byte* (a cheap-to-recompute result occupying many bytes
//!   goes first), with recency as tie-break. This matters once datasets
//!   mix: a SYN-1024 retrieval costs ~4x a SIFT one at the same footprint.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use super::key::{CacheKey, KeyPolicy};

/// Modeled coordinator-side cost of a cache hit (hash + copy of the K
/// result rows) — charged instead of the full ChamVS round trip.
pub const CACHE_LOOKUP_S: f64 = 2e-6;

/// Fixed per-entry bookkeeping overhead charged to the byte budget.
const ENTRY_OVERHEAD_BYTES: usize = 64;

/// Which entry goes first when the byte budget is exceeded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    Lru,
    CostAware,
}

impl EvictionPolicy {
    /// Eviction-index score for an entry occupying `bytes` total: the
    /// minimum (score, recency tick) is the next victim.
    fn score(&self, entry: &CachedEntry, bytes: usize) -> f64 {
        match self {
            EvictionPolicy::Lru => 0.0,
            EvictionPolicy::CostAware => entry.modeled_s / bytes as f64,
        }
    }
}

/// Cache sizing + keying knobs.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Byte budget over keys + payloads + per-entry overhead.
    pub capacity_bytes: usize,
    pub policy: EvictionPolicy,
    pub key: KeyPolicy,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity_bytes: 4 << 20,
            policy: EvictionPolicy::Lru,
            key: KeyPolicy::Quantized(0.05),
        }
    }
}

/// One cached retrieval outcome.
#[derive(Clone, Debug)]
pub struct CachedEntry {
    pub ids: Vec<u64>,
    pub dists: Vec<f32>,
    /// Modeled paper-scale latency of the retrieval this entry replaces —
    /// the latency a hit saves, and the cost-aware eviction numerator.
    pub modeled_s: f64,
}

impl CachedEntry {
    fn payload_bytes(&self) -> usize {
        8 * self.ids.len() + 4 * self.dists.len()
    }
}

struct Slot {
    entry: CachedEntry,
    bytes: usize,
    /// Monotonic recency stamp (larger = more recently used).
    tick: u64,
}

/// One candidate in the ordered eviction index — a lazy-deletion min-heap
/// entry keyed on the policy's eviction score:
/// * LRU pushes `score = 0` for every entry, so ordering degenerates to
///   the recency tick (classic LRU order);
/// * cost-aware pushes `score = modeled_s / bytes` (saved latency per
///   byte), with the tick as tie-break — identical to the old O(n) scan's
///   `min_by` comparison.
///
/// A candidate is *stale* — skipped on pop — once its slot was touched
/// again (the slot's tick moved past `tick`) or removed entirely; every
/// touch pushes a fresh candidate, so each live slot always has exactly
/// one valid candidate and `evict_one` is O(log n) amortized instead of a
/// full scan.
struct EvictCandidate {
    score: f64,
    tick: u64,
    key: CacheKey,
}

impl PartialEq for EvictCandidate {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for EvictCandidate {}

impl PartialOrd for EvictCandidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EvictCandidate {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed comparison: `BinaryHeap` is a max-heap, so the top is
        // the minimum (score, tick) — the next victim.
        other
            .score
            .total_cmp(&self.score)
            .then(other.tick.cmp(&self.tick))
    }
}

/// Byte-budgeted retrieval cache.
pub struct RetrievalCache {
    pub cfg: CacheConfig,
    map: HashMap<CacheKey, Slot>,
    /// Ordered eviction index over `map` (see [`EvictCandidate`]).
    heap: BinaryHeap<EvictCandidate>,
    bytes: usize,
    tick: u64,
    // Lifetime counters (exported via retcache::stats; saved-latency
    // accounting lives in RetrievalStats via Retriever::charge_retrieval).
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl RetrievalCache {
    pub fn new(cfg: CacheConfig) -> RetrievalCache {
        RetrievalCache {
            cfg,
            map: HashMap::new(),
            heap: BinaryHeap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Bytes currently charged against the budget.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Look up a query; a hit refreshes recency (re-indexing the entry in
    /// the eviction heap) and updates counters.
    pub fn get(&mut self, query: &[f32]) -> Option<&CachedEntry> {
        let key = self.cfg.key.key(query);
        self.tick += 1;
        let tick = self.tick;
        let score = match self.map.get_mut(&key) {
            Some(slot) => {
                slot.tick = tick;
                self.hits += 1;
                self.cfg.policy.score(&slot.entry, slot.bytes)
            }
            None => {
                self.misses += 1;
                return None;
            }
        };
        self.push_candidate(score, tick, key.clone());
        Some(&self.map[&key].entry)
    }

    /// Insert (or refresh) a query's retrieval result, evicting under the
    /// configured policy until it fits. An entry larger than the whole
    /// budget is rejected rather than flushing the cache for nothing.
    pub fn insert(&mut self, query: &[f32], entry: CachedEntry) {
        let key = self.cfg.key.key(query);
        let new_bytes = key.bytes() + entry.payload_bytes() + ENTRY_OVERHEAD_BYTES;
        if new_bytes > self.cfg.capacity_bytes {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.bytes;
        }
        while self.bytes + new_bytes > self.cfg.capacity_bytes {
            if !self.evict_one() {
                break;
            }
        }
        self.tick += 1;
        let tick = self.tick;
        let score = self.cfg.policy.score(&entry, new_bytes);
        self.bytes += new_bytes;
        self.insertions += 1;
        self.map.insert(key.clone(), Slot { entry, bytes: new_bytes, tick });
        self.push_candidate(score, tick, key);
    }

    /// Evict one entry per the policy; false if the cache is empty.
    ///
    /// O(log n) amortized: pop the ordered eviction index until a live
    /// candidate surfaces (stale candidates — superseded by a later touch
    /// or already removed — are discarded lazily). The old O(n)
    /// `min_by` scan survives verbatim as the reference model in the
    /// `eviction_order_matches_scan_reference` test.
    fn evict_one(&mut self) -> bool {
        while let Some(c) = self.heap.pop() {
            let live = self.map.get(&c.key).is_some_and(|s| s.tick == c.tick);
            if !live {
                continue;
            }
            let slot = self.map.remove(&c.key).unwrap();
            self.bytes -= slot.bytes;
            self.evictions += 1;
            return true;
        }
        false
    }

    /// Index (or re-index after a recency touch) one entry in the
    /// eviction heap, compacting away stale candidates when they dominate.
    fn push_candidate(&mut self, score: f64, tick: u64, key: CacheKey) {
        self.heap.push(EvictCandidate { score, tick, key });
        if self.heap.len() > 64 && self.heap.len() > 8 * self.map.len() {
            let heap = std::mem::take(&mut self.heap);
            self.heap = heap
                .into_iter()
                .filter(|c| self.map.get(&c.key).is_some_and(|s| s.tick == c.tick))
                .collect();
        }
    }

    /// Re-budget the cache to `bytes`, evicting under the configured
    /// policy until the live set fits — the resize primitive behind
    /// per-tenant cache slicing (a shrink pays its evictions immediately
    /// so no tenant holds more than its slice).
    pub fn set_capacity(&mut self, bytes: usize) {
        self.cfg.capacity_bytes = bytes;
        while self.bytes > self.cfg.capacity_bytes {
            if !self.evict_one() {
                break;
            }
        }
    }

    /// Lifetime hit rate in [0, 1] (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Whether a query would currently hit, without touching recency or
    /// counters (used by the speculation layer to decide what to prefetch).
    pub fn would_hit(&self, query: &[f32]) -> bool {
        self.map.contains_key(&self.cfg.key.key(query))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(k: usize, modeled_s: f64) -> CachedEntry {
        CachedEntry {
            ids: (0..k as u64).collect(),
            dists: vec![0.5; k],
            modeled_s,
        }
    }

    fn cfg(capacity: usize, policy: EvictionPolicy) -> CacheConfig {
        CacheConfig { capacity_bytes: capacity, policy, key: KeyPolicy::Exact }
    }

    fn q(i: usize) -> Vec<f32> {
        vec![i as f32; 8]
    }

    // Entry size with KeyPolicy::Exact, d=8, k=10:
    // key 32 + ids 80 + dists 40 + overhead 64 = 216 bytes.
    const E: usize = 216;

    #[test]
    fn hit_returns_payload_and_counts() {
        let mut c = RetrievalCache::new(cfg(10 * E, EvictionPolicy::Lru));
        assert!(c.get(&q(1)).is_none());
        c.insert(&q(1), entry(10, 1e-3));
        let e = c.get(&q(1)).expect("hit");
        assert_eq!(e.ids.len(), 10);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        // Room for exactly 2 entries.
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::Lru));
        c.insert(&q(1), entry(10, 1e-3));
        c.insert(&q(2), entry(10, 1e-3));
        // Touch 1 so 2 becomes LRU, then insert 3.
        assert!(c.get(&q(1)).is_some());
        c.insert(&q(3), entry(10, 1e-3));
        assert_eq!(c.evictions, 1);
        assert!(c.would_hit(&q(1)), "recently used survives");
        assert!(!c.would_hit(&q(2)), "LRU evicted");
        assert!(c.would_hit(&q(3)));
    }

    #[test]
    fn cost_aware_evicts_cheapest_per_byte() {
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::CostAware));
        c.insert(&q(1), entry(10, 5e-3)); // expensive to recompute
        c.insert(&q(2), entry(10, 1e-4)); // cheap
        // Make the cheap entry the most recent; cost-aware must still pick it.
        assert!(c.get(&q(2)).is_some());
        c.insert(&q(3), entry(10, 2e-3));
        assert!(c.would_hit(&q(1)), "expensive entry survives");
        assert!(!c.would_hit(&q(2)), "cheap entry evicted despite recency");
    }

    #[test]
    fn byte_budget_enforced() {
        let cap = 5 * E + E / 2; // room for 5, not 6
        let mut c = RetrievalCache::new(cfg(cap, EvictionPolicy::Lru));
        for i in 0..50 {
            c.insert(&q(i), entry(10, 1e-3));
            assert!(c.bytes() <= cap, "over budget: {} > {cap}", c.bytes());
        }
        assert_eq!(c.len(), 5);
        assert_eq!(c.bytes(), 5 * E);
        assert_eq!(c.evictions, 45);
    }

    #[test]
    fn oversized_entry_rejected_without_flushing() {
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::Lru));
        c.insert(&q(1), entry(10, 1e-3));
        c.insert(&q(2), entry(1000, 1e-3)); // > whole budget
        assert!(c.would_hit(&q(1)), "existing entries untouched");
        assert!(!c.would_hit(&q(2)));
        assert_eq!(c.evictions, 0);
    }

    #[test]
    fn reinsert_same_key_replaces_in_place() {
        let mut c = RetrievalCache::new(cfg(2 * E, EvictionPolicy::Lru));
        c.insert(&q(1), entry(10, 1e-3));
        c.insert(&q(1), entry(10, 9e-3));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), E);
        let e = c.get(&q(1)).unwrap();
        assert!((e.modeled_s - 9e-3).abs() < 1e-12);
    }

    /// The pre-index O(n) eviction scan, kept verbatim as the reference
    /// model: the heap-based index must pick byte-for-byte the same
    /// victims on any recorded trace.
    struct ScanReference {
        policy: EvictionPolicy,
        capacity: usize,
        /// (query id, recency tick, modeled_s, slot bytes)
        slots: Vec<(usize, u64, f64, usize)>,
        tick: u64,
        bytes: usize,
        evictions: u64,
    }

    impl ScanReference {
        fn new(capacity: usize, policy: EvictionPolicy) -> ScanReference {
            ScanReference {
                policy,
                capacity,
                slots: Vec::new(),
                tick: 0,
                bytes: 0,
                evictions: 0,
            }
        }

        fn get(&mut self, qi: usize) {
            self.tick += 1;
            let tick = self.tick;
            if let Some(s) = self.slots.iter_mut().find(|s| s.0 == qi) {
                s.1 = tick;
            }
        }

        fn insert(&mut self, qi: usize, modeled_s: f64, bytes: usize) {
            if bytes > self.capacity {
                return;
            }
            if let Some(i) = self.slots.iter().position(|s| s.0 == qi) {
                self.bytes -= self.slots[i].3;
                self.slots.remove(i);
            }
            while self.bytes + bytes > self.capacity {
                let victim = match self.policy {
                    EvictionPolicy::Lru => self
                        .slots
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, s)| s.1)
                        .map(|(i, _)| i),
                    EvictionPolicy::CostAware => self
                        .slots
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| {
                            let sa = a.2 / a.3 as f64;
                            let sb = b.2 / b.3 as f64;
                            sa.partial_cmp(&sb)
                                .unwrap_or(std::cmp::Ordering::Equal)
                                .then(a.1.cmp(&b.1))
                        })
                        .map(|(i, _)| i),
                };
                match victim {
                    Some(i) => {
                        self.bytes -= self.slots[i].3;
                        self.slots.remove(i);
                        self.evictions += 1;
                    }
                    None => break,
                }
            }
            self.tick += 1;
            self.bytes += bytes;
            self.slots.push((qi, self.tick, modeled_s, bytes));
        }

        fn live(&self) -> Vec<usize> {
            let mut v: Vec<usize> = self.slots.iter().map(|s| s.0).collect();
            v.sort_unstable();
            v
        }
    }

    #[test]
    fn eviction_order_matches_scan_reference() {
        use crate::util::rng::Rng;
        // Entry bytes with KeyPolicy::Exact, d=8: 32 + 12k + 64.
        let entry_bytes = |k: usize| 32 + 12 * k + 64;
        for policy in [EvictionPolicy::Lru, EvictionPolicy::CostAware] {
            let cap = 6 * entry_bytes(10);
            let mut cache = RetrievalCache::new(cfg(cap, policy));
            let mut reference = ScanReference::new(cap, policy);
            let mut rng = Rng::new(0xEV1C7);
            // Recorded trace: interleaved gets and inserts over a small
            // universe, with varying entry sizes and recompute costs so
            // cost-aware ordering differs from pure recency.
            for step in 0..400 {
                let qi = rng.below(24);
                if rng.below(3) == 0 {
                    cache.get(&q(qi));
                    reference.get(qi);
                } else {
                    let k = [5usize, 10, 20][rng.below(3)];
                    let modeled_s = 1e-4 * (1 + rng.below(50)) as f64;
                    cache.insert(&q(qi), entry(k, modeled_s));
                    reference.insert(qi, modeled_s, entry_bytes(k));
                }
                // Identical victims at every step => identical live sets,
                // byte accounting and eviction counts.
                let live: Vec<usize> =
                    (0..24).filter(|&i| cache.would_hit(&q(i))).collect();
                assert_eq!(live, reference.live(), "{policy:?} step {step}");
                assert_eq!(cache.bytes(), reference.bytes, "{policy:?} step {step}");
                assert_eq!(
                    cache.evictions, reference.evictions,
                    "{policy:?} step {step}"
                );
            }
            assert!(cache.evictions > 20, "trace must exercise eviction");
        }
    }

    #[test]
    fn set_capacity_shrink_evicts_to_fit_and_grow_is_free() {
        let mut c = RetrievalCache::new(cfg(6 * E, EvictionPolicy::Lru));
        for i in 0..6 {
            c.insert(&q(i), entry(10, 1e-3));
        }
        assert_eq!(c.len(), 6);

        // Shrinking to half pays the evictions now, in LRU order.
        c.set_capacity(3 * E);
        assert_eq!(c.len(), 3);
        assert!(c.bytes() <= 3 * E);
        for i in 0..3 {
            assert!(!c.would_hit(&q(i)), "oldest entries evicted first");
        }
        for i in 3..6 {
            assert!(c.would_hit(&q(i)), "recent entries survive the shrink");
        }

        // Growing back changes the budget only; live set untouched, and
        // the headroom is immediately usable.
        let before = c.len();
        c.set_capacity(6 * E);
        assert_eq!(c.len(), before);
        for i in 6..9 {
            c.insert(&q(i), entry(10, 1e-3));
        }
        assert_eq!(c.len(), 6);
    }

    #[test]
    fn quantized_policy_hits_on_jittered_queries() {
        let mut c = RetrievalCache::new(CacheConfig {
            capacity_bytes: 1 << 20,
            policy: EvictionPolicy::Lru,
            key: KeyPolicy::Quantized(0.1),
        });
        c.insert(&q(1), entry(10, 1e-3));
        let mut jq = q(1);
        jq[0] += 0.01;
        assert!(c.get(&jq).is_some(), "near-identical query hits");
    }
}
