//! `retcache` — retrieval cache + speculative retrieval for the serving
//! path.
//!
//! Chameleon's disaggregated design makes every retrieval a full
//! coordinator -> ChamVS.idx -> ChamVS.mem round trip, and the seed
//! `RalmEngine` blocks decode on that trip at every retrieval interval.
//! This subsystem removes the round trip from the hot path twice over:
//!
//! * [`cache`] — a byte-budgeted retrieval cache keyed on exact or
//!   quantized query embeddings, with LRU and cost-aware eviction.
//! * [`spec`] — a RaLMSpec-style speculative prefetcher: the predicted
//!   next query is submitted to the [`crate::chamvs::Dispatcher`]'s
//!   non-blocking `submit`/`poll` API while the GPU decodes, verified
//!   against the real query on arrival, and cancelled on mismatch.
//! * [`stats`] — hit/miss + speculation-accuracy + saved-latency counters,
//!   exportable to [`crate::util::metrics::Metrics`] and the reports.
//! * [`model`] — a worker-free serving model (decode latencies from
//!   [`crate::hwmodel::GpuModel`], real retrieval numerics from
//!   [`crate::coordinator::Retriever`]) used by the benches, tests and the
//!   `report retcache` command.
//! * [`workload`] — deterministic Zipf query streams.
//!
//! Latency accounting contract (see [`charged_latency`]): a cache hit is
//! charged the lookup constant; a verified speculative prefetch is charged
//! only the residual of the retrieval latency not hidden behind the decode
//! steps it overlapped (`max(0, retrieval - overlap)`), so a serving step
//! costs `max(decode, residual_retrieval)`-shaped time instead of the sum;
//! a miss is charged the full synchronous round trip, exactly like the
//! seed engine.

pub mod cache;
pub mod key;
pub mod model;
pub mod slices;
pub mod spec;
pub mod stats;
pub mod workload;

pub use cache::{CacheConfig, CachedEntry, EvictionPolicy, RetrievalCache, CACHE_LOOKUP_S};
pub use key::{CacheKey, KeyPolicy};
pub use slices::SlicedCache;
pub use model::{ModeledServe, ServeModel};
pub use spec::{SpecConfig, SpecSlots, SpecVerdict, Speculator};
pub use stats::{RetrievalSource, RetrievalStats};
pub use workload::{repeat_fraction, zipf_stream};

/// Modeled latency charged to a serving step for one retrieval, given how
/// it was served, its full synchronous latency, and the decode window it
/// could overlap with (`interval * decode_step * speculation depth`).
pub fn charged_latency(source: RetrievalSource, full_s: f64, overlap_s: f64) -> f64 {
    match source {
        RetrievalSource::Miss => full_s,
        RetrievalSource::CacheHit => CACHE_LOOKUP_S,
        RetrievalSource::SpecHit => CACHE_LOOKUP_S + (full_s - overlap_s).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_charges_full_latency() {
        assert_eq!(charged_latency(RetrievalSource::Miss, 1e-3, 5e-4), 1e-3);
    }

    #[test]
    fn cache_hit_charges_lookup_only() {
        assert_eq!(charged_latency(RetrievalSource::CacheHit, 1e-3, 0.0), CACHE_LOOKUP_S);
    }

    #[test]
    fn spec_hit_charges_residual() {
        // Retrieval 1 ms, overlap 0.4 ms -> 0.6 ms residual + lookup.
        let c = charged_latency(RetrievalSource::SpecHit, 1e-3, 4e-4);
        assert!((c - (6e-4 + CACHE_LOOKUP_S)).abs() < 1e-12);
        // Fully hidden retrieval charges only the lookup.
        let c = charged_latency(RetrievalSource::SpecHit, 1e-3, 5e-3);
        assert_eq!(c, CACHE_LOOKUP_S);
    }
}
